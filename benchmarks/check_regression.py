"""CI regression gate for the serving benchmarks.

Compares a fresh bench JSON against its checked-in baseline and fails
(exit 1) on >``--tol`` regression of any *deterministic* metric, or if the
tokens diverged from the reference path. Wall-clock throughput is printed
for the artifact trail but never gated — hosted CI runners are too noisy
for absolute tok/s thresholds.

Two profiles (``--profile``):
  serve   BENCH_serve.json        — continuous-batching scheduler counters
                                    vs the fixed-batch path
  quant   BENCH_quant_serve.json  — packed mixed-precision runtime: decode
                                    steps, packed-HBM ratios, bucketed
                                    prefill compile count, token identity
                                    vs the fake-quant reference graph

Regression direction per metric:
  decode/slot steps        more steps than baseline  = scheduler regressed
  tokens_generated         fewer tokens than baseline = work went missing
  packed_vs_*/compiles     bigger than baseline = packing/bucketing regressed

Usage:
  python benchmarks/check_regression.py benchmarks/out/BENCH_serve.json \
      benchmarks/baselines/serve_baseline.json --tol 0.20
  python benchmarks/check_regression.py \
      benchmarks/out/BENCH_quant_serve.json \
      benchmarks/baselines/quant_serve_baseline.json --profile quant
"""
from __future__ import annotations

import argparse
import json
import sys

# metric -> +1 if larger-is-worse, -1 if smaller-is-worse
GATED = {
    "continuous_decode_steps": +1,
    "continuous_slot_steps": +1,
    "fixed_decode_steps": +1,
    "fixed_padded_slot_steps": +1,
    "tokens_generated": -1,
}
INFO = (
    "continuous_tok_per_s",
    "fixed_tok_per_s",
    "continuous_total_tok_per_s",
    "fixed_total_tok_per_s",
)

GATED_QUANT = {
    "decode_steps": +1,
    "tokens_generated": -1,
    "prefill_compiles": +1,
    "packed_vs_policy": +1,
    "packed_vs_fp32": +1,
    # the --mesh host8 sharded serving path (2-way dp x 4-way tp): same
    # scheduler counters plus the per-chip packed-bytes ratio, so the
    # tensor-parallel path is regression-gated alongside the single-device
    # one
    "sharded_decode_steps": +1,
    "sharded_tokens_generated": -1,
    "sharded_prefill_compiles": +1,
    "sharded_per_shard_vs_policy": +1,
    # per-step decode-attention cache traffic of the fused int8 route
    # (codes + scales + pos): growing = the cache inventory regressed
    "decode_attn_hbm_bytes": +1,
    # the paged-KV shared-prefix preset: FLOPs avoided by page-table hits
    # shrinking = prefix reuse regressed; compile shapes growing = chunked
    # append re-grew a per-prompt-length recompile
    "prefill_flops_saved": -1,
    "shared_prefix_prefill_compiles": +1,
    "shared_prefix_prefill_tokens": +1,
    # quantization health: pack-time saturation growing = the trained
    # scales stopped covering the served weights; any monitor alert on
    # the demo preset = the signal plane stopped being quiet on a healthy
    # workload (the bench itself also hard-asserts alerts_fired == 0, so
    # a zero baseline can never mask a regression via the ratio formula)
    "saturation_rate_max": +1,
    "alerts_fired": +1,
    # self-speculative decoding: the fraction of int4-draft proposals the
    # searched target policy confirms is deterministic on the demo preset
    # (greedy everywhere) — shrinking means the draft repack or the
    # verify/rollback path regressed
    "spec_accept_rate": -1,
    # elastic precision serving: the traffic ramp must keep trading
    # precision for load — fewer swaps means the controller stopped
    # reacting; pool-pressure deferrals growing means the downshift
    # stopped relieving admission pressure (zero in the baseline, so the
    # ratio formula can't fire on it alone — the bench hard-asserts the
    # flat-after-swap flag, mirroring the alerts_fired arrangement)
    "elastic_swaps": -1,
    "elastic_admissions_deferred": +1,
}
INFO_QUANT = (
    "packed_tok_per_s",
    "reference_tok_per_s",
    "hbm_bytes_saved_per_step",
    "sharded_per_shard_bytes",
    "decode_attn_model_vs_measured",
    "shared_prefix_unique_pages",
    # request-latency percentiles + roofline calibration ratios from the
    # obs metrics registry: wall-clock / host-dependent, never gated
    "ttft_p50_ms",
    "ttft_p95_ms",
    "itl_p50_ms",
    "roofline_modeled_vs_measured",
    # pack-time scale utilization (max|w| / (scale * qmax), p50 over
    # sites): informational — tracks how tightly the trained scales hug
    # the served weights, but init noise moves it
    "scale_utilization_p50",
    # speculative throughput and its ratio to the single-policy engine:
    # wall-clock, so never ratio-gated — the > 1.0x floor is the boolean
    # spec_speedup_gt_1 flag instead
    "spec_tokens_per_s",
    "spec_speedup_vs_single",
    # elastic serving shape: re-solve latency is wall-clock (the < 50 ms
    # floor is a bench hard-assert), downshift/hold counts are workload
    # color on top of the gated swap count
    "elastic_ilp_solve_ms_max",
    "elastic_downshifts",
    "elastic_swap_holds",
)

# boolean identity flags checked per profile (False or missing = failure)
IDENTITY_FLAGS = {
    "serve": ("token_identical",),
    # decode_attn_bytes_match: the roofline's kv_hbm_bytes must stay
    # within 5% of the fused route's measured cache traffic
    # shared_prefix_token_identical: the paged layout must generate the
    # ring layout's exact greedy tokens on both decode-attention routes
    # spec_token_identical: the speculating engine (int4 draft, searched
    # verify) must emit the single-policy engine's exact greedy tokens
    # spec_speedup_gt_1: a draft-k/verify-once round must beat k single
    # steps in measured decode wall-clock (a floor, not a ratio — hosted
    # runners are too noisy for absolute tok/s gates)
    "quant": (
        "token_identical",
        "sharded_token_identical",
        "decode_attn_bytes_match",
        "shared_prefix_token_identical",
        "spec_token_identical",
        "spec_speedup_gt_1",
        # elastic_token_identical: every completion of the elastic ramp
        # must match its generating variant's single-policy reference
        # elastic_deferred_flat_after_swap: once the controller downshifts,
        # pool-pressure admission deferrals must stop growing
        "elastic_token_identical",
        "elastic_deferred_flat_after_swap",
    ),
}

PROFILES = {
    "serve": (GATED, INFO, "the fixed-batch path"),
    "quant": (GATED_QUANT, INFO_QUANT, "the fake-quant reference graph"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument(
        "--tol",
        type=float,
        default=0.20,
        help="allowed fractional regression (default 20%%)",
    )
    ap.add_argument(
        "--profile",
        default="serve",
        choices=sorted(PROFILES),
        help="which benchmark's metric table to gate",
    )
    args = ap.parse_args(argv)
    cur = json.load(open(args.current))
    base = json.load(open(args.baseline))
    gated, info_metrics, reference = PROFILES[args.profile]

    failures = []
    for flag in IDENTITY_FLAGS[args.profile]:
        if not cur.get(flag, False):
            failures.append(f"{flag} is false: engine diverged from {reference}")
    for metric, worse_sign in gated.items():
        b, c = base.get(metric), cur.get(metric)
        if b is None or c is None:
            failures.append(f"{metric}: missing (baseline={b}, current={c})")
            continue
        delta = (c - b) / b if b else 0.0
        regressed = worse_sign * delta > args.tol
        mark = "FAIL" if regressed else "ok"
        print(
            f"  [{mark}] {metric}: baseline {b} -> current {c} "
            f"({delta:+.1%}, tol {args.tol:.0%})"
        )
        if regressed:
            failures.append(f"{metric} regressed {delta:+.1%}")
    for metric in info_metrics:
        if metric not in cur:
            continue
        c = cur[metric]
        if isinstance(c, dict):
            # e.g. roofline_modeled_vs_measured: {phase: ratio}
            pairs = ", ".join(f"{k}=x{v:.1f}" for k, v in sorted(c.items()))
            print(f"  [info] {metric}: {pairs} (not gated)")
            continue
        b = base.get(metric)
        btxt = f"{b:.1f}" if isinstance(b, (int, float)) else "n/a"
        print(f"  [info] {metric}: {c:.1f} (baseline {btxt}, not gated)")

    if failures:
        print("\nREGRESSION: " + "; ".join(failures))
        return 1
    print("\nno regression vs baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
