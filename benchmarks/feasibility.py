"""Paper Fig. 1 analog: do learned quantizer scales rank layer sensitivity?

The paper contrasts DW-convs (few params, sensitive) vs PW-convs (many,
insensitive) in MobileNet. The LM analog: narrow attention projections vs
wide MLP matmuls. Protocol (paper §3.3.1, adapted):

  1. ground-truth sensitivity: quantize ONE projection group at a time to
     2 bits vs 4 bits (others fp), finetune briefly, record the CE
     degradation gap CE(2b) - CE(4b);
  2. learned indicators: one joint training run (§3.4);
  3. report the rank correlation between indicator value s(2b) and the
     ground-truth sensitivity gap.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import importance as imp
from repro.core.policy import MPQPolicy
from repro.models import lm


def run(fast: bool = True):
    cfg, params, ctx, batches = common.demo_setup(fast)
    ql = lm.enumerate_qlayers(cfg)
    train_b, eval_b = batches[:10], batches[20:]

    # --- 1) ground truth: per-group one-at-a-time quantization -------------
    # 8-bit stands in for "unquantized" within the bank (6 bits max)
    rows = []
    gt_gap = {}
    for q in ql:
        gaps = {}
        for b in (2, 4):
            w_bits = {qq.name: 6 for qq in ql}
            a_bits = {qq.name: 6 for qq in ql}
            w_bits[q.name] = b
            a_bits[q.name] = b
            policy = MPQPolicy(w_bits, a_bits)
            bits = lm.bits_from_policy(cfg, policy, ql)
            ce, _ = common.finetune_and_eval(cfg, params, ctx, bits,
                                             train_b[:6], eval_b)
            gaps[b] = ce
        gt_gap[q.name] = gaps[2] - gaps[4]
        rows.append({"layer": q.name, "kind": q.kind,
                     "ce_2b": round(gaps[2], 4), "ce_4b": round(gaps[4], 4),
                     "sensitivity_gap": round(gt_gap[q.name], 4)})

    # --- 2) learned indicators ----------------------------------------------
    params2, _ = imp.train_importance(params, cfg, ctx, train_b, lr=0.02)
    ind = imp.extract_indicators(params2, cfg, ql)
    for r in rows:
        r["indicator_w_2b"] = round(float(ind[r["layer"]]["w"][0]), 5)
        r["indicator_a_2b"] = round(float(ind[r["layer"]]["a"][0]), 5)

    # --- 3) rank correlation -------------------------------------------------
    names = [q.name for q in ql]
    gt = np.asarray([gt_gap[n] for n in names])
    s2 = np.asarray([ind[n]["w"][0] + ind[n]["a"][0] for n in names])

    rho = common.spearman(gt, s2)
    print(f"feasibility: spearman(indicator, sensitivity) = {rho:.3f}  "
          f"(n={len(names)})")
    rows.append({"layer": "SPEARMAN", "kind": "-", "ce_2b": "", "ce_4b": "",
                 "sensitivity_gap": round(rho, 4), "indicator_w_2b": "",
                 "indicator_a_2b": ""})
    common.write_csv("feasibility.csv", rows)
    return {"spearman": rho}


if __name__ == "__main__":
    run()
