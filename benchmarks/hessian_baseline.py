"""Criterion-comparison (paper Table 1/3): learned indicators vs the
HAWQ-style Hessian-trace criterion under identical search + finetune.

The paper's argument: Hessian criteria are computed on the full-precision
net (quantization-blind) and rank only weights; ours is quantization-aware
and covers activations. Both criteria run through the SAME MCKP solver.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import hessian
from repro.core import importance as imp
from repro.core import search
from repro.models import lm

import jax


def run(fast: bool = True):
    cfg, params, ctx, batches = common.demo_setup(fast, n_batches=30)
    ql = lm.enumerate_qlayers(cfg)
    train_b, eval_b = batches[:12], batches[24:]

    with common.Timer() as t_ours:
        params_i, _ = imp.train_importance(params, cfg, ctx, train_b[:8],
                                           lr=0.02)
        ind = imp.extract_indicators(params_i, cfg, ql)
    with common.Timer() as t_hawq:
        hawq = hessian.hawq_sensitivities(params, cfg, train_b[0],
                                          jax.random.PRNGKey(7),
                                          qlayers=ql, n_samples=4)

    budget = search.bitops_budget_for_uniform(ql, 3)
    rows = []
    for label, table, alpha, src_params in (
            ("ours", ind, 1.0, params_i),
            ("hawq-proxy", hawq, 1.0, params)):
        res = search.search_policy(ql, table, cfg.bits, alpha=alpha,
                                   bitops_budget=budget)
        bits = lm.bits_from_policy(cfg, res.policy, ql)
        ce, _ = common.finetune_and_eval(cfg, src_params, ctx, bits,
                                         train_b, eval_b)
        rows.append({"criterion": label, "ce": round(ce, 4),
                     "avg_w": round(res.policy.avg_bits()[0], 2),
                     "avg_a": round(res.policy.avg_bits()[1], 2),
                     "criterion_time_s": round(
                         t_ours.dt if label == "ours" else t_hawq.dt, 1)})
        print(f"hessian_baseline {label}: ce={ce:.4f} "
              f"avg={rows[-1]['avg_w']}w/{rows[-1]['avg_a']}a "
              f"(criterion cost {rows[-1]['criterion_time_s']}s)")
    common.write_csv("hessian_baseline.csv", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
