"""Paper Table 3/5 analog: model-size (compression-rate) constrained search,
the dual BitOps+size constraint, and weight-only quantization."""
from __future__ import annotations

from benchmarks import common
from repro.core import importance as imp
from repro.core import search
from repro.models import lm


def run(fast: bool = True):
    cfg, params, ctx, batches = common.demo_setup(fast, n_batches=30)
    ql = lm.enumerate_qlayers(cfg)
    train_b, eval_b = batches[:12], batches[24:]
    params, _ = imp.train_importance(params, cfg, ctx, train_b[:8], lr=0.02)
    ind = imp.extract_indicators(params, cfg, ql)

    fp_bytes = sum(q.w_params for q in ql) * 4
    rows = []

    # Table 3: 12.2x compression-rate constraint
    for rate in (8.0, 12.2):
        size_budget = search.size_budget_for_rate(ql, 32, rate)
        res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                                   size_budget_bytes=size_budget)
        bits = lm.bits_from_policy(cfg, res.policy, ql)
        ce, _ = common.finetune_and_eval(cfg, params, ctx, bits, train_b,
                                         eval_b)
        rows.append({"constraint": f"size {rate}x",
                     "achieved_rate": round(fp_bytes / res.size_bytes, 2),
                     "avg_w_bits": round(res.policy.avg_bits()[0], 2),
                     "ce": round(ce, 4),
                     "search_ms": round(res.elapsed_s * 1e3, 1)})
        print(f"search_size rate={rate}x: achieved "
              f"{rows[-1]['achieved_rate']}x ce={ce:.4f}")

    # dual constraint (BitOps AND size)
    bud_ops = search.bitops_budget_for_uniform(ql, 4)
    bud_size = search.size_budget_for_rate(ql, 32, 10.0)
    res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                               bitops_budget=bud_ops,
                               size_budget_bytes=bud_size)
    bits = lm.bits_from_policy(cfg, res.policy, ql)
    ce, _ = common.finetune_and_eval(cfg, params, ctx, bits, train_b, eval_b)
    rows.append({"constraint": "bitops(4b) + size 10x",
                 "achieved_rate": round(fp_bytes / res.size_bytes, 2),
                 "avg_w_bits": round(res.policy.avg_bits()[0], 2),
                 "ce": round(ce, 4),
                 "search_ms": round(res.elapsed_s * 1e3, 1)})
    print(f"search_size dual: rate {rows[-1]['achieved_rate']}x "
          f"bitops<=budget={res.bitops <= bud_ops * 1.000001} ce={ce:.4f}")

    common.write_csv("search_size.csv", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
