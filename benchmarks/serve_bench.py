"""Serving-engine benchmark: continuous batching vs the fixed-batch path.

Runs the same staggered request set through the `repro.launch.engine`
continuous scheduler and the legacy fixed-batch policy, and writes
``benchmarks/out/BENCH_serve.json``. Two metric classes:

* deterministic scheduler metrics (decode_steps, slot_steps, tokens,
  token_identical) — machine-independent, gated by
  ``benchmarks/check_regression.py`` against the checked-in baseline in
  ``benchmarks/baselines/serve_baseline.json``;
* wall-clock throughput (tok/s for both policies) — recorded for the CI
  artifact trail but not gated (hosted-runner speed varies run to run).

Usage: PYTHONPATH=src python -m benchmarks.run --only serve_bench
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp

from benchmarks.common import OUT_DIR
from repro.configs import smoke_config
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.serve import build_requests
from repro.models import lm
from repro.models.quant_layers import QuantContext

import jax

BENCH_PATH = os.path.join(OUT_DIR, "BENCH_serve.json")


def bench_preset(fast: bool = True):
    """Small deterministic preset: staggered prompts/gens, mixed arrivals."""
    n_req = 8 if fast else 24
    return dict(arch="limpq-demo", slots=4, prompt_len=16, gen=8,
                n_requests=n_req, arrive_every=1)


def run(fast: bool = True):
    p = bench_preset(fast)
    cfg = smoke_config(p["arch"])
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    bits = lm.bits_uniform(cfg, 3)
    data = SyntheticLM(cfg)
    reqs = build_requests(data, p["n_requests"], p["prompt_len"], p["gen"],
                          stagger=True, arrive_every=p["arrive_every"])
    cache_len = p["prompt_len"] + p["gen"]

    eng = DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                       EngineConfig(slots=p["slots"], cache_len=cache_len))
    results = {}
    for policy in ("continuous", "fixed"):
        eng.reset(policy)           # warmup pass: pay the jit compiles so the
        eng.submit_all(reqs)        # recorded wall-clock is steady-state
        eng.run()
        eng.reset(policy)
        eng.submit_all(reqs)
        completions = eng.run()
        results[policy] = {
            "stats": eng.stats.as_dict(),
            "tokens": {r.rid: completions[r.rid].tokens for r in reqs},
        }

    cont, fixed = results["continuous"], results["fixed"]
    identical = cont["tokens"] == fixed["tokens"]
    out = {
        "preset": p,
        "prefill_chunk": eng.prefill_chunk,
        "token_identical": identical,
        # gated (deterministic)
        "continuous_decode_steps": cont["stats"]["decode_steps"],
        "continuous_slot_steps": cont["stats"]["slot_steps"],
        "fixed_decode_steps": fixed["stats"]["decode_steps"],
        "fixed_padded_slot_steps": fixed["stats"]["padded_slot_steps"],
        "tokens_generated": cont["stats"]["tokens_generated"],
        # informational (machine-dependent)
        "continuous_tok_per_s": cont["stats"]["decode_tokens_per_s"],
        "fixed_tok_per_s": fixed["stats"]["decode_tokens_per_s"],
        "continuous_total_tok_per_s": cont["stats"]["total_tokens_per_s"],
        "fixed_total_tok_per_s": fixed["stats"]["total_tokens_per_s"],
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"  token_identical={identical} | decode steps: "
          f"continuous {out['continuous_decode_steps']} vs fixed "
          f"{out['fixed_decode_steps']} | slot-steps "
          f"{out['continuous_slot_steps']} vs "
          f"{out['fixed_padded_slot_steps']} (padded)")
    print(f"  -> {BENCH_PATH}")
    assert identical, "continuous batching diverged from the fixed-batch path"
    assert out["continuous_decode_steps"] < out["fixed_decode_steps"], \
        "continuous batching saved no decode steps on the staggered preset"
    return out


if __name__ == "__main__":
    run()
