"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).

  feasibility        Fig. 1/2   indicator-vs-sensitivity rank correlation
  joint_training     §3.4/Fig.3 one-shot indicator training + freeze check
  search_bitops      Table 2/4  BitOps-constrained MPQ (2.5/3/4-bit levels)
  search_size        Table 3/5  compression-rate + dual constraints
  ablation_reverse   Table 6    reversed-assignment ablation
  search_efficiency  §4.3       ILP time on all 10 real arch tables
  hessian_baseline   Table 1/3  HAWQ-proxy criterion comparison
  kernel_report      —          Pallas kernels: correctness + VMEM budgets
  roofline_report    —          aggregates experiments/dryrun artifacts
  serve_bench        —          continuous-batching engine vs fixed batch
                                (writes BENCH_serve.json for the CI gate)
  quant_serve_bench  —          packed mixed-precision runtime vs the
                                fake-quant reference graph (writes
                                BENCH_quant_serve.json for the CI gate)
  roofline_calibration  —       measured engine phases vs the roofline
                                step-cost model + measured device table
                                (writes BENCH_roofline_calibration.json;
                                informational, never gated)

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--full]
       PYTHONPATH=src python -m benchmarks.run --baseline
"""
import argparse
import time
import traceback

MODULES = ["kernel_report", "search_efficiency", "joint_training",
           "ablation_reverse", "search_bitops", "search_size",
           "hessian_baseline", "feasibility", "roofline_report",
           "serve_bench", "quant_serve_bench", "roofline_calibration"]

# --baseline: profile -> (fresh bench JSON, checked-in baseline JSON)
BASELINE_PAIRS = {
    "serve": ("out/BENCH_serve.json", "baselines/serve_baseline.json"),
    "quant": ("out/BENCH_quant_serve.json",
              "baselines/quant_serve_baseline.json"),
}
EXPERIMENTS_MD = "experiments/EXPERIMENTS.md"


def baseline_dryrun():
    """Dry-run delta report: compare the bench JSONs already under
    ``benchmarks/out/`` against the checked-in baselines (no benchmark is
    re-run) and append a dated markdown delta table to
    ``experiments/EXPERIMENTS.md``. Metric tables and regression
    directions come from ``check_regression`` — the same source the CI
    gate reads, so the experiment log and the gate can never disagree on
    what a metric means."""
    import datetime
    import json
    import os

    from benchmarks import check_regression as cr

    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    lines = [f"## Baseline dry-run — "
             f"{datetime.date.today().isoformat()}", ""]
    lines.append("| profile | metric | baseline | current | delta | gate |")
    lines.append("|---|---|---|---|---|---|")
    n_rows = 0
    for profile, (cur_rel, base_rel) in sorted(BASELINE_PAIRS.items()):
        cur_path = os.path.join(here, cur_rel)
        base_path = os.path.join(here, base_rel)
        if not os.path.exists(cur_path):
            print(f"  [{profile}] skipped: {cur_rel} not found (run the "
                  "benchmarks first)")
            continue
        cur = json.load(open(cur_path))
        base = json.load(open(base_path))
        gated, info_metrics, _ = cr.PROFILES[profile]
        flags = cr.IDENTITY_FLAGS[profile]
        for metric in list(gated) + list(flags) + list(info_metrics):
            b, c = base.get(metric), cur.get(metric)
            if c is None or isinstance(c, dict):
                continue
            if isinstance(c, bool) or isinstance(b, bool):
                delta = "—"
            elif isinstance(b, (int, float)) and b:
                delta = f"{(c - b) / b:+.1%}"
            else:
                delta = "—"
            kind = ("gated" if metric in gated else
                    "identity" if metric in flags else "info")

            def fmt(v):
                if isinstance(v, bool):
                    return str(v)
                if isinstance(v, float):
                    return f"{v:.4g}"
                return str(v)
            lines.append(f"| {profile} | {metric} | {fmt(b)} | {fmt(c)} "
                         f"| {delta} | {kind} |")
            n_rows += 1
    if not n_rows:
        raise SystemExit("--baseline: no bench outputs to compare "
                         "(benchmarks/out/ is empty)")
    lines.append("")
    md = os.path.join(root, EXPERIMENTS_MD)
    os.makedirs(os.path.dirname(md), exist_ok=True)
    fresh = not os.path.exists(md)
    with open(md, "a") as f:
        if fresh:
            f.write("# Experiment log\n\nDated delta tables appended by "
                    "`python -m benchmarks.run --baseline` (dry-run: "
                    "compares `benchmarks/out/*.json` against the "
                    "checked-in baselines without re-running anything).\n"
                    "`gate` column: gated/identity rows fail CI on "
                    "regression (`benchmarks/check_regression.py`); info "
                    "rows are the artifact trail.\n\n")
        f.write("\n".join(lines) + "\n")
    print(f"  {n_rows} delta rows -> {EXPERIMENTS_MD}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full-size demo model (slower)")
    ap.add_argument("--baseline", action="store_true",
                    help="dry-run: diff benchmarks/out/*.json against the "
                         "checked-in baselines and append a dated delta "
                         "table to experiments/EXPERIMENTS.md (runs no "
                         "benchmarks)")
    args = ap.parse_args()
    if args.baseline:
        baseline_dryrun()
        return
    mods = [args.only] if args.only else MODULES
    results, failures = {}, []
    for name in mods:
        print(f"=== {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            results[name] = mod.run(fast=not args.full)
            print(f"=== {name} done in {time.time()-t0:.1f}s ===", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\nbenchmarks complete: {len(results)} ok, {len(failures)} failed "
          f"{failures if failures else ''}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
