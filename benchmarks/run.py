"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).

  feasibility        Fig. 1/2   indicator-vs-sensitivity rank correlation
  joint_training     §3.4/Fig.3 one-shot indicator training + freeze check
  search_bitops      Table 2/4  BitOps-constrained MPQ (2.5/3/4-bit levels)
  search_size        Table 3/5  compression-rate + dual constraints
  ablation_reverse   Table 6    reversed-assignment ablation
  search_efficiency  §4.3       ILP time on all 10 real arch tables
  hessian_baseline   Table 1/3  HAWQ-proxy criterion comparison
  kernel_report      —          Pallas kernels: correctness + VMEM budgets
  roofline_report    —          aggregates experiments/dryrun artifacts
  serve_bench        —          continuous-batching engine vs fixed batch
                                (writes BENCH_serve.json for the CI gate)
  quant_serve_bench  —          packed mixed-precision runtime vs the
                                fake-quant reference graph (writes
                                BENCH_quant_serve.json for the CI gate)
  roofline_calibration  —       measured engine phases vs the roofline
                                step-cost model + measured device table
                                (writes BENCH_roofline_calibration.json;
                                informational, never gated)

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--full]
"""
import argparse
import time
import traceback

MODULES = ["kernel_report", "search_efficiency", "joint_training",
           "ablation_reverse", "search_bitops", "search_size",
           "hessian_baseline", "feasibility", "roofline_report",
           "serve_bench", "quant_serve_bench", "roofline_calibration"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full-size demo model (slower)")
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    results, failures = {}, []
    for name in mods:
        print(f"=== {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            results[name] = mod.run(fast=not args.full)
            print(f"=== {name} done in {time.time()-t0:.1f}s ===", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\nbenchmarks complete: {len(results)} ok, {len(failures)} failed "
          f"{failures if failures else ''}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
