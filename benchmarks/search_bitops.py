"""Paper Table 2/4 analog: BitOps-constrained MPQ at 2.5/3/4-bit levels.

For each budget level: ours (ILP over learned indicators) vs the uniform-
bit baseline at the same level vs the reversed assignment — identical
finetuning, CE on held-out synthetic data. (ImageNet accuracies are not
reproducible in-container; the claims *structure* — ours <= uniform <=
reversed, budgets respected — is what this table validates. DESIGN.md §8.)
"""
from __future__ import annotations

from benchmarks import common
from repro.core import importance as imp
from repro.core import search
from repro.core.policy import MPQPolicy
from repro.models import lm


def run(fast: bool = True):
    cfg, params, ctx, batches = common.demo_setup(fast, n_batches=30)
    ql = lm.enumerate_qlayers(cfg)
    train_b, eval_b = batches[:12], batches[24:]

    params, _ = imp.train_importance(params, cfg, ctx, train_b[:8], lr=0.02)
    ind = imp.extract_indicators(params, cfg, ql)

    rows = []
    for level in (2.5, 3, 4):
        budget = search.bitops_budget_for_uniform(ql, 4) * (level / 4) ** 2 \
            if level == 2.5 else search.bitops_budget_for_uniform(ql, int(level))
        res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                                   bitops_budget=budget)
        bits = lm.bits_from_policy(cfg, res.policy, ql)
        ce0_ours = common.eval_no_finetune(cfg, params, ctx, bits, eval_b)
        ce_ours, _ = common.finetune_and_eval(cfg, params, ctx, bits,
                                              train_b, eval_b)
        row = {"level": level, "budget_bitops": f"{budget:.3e}",
               "ours_bitops": f"{res.bitops:.3e}",
               "ours_avg_w": round(res.policy.avg_bits()[0], 2),
               "ours_avg_a": round(res.policy.avg_bits()[1], 2),
               "ce_ours_immediate": round(ce0_ours, 4),
               "ce_ours": round(ce_ours, 4),
               "search_ms": round(res.elapsed_s * 1e3, 1)}
        if level in (3, 4):
            uni = MPQPolicy.uniform(ql, int(level))
            ubits = lm.bits_from_policy(cfg, uni, ql)
            row["ce_uniform_immediate"] = round(
                common.eval_no_finetune(cfg, params, ctx, ubits, eval_b), 4)
            ce_uni, _ = common.finetune_and_eval(cfg, params, ctx, ubits,
                                                 train_b, eval_b)
            row["ce_uniform"] = round(ce_uni, 4)
        rows.append(row)
        print(f"search_bitops level={level}: ours ce={ce_ours:.4f} "
              f"(immediate {ce0_ours:.4f}, avg {row['ours_avg_w']}w/"
              f"{row['ours_avg_a']}a, search {row['search_ms']}ms)"
              + (f" uniform ce={row['ce_uniform']:.4f} "
                 f"(immediate {row['ce_uniform_immediate']:.4f})"
                 if "ce_uniform" in row else ""))
    common.write_csv("search_bitops.csv", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
