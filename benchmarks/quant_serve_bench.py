"""Quantized-serving benchmark: the packed runtime vs the fake-quant graph.

Runs a mixed (cyclic over the searched widths) policy through
``repro.runtime.session.QuantizedSession`` — packed weights, int8 KV
slots, bucketed prefill — and the fake-quant reference engine on the same
staggered request set, then writes ``benchmarks/out/BENCH_quant_serve.json``:

* deterministic gated metrics (``check_regression.py --profile quant``):
  token identity with the reference graph, decode steps, tokens, measured
  packed-vs-policy HBM ratio, packed-vs-fp32 compression, bucketed prefill
  compile count;
* per-step FLOP/byte counters from the bit-aware roofline
  (``dist.roofline.decode_step_cost``) for the fp16/bf16-KV baseline vs
  the packed+int8-KV runtime — the arithmetic-intensity shift quantized
  serving buys — including the "int8 stored but fp-attended" column
  (``kv_attend="dequant"``) the fused decode-attention kernel removes;
* the routed decode-attention story (gated): the packed engine runs with
  the fused int8 decode-attention kernel forced through the Pallas
  interpreter (``decode_attn_route``), so token identity vs the reference
  graph covers the kernel program, and the measured per-step cache
  traffic (``decode_attn_hbm_bytes`` = codes + scales + pos, from
  ``runtime.kv_cache.cache_bytes``) must match the roofline's
  ``kv_hbm_bytes`` within 5% (``decode_attn_bytes_match``);
* the self-speculative decoding preset (``_spec_counters``): an int4
  draft repack of the same session drafts k=4 tokens per round for the
  searched target policy — token identity with the single-policy engine,
  the acceptance rate, and a measured decode speedup > 1.0x are gated;
* the elastic precision serving preset (``_elastic_counters``): a 3/4/6
  average-bit policy-variant bank served through the admission-time ILP
  controller on a one-request-per-tick ramp — gated on a downshift swap
  firing, per-variant token identity with each generating variant's
  single-policy reference, pool deferrals going flat after the swap,
  zero weight repacks after engine build, and sub-50 ms re-solves;
* wall-clock throughput for the artifact trail (never gated);
* the SHARDED serving path (``--mesh host8``-equivalent: 2-way dp x 4-way
  tp over 8 forced host devices, run in a subprocess so this process
  keeps 1 device): scheduler counters + token identity vs the
  single-device session + measured per-shard-vs-budget ratio, all gated,
  plus the tp roofline's per-shard HBM and all-reduce wire bytes so the
  bench table shows the tp-scaling story.

Usage: PYTHONPATH=src python -m benchmarks.run --only quant_serve_bench
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import OUT_DIR
from repro.configs import smoke_config
from repro.data import SyntheticLM
from repro.dist import roofline
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.serve import build_requests
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.runtime.session import QuantizedSession, summarize

BENCH_PATH = os.path.join(OUT_DIR, "BENCH_quant_serve.json")


def bench_preset(fast: bool = True):
    n_req = 6 if fast else 16
    return dict(arch="limpq-demo", slots=4, prompt_len=16, gen=6,
                n_requests=n_req, arrive_every=1)


def shared_prefix_preset(fast: bool = True):
    """The shared-system-prompt workload the paged KV layout wins on:
    every prompt opens with the same ``prompt_len // 2`` tokens (a full
    page), so the paged engine re-maps those pages instead of
    re-prefilling them."""
    return dict(requests=4 if fast else 8, slots=2, prompt_len=16, gen=4,
                page_size=8)


def _shared_prefix_counters(cfg, params, ctx, policy, fast: bool) -> dict:
    """Serve one shared-prefix request set through {ring, paged} x
    {fused-interpret, dequant-fp} engines over ONE packed session.  Gated:
    greedy tokens bitwise-identical between the layouts on both routes,
    paged saves >0 prefill FLOPs via page-table hits, and chunked-append
    prefill compiles exactly one shape (no prompt-length bucketing)."""
    from repro.launch.serve import ServeConfig
    from repro.runtime import dispatch

    sp = shared_prefix_preset(fast)
    scfg = ServeConfig(arch=cfg.name, requests=sp["requests"],
                       slots=sp["slots"], prompt_len=sp["prompt_len"],
                       gen=sp["gen"], stagger=True, arrive_every=1,
                       kv_layout="paged", page_size=sp["page_size"])
    data = SyntheticLM(cfg)
    reqs = build_requests(data, scfg.requests, scfg.prompt_len, scfg.gen,
                          stagger=scfg.stagger,
                          arrive_every=scfg.arrive_every,
                          share_prefix=scfg.prompt_len // 2)
    sess = QuantizedSession(cfg, params, policy, ctx, mode="packed",
                            kv_quant="int8")
    identical = True
    saved = None
    paged = {}
    for route in ("fused-interpret", "dequant-fp"):
        toks = {}
        for layout in ("ring", "paged"):
            with dispatch.force_decode_attn(route):
                eng = DecodeEngine(
                    sess.params, cfg, None, ctx, NO_AXES,
                    scfg.engine_config(layout=layout), adapter=sess)
                eng.submit_all(reqs)
                out = eng.run()
            toks[layout] = {r.rid: out[r.rid].tokens for r in reqs}
            st = eng.stats.as_dict()
            if layout == "paged":
                eng.pool.check()
                saved = st["prefill_flops_saved"]
                paged.update(prefill_tokens=st["prefill_tokens"],
                             prefill_compiles=st["prefill_compiles"],
                             unique_pages=st["kv_unique_pages"])
            else:
                paged["ring_prefill_tokens"] = st["prefill_tokens"]
        identical &= toks["paged"] == toks["ring"]
    return {
        "shared_prefix_token_identical": bool(identical),
        "prefill_flops_saved": float(saved),
        "shared_prefix_prefill_compiles": paged["prefill_compiles"],
        "shared_prefix_prefill_tokens": paged["prefill_tokens"],
        "shared_prefix_ring_prefill_tokens": paged["ring_prefill_tokens"],
        "shared_prefix_unique_pages": paged["unique_pages"],
    }


def spec_preset(fast: bool = True):
    """Self-speculative decoding preset: int4 draft, k=4 rounds, untraced
    (the single fused draft+verify launch the serving path times).  k=4
    is the first round shape the roofline says beats k single steps on
    the demo model; the int4 draft keeps the acceptance rate high enough
    (~0.4) that the measured speedup clears 1.0x with margin on a noisy
    CI host.  Single-slot on purpose: batch-1 latency-bound decode is
    the regime speculation targets — per-launch dispatch overhead
    amortizes over k+1 tokens per round and the win is stable
    (1.7-2.3x here); at slots=4 the round is compute-bound on the tiny
    demo model and the measured ratio straddles 1.0 with host noise."""
    return dict(requests=2 if fast else 4, slots=1, prompt_len=16, gen=24,
                speculate=4, draft_bits=4)


def _spec_counters(cfg, params, ctx, policy, fast: bool) -> dict:
    """Serve one request set through a speculating engine and a
    non-speculative engine over the same dual-pack session.  Gated:
    greedy tokens identical (the acceptance rule compares argmaxes, so
    identity holds by construction — this gate catches rollback/KV bugs,
    not sampling luck), acceptance rate, and decode speedup > 1.0x."""
    from repro.runtime.session import SpecSession

    sp = spec_preset(fast)
    cache_len = sp["prompt_len"] + sp["gen"] + 8  # k-row verify headroom
    data = SyntheticLM(cfg)
    reqs = build_requests(data, sp["requests"], sp["prompt_len"], sp["gen"],
                          stagger=False)
    sess = SpecSession(cfg, params, policy, ctx,
                       draft_w_bits=sp["draft_bits"], kv_quant="int8")

    picked = {}
    for name, spec_k in (("single", 0), ("spec", sp["speculate"])):
        eng = DecodeEngine(
            sess.params, cfg, None, ctx, NO_AXES,
            EngineConfig(slots=sp["slots"], cache_len=cache_len,
                         kv_quant="int8", speculate=spec_k, trace=False),
            adapter=sess)
        eng.submit_all(reqs)
        eng.run()                                 # warmup: pay the jits
        best = None
        for _ in range(3):                        # best-of-3 measured
            eng.reset()
            eng.submit_all(reqs)
            completions = eng.run()
            st = eng.stats
            if best is None or st.t_decode_s < best[0].t_decode_s:
                best = (st, {r.rid: completions[r.rid].tokens
                             for r in reqs})
        picked[name] = best

    single_st, single_toks = picked["single"]
    spec_st, spec_toks = picked["spec"]
    speedup = (single_st.t_decode_s / spec_st.t_decode_s
               if spec_st.t_decode_s else float("nan"))
    return {
        "spec_token_identical": bool(spec_toks == single_toks),
        "spec_accept_rate": float(spec_st.spec_accept_rate),
        "spec_rounds": spec_st.spec_rounds,
        "spec_draft_tokens": spec_st.spec_draft_tokens,
        "spec_tokens_per_s": spec_st.decode_tokens_per_s,
        "single_policy_tokens_per_s": single_st.decode_tokens_per_s,
        "spec_speedup_vs_single": float(speedup),
        "spec_speedup_gt_1": bool(speedup > 1.0),
    }


def elastic_preset(fast: bool = True):
    """Elastic precision serving: the traffic ramp that forces a swap.
    One request per tick into 2 slots builds a queue fast enough that the
    admission-time ILP re-solve downshifts the active variant; the 3/4/6
    average-bit budgets match the serve --elastic default bank."""
    return dict(requests=8 if fast else 16, slots=2, prompt_len=16, gen=6,
                arrive_every=1, budgets=(3.0, 4.0, 6.0))


def _elastic_counters(cfg, params, ctx, fast: bool) -> dict:
    """Serve the ramp through a variant bank + elastic controller.  Gated:
    at least one downshift swap fires, per-request tokens are bitwise
    identical to the generating variant's single-policy reference, the
    pool-pressure deferral counter stays flat once the swap lands (the
    whole point of degrading precision under load), zero weight repacks
    after engine build, and every admission re-solve closes under 50 ms."""
    from repro.launch import elastic
    from repro.runtime import packing
    from repro.runtime.session import ElasticSession, bank_fingerprint

    ep = elastic_preset(fast)
    cache_len = ep["prompt_len"] + ep["gen"]
    data = SyntheticLM(cfg)
    reqs = build_requests(data, ep["requests"], ep["prompt_len"], ep["gen"],
                          stagger=True, arrive_every=ep["arrive_every"])
    ql = lm.enumerate_qlayers(cfg)
    bank = elastic.build_variant_bank(ql, cfg.bits, ep["budgets"],
                                      family=bank_fingerprint(params))
    sess = ElasticSession(cfg, params, bank.policies, ctx,
                          active=bank.full)
    ctrl = elastic.ElasticController(cfg, bank, slots=ep["slots"],
                                     cache_len=cache_len)
    eng = DecodeEngine(
        sess.params, cfg, None, ctx, NO_AXES,
        EngineConfig(slots=ep["slots"], cache_len=cache_len,
                     kv_quant="int8"),
        adapter=sess, elastic=ctrl)
    # hot-path contract: swaps device_put pre-packed trees, they never
    # repack — count pack_linear calls from here on (build already paid)
    repacks = {"n": 0}
    real_pack = packing.pack_linear

    def counting_pack(*a, **kw):
        repacks["n"] += 1
        return real_pack(*a, **kw)

    # per-iteration (swaps, pool-deferral) series for the flatness gate
    series = []
    eng.on_step = lambda m: series.append(
        (m.value("engine.policy_swaps"),
         m.value("scheduler.admissions_deferred_pool")))
    packing.pack_linear = counting_pack
    try:
        eng.submit_all(reqs)
        completions = eng.run()
    finally:
        packing.pack_linear = real_pack
    st = eng.stats

    # once the controller traded precision for load, pool pressure must
    # stop deferring admissions — the deferral counter goes flat
    after = [d for swaps, d in series if swaps >= 1]
    deferred_flat = (not after) or after[-1] == after[0]

    per_variant = {}
    for c in completions.values():
        per_variant.setdefault(c.policy_id, []).append(c.rid)
    identical = True
    for pid, rids in sorted(per_variant.items()):
        vbits = lm.bits_from_policy(cfg, bank.policies[pid])
        ref = DecodeEngine(
            params, cfg, vbits, ctx, NO_AXES,
            EngineConfig(slots=ep["slots"], cache_len=cache_len,
                         kv_quant="fake"))
        ref.submit_all([r for r in reqs if r.rid in set(rids)])
        ref_out = ref.run()
        identical &= all(ref_out[rid].tokens == completions[rid].tokens
                         for rid in rids)
    return {
        "elastic_swaps": st.policy_swaps,
        "elastic_downshifts": st.policy_swaps_down,
        "elastic_token_identical": bool(identical),
        "elastic_admissions_deferred":
            int(eng.metrics.value("scheduler.admissions_deferred_pool")),
        "elastic_deferred_flat_after_swap": bool(deferred_flat),
        "elastic_repacks_after_build": repacks["n"],
        "elastic_ilp_solves": st.ilp_solves,
        "elastic_ilp_solve_ms_max": float(ctrl.max_solve_ms),
        "elastic_variants_resident": len(sess.variants),
        "elastic_final_variant": st.active_policy,
        "elastic_swap_holds": st.admissions_deferred_swap,
    }


def _mixed_policy(cfg):
    # the same builder the serve --policy smoke uses: the checked-in
    # baseline pins this exact bit assignment
    from repro.launch.serve import demo_mixed_policy
    return lm.enumerate_qlayers(cfg), demo_mixed_policy(cfg)


def _step_counters(cfg, slots, cache_len, *, kv_bits, w_bits_total=None,
                   avg_weight_bits=32.0, tp_size=1, kv_attend="fused"):
    cost = roofline.decode_step_cost(
        cfg, slots, cache_tokens=cache_len, kv_bits=kv_bits,
        w_bits_total=w_bits_total, avg_weight_bits=avg_weight_bits,
        tp_size=tp_size, kv_attend=kv_attend)
    chip = roofline.DEFAULT_CHIP
    flops = cost["compute_s"] * chip.peak_flops
    hbm = cost["memory_s"] * chip.hbm_bytes_s
    return {"step_flops": flops, "step_hbm_bytes": hbm,
            "flops_per_byte": flops / hbm if hbm else 0.0,
            "step_s_model": cost["step_s"], "dominant": cost["dominant"],
            # per-shard HBM + tp all-reduce wire bytes (tp-scaling story)
            "per_shard_hbm_bytes": cost["hbm_bytes"],
            "allreduce_wire_bytes": cost["wire_bytes"]}


# The --mesh host8 serving path, measured in a subprocess: the forced
# 8-device host platform must be set before jax initializes, and this
# process keeps its single device for the main bench. The harness itself
# is shared with tests/test_multidevice.py (repro.runtime.sharded_smoke).
_SHARDED_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.runtime import sharded_smoke

preset = json.loads(os.environ["QS_BENCH_PRESET"])
ref, sharded = sharded_smoke.run_sharded_vs_single(preset)
print("QS_SHARDED " + json.dumps(sharded_smoke.sharded_counters(ref, sharded)))
"""


def _sharded_counters(preset) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    tail = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + tail if tail else "")
    env["QS_BENCH_PRESET"] = json.dumps(preset)
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("QS_SHARDED "):
            return json.loads(line[len("QS_SHARDED "):])
    raise RuntimeError(
        f"sharded bench subprocess produced no counters:\n"
        f"{out.stdout[-1000:]}\n{out.stderr[-2000:]}")


def run(fast: bool = True):
    p = bench_preset(fast)
    cfg = smoke_config(p["arch"])
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql, policy = _mixed_policy(cfg)
    data = SyntheticLM(cfg)
    reqs = build_requests(data, p["n_requests"], p["prompt_len"], p["gen"],
                          stagger=True, arrive_every=p["arrive_every"])
    cache_len = p["prompt_len"] + p["gen"]

    # the packed engine serves with the fused int8 decode-attention kernel
    # on the hot path (interpret mode — the TPU program, executed
    # step-by-step): the token-identity gate below therefore proves the
    # kernel against the dequant reference over a full staggered workload.
    # The force scope wraps build AND runs (route resolves at trace time).
    from repro.runtime import dispatch, kv_cache as qkv

    with dispatch.force_decode_attn("fused-interpret"):
        sess = QuantizedSession(cfg, params, policy, ctx, mode="packed",
                                kv_quant="int8")
        packed_eng = DecodeEngine(
            sess.params, cfg, None, ctx, NO_AXES,
            EngineConfig(slots=p["slots"], cache_len=cache_len,
                         kv_quant="int8", bucket_prompts=True),
            adapter=sess)
        bits = lm.bits_from_policy(cfg, policy, ql)
        ref_eng = DecodeEngine(
            params, cfg, bits, ctx, NO_AXES,
            EngineConfig(slots=p["slots"], cache_len=cache_len,
                         kv_quant="fake"))

        results = {}
        for name, eng in (("packed", packed_eng), ("reference", ref_eng)):
            eng.submit_all(reqs)    # warmup pass: pay the jit compiles
            eng.run()
            eng.reset()
            eng.submit_all(reqs)
            completions = eng.run()
            results[name] = {
                "stats": eng.stats.as_dict(),
                "tokens": {r.rid: completions[r.rid].tokens for r in reqs},
            }

    # measured per-step decode-attention cache traffic: the fused route
    # scans the whole ring buffer every step, so one step's traffic is the
    # resident inventory — codes + scales + pos over every layer cache
    measured_kv = qkv.tree_cache_bytes(packed_eng.state)
    model_kv = roofline.decode_step_cost(
        cfg, p["slots"], cache_tokens=cache_len, kv_bits=8.0,
        kv_attend="fused")["kv_hbm_bytes"]
    kv_ratio = model_kv / measured_kv if measured_kv else float("nan")

    identical = results["packed"]["tokens"] == results["reference"]["tokens"]
    info = summarize(sess)
    w_bits_total = policy.size_bytes(ql) * 8.0
    counters = {
        "fp": _step_counters(cfg, p["slots"], cache_len, kv_bits=16.0,
                             avg_weight_bits=16.0),
        "quantized": _step_counters(cfg, p["slots"], cache_len, kv_bits=8.0,
                                    w_bits_total=w_bits_total),
        # int8 stored but fp-attended: what the dequant fallback pays per
        # step — the honesty gap the fused decode-attention kernel closes
        "quantized_fp_attended": _step_counters(
            cfg, p["slots"], cache_len, kv_bits=8.0,
            w_bits_total=w_bits_total, kv_attend="dequant"),
        # per-shard view of the same quantized step under 4-way tp: HBM
        # per chip and the megatron all-reduce bytes the tp split pays
        "quantized_tp4": _step_counters(cfg, p["slots"], cache_len,
                                        kv_bits=8.0,
                                        w_bits_total=w_bits_total,
                                        tp_size=4),
    }
    sharded = _sharded_counters(p)
    shared_prefix = _shared_prefix_counters(cfg, params, ctx, policy, fast)
    spec = _spec_counters(cfg, params, ctx, policy, fast)
    elastic_m = _elastic_counters(cfg, params, ctx, fast)
    pstats = results["packed"]["stats"]
    # pack-time quantization health: the demo policy packs from its own
    # init's trained-scale bank, so saturation stays near zero and the
    # engine's saturation watcher must never trip (alerts_fired == 0 is
    # gated — a baseline regression here means scales stopped covering
    # the served weights)
    from repro.obs import health as obs_health
    pack_health = obs_health.pack_summary(sess.pack_health)
    # measured-vs-modeled phase ratios from the packed engine's (warmed)
    # measured epoch — the roofline calibration loop, ungated in CI: the
    # ratios are host-dependent, their *presence and finiteness* is not
    from repro.obs import calibrate
    calib = calibrate.calibrate(
        cfg, pstats, slots=p["slots"], cache_tokens=cache_len,
        kv_bits=packed_eng.kv_bits, kv_attend=packed_eng.kv_attend,
        w_bits_total=w_bits_total)
    assert calib["finite"], \
        f"roofline calibration produced non-finite ratios: {calib['rows']}"
    out = {
        "preset": p,
        "token_identical": identical,
        # gated (deterministic)
        "decode_steps": pstats["decode_steps"],
        "tokens_generated": pstats["tokens_generated"],
        "prefill_compiles": pstats["prefill_compiles"],
        "packed_vs_policy": info["packed_vs_policy"],
        "packed_vs_fp32": 1.0 / info["compression_vs_fp32"],
        "decode_attn_route": pstats["decode_attn_route"],
        "decode_attn_hbm_bytes": int(measured_kv),
        "decode_attn_model_vs_measured": kv_ratio,
        "decode_attn_bytes_match": bool(abs(kv_ratio - 1.0) <= 0.05),
        "saturation_rate_max": pack_health["saturation_rate_max"],
        "alerts_fired": pstats["alerts_fired"],
        "scale_utilization_p50": pack_health["scale_utilization_p50"],
        # informational
        "packed_bytes": info["packed_bytes"],
        "scale_bytes": info["scale_bytes"],
        "policy_bytes": info["policy_bytes"],
        "fp32_bytes": info["fp32_bytes"],
        "avg_bits_w": info["avg_bits"][0],
        "avg_bits_a": info["avg_bits"][1],
        "reference_prefill_compiles":
            results["reference"]["stats"]["prefill_compiles"],
        "step_counters": counters,
        "hbm_bytes_saved_per_step":
            counters["fp"]["step_hbm_bytes"]
            - counters["quantized"]["step_hbm_bytes"],
        "packed_tok_per_s": pstats["decode_tokens_per_s"],
        "reference_tok_per_s":
            results["reference"]["stats"]["decode_tokens_per_s"],
        # request-latency percentiles from the engine's metrics registry
        # (wall-clock: artifact trail only, never gated)
        "ttft_p50_ms": pstats.get("ttft_p50_ms", 0.0),
        "ttft_p95_ms": pstats.get("ttft_p95_ms", 0.0),
        "itl_p50_ms": pstats.get("itl_p50_ms", 0.0),
        "itl_p95_ms": pstats.get("itl_p95_ms", 0.0),
        "roofline_modeled_vs_measured": {
            r["phase"]: r["ratio"] for r in calib["rows"]},
    }
    out.update(sharded)
    out.update(shared_prefix)
    out.update(spec)
    out.update(elastic_m)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"  token_identical={identical} | packed {info['packed_bytes']} B "
          f"= x{info['packed_vs_policy']:.3f} of policy accounting, "
          f"{info['compression_vs_fp32']:.2f}x under fp32 | decode steps "
          f"{out['decode_steps']} | prefill shapes {out['prefill_compiles']} "
          f"(reference {out['reference_prefill_compiles']})")
    print(f"  roofline step bytes: fp {counters['fp']['step_hbm_bytes']:.2e}"
          f" -> quantized {counters['quantized']['step_hbm_bytes']:.2e} "
          f"(fp-attended int8: "
          f"{counters['quantized_fp_attended']['step_hbm_bytes']:.2e})")
    print(f"  decode-attn route {out['decode_attn_route']} | cache traffic "
          f"{out['decode_attn_hbm_bytes']} B/step measured, model x"
          f"{kv_ratio:.3f}")
    tp4 = counters["quantized_tp4"]
    print(f"  tp=4 per-shard HBM {tp4['per_shard_hbm_bytes']:.2e} B/step | "
          f"all-reduce {tp4['allreduce_wire_bytes']:.2e} B/step | sharded "
          f"serve: tokens_identical={sharded['sharded_token_identical']} "
          f"per-shard x{sharded['sharded_per_shard_vs_policy']:.3f} of "
          f"budget on tp={sharded['sharded_tp_size']}")
    print(f"  shared-prefix preset: tokens_identical="
          f"{shared_prefix['shared_prefix_token_identical']} | paged saved "
          f"{shared_prefix['prefill_flops_saved']:.2e} prefill FLOPs "
          f"({shared_prefix['shared_prefix_prefill_tokens']} prefill tokens "
          f"vs ring {shared_prefix['shared_prefix_ring_prefill_tokens']}) | "
          f"{shared_prefix['shared_prefix_prefill_compiles']} compile "
          f"shape(s)")
    print(f"  self-speculative (k={spec_preset(fast)['speculate']}, int"
          f"{spec_preset(fast)['draft_bits']} draft): tokens_identical="
          f"{spec['spec_token_identical']} | accept rate "
          f"{spec['spec_accept_rate']:.2f} over {spec['spec_rounds']} "
          f"rounds | {spec['spec_tokens_per_s']:.1f} tok/s vs single "
          f"{spec['single_policy_tokens_per_s']:.1f} = x"
          f"{spec['spec_speedup_vs_single']:.2f}")
    print(f"  elastic ramp ({len(elastic_preset(fast)['budgets'])}-variant "
          f"bank): {elastic_m['elastic_swaps']} swap(s), "
          f"{elastic_m['elastic_downshifts']} down | tokens_identical="
          f"{elastic_m['elastic_token_identical']} | "
          f"{elastic_m['elastic_ilp_solves']} re-solves, max "
          f"{elastic_m['elastic_ilp_solve_ms_max']:.1f} ms | held "
          f"{elastic_m['elastic_swap_holds']} round(s) | pool deferrals "
          f"{elastic_m['elastic_admissions_deferred']} (flat after swap: "
          f"{elastic_m['elastic_deferred_flat_after_swap']}) | final "
          f"{elastic_m['elastic_final_variant']}")
    print(f"  pack health: saturation_rate_max="
          f"{pack_health['saturation_rate_max']:.4f} "
          f"scale_utilization_p50="
          f"{pack_health['scale_utilization_p50']:.3f} over "
          f"{pack_health['sites']} sites | alerts_fired="
          f"{out['alerts_fired']}")
    print(f"  -> {BENCH_PATH}")
    assert shared_prefix["shared_prefix_token_identical"], \
        "paged layout diverged from the ring layout on a shared prefix"
    assert shared_prefix["prefill_flops_saved"] > 0, \
        "shared-prefix preset saved no prefill FLOPs (prefix reuse broken)"
    assert shared_prefix["shared_prefix_prefill_compiles"] == 1, \
        "paged chunked-append prefill compiled more than one shape"
    assert identical, "packed runtime diverged from the fake-quant reference"
    assert spec["spec_token_identical"], \
        "speculative decode diverged from the single-policy engine"
    assert spec["spec_speedup_gt_1"], \
        (f"speculative decode did not beat single-policy decode "
         f"(x{spec['spec_speedup_vs_single']:.2f}, accept rate "
         f"{spec['spec_accept_rate']:.2f})")
    assert abs(info["packed_vs_policy"] - 1.0) <= 0.05, \
        "packed HBM bytes off the policy accounting by more than 5%"
    assert sharded["sharded_token_identical"], \
        "sharded session diverged from the single-device session"
    assert sharded["sharded_per_shard_vs_policy"] <= 1.05, \
        "per-shard packed bytes exceed policy.size_bytes/tp beyond padding"
    assert out["decode_attn_route"] == "fused-interpret", \
        "packed engine did not run the fused decode-attention route"
    assert out["decode_attn_bytes_match"], \
        (f"decode_step_cost kv bytes off the measured cache inventory by "
         f"more than 5% (x{kv_ratio:.3f})")
    assert elastic_m["elastic_downshifts"] >= 1, \
        "elastic ramp triggered no downshift swap"
    assert elastic_m["elastic_token_identical"], \
        "elastic completion diverged from its variant's single-policy run"
    assert elastic_m["elastic_deferred_flat_after_swap"], \
        "pool-pressure deferrals kept growing after the downshift swap"
    assert elastic_m["elastic_repacks_after_build"] == 0, \
        "policy hot-swap repacked weights after engine build"
    assert elastic_m["elastic_ilp_solve_ms_max"] < 50.0, \
        (f"admission-time ILP re-solve took "
         f"{elastic_m['elastic_ilp_solve_ms_max']:.1f} ms (>= 50 ms: the "
         "paper's ~0.06 s one-shot search claim is load-bearing here)")
    assert out["alerts_fired"] == 0, \
        (f"{out['alerts_fired']} monitor alert(s) fired on the demo preset "
         f"(saturation_rate_max={out['saturation_rate_max']:.4f}): "
         "the signal plane must stay quiet on a healthy workload")
    return out


if __name__ == "__main__":
    run()
