"""Kernel-level report: correctness sweep + static VMEM budget check.

Wall-clock of interpret=True is meaningless (Python emulation), so the
kernel benchmark reports what CAN be verified off-TPU: numerical match vs
the oracle over a shape sweep, and the per-block VMEM working set vs the
~16 MiB/core budget for the production block shapes.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops, ref
from repro.kernels import fake_quant as fq
from repro.kernels import quant_matmul as qmm
from repro.kernels import rwkv_scan as rs

VMEM_BYTES = 16 * 2 ** 20


def run(fast: bool = True):
    rng = np.random.default_rng(0)
    rows = []

    # --- VMEM budgets (static) ---------------------------------------------
    bm, bn = fq.DEFAULT_BLOCK
    rows.append({"kernel": "fake_quant", "block": f"{bm}x{bn}",
                 "vmem_bytes": 3 * bm * bn * 4,
                 "fits": 3 * bm * bn * 4 < VMEM_BYTES, "max_err": 0.0})
    m, n, k = qmm.DEFAULT_BLOCKS
    v = (m * k + k * n) * 1 + m * n * 4 + m * n * 4
    rows.append({"kernel": "quant_matmul", "block": f"{m}x{n}x{k}",
                 "vmem_bytes": v, "fits": v < VMEM_BYTES, "max_err": 0.0})
    ch, hd = rs.DEFAULT_CHUNK, 64
    v = 4 * ch * hd * 4 + hd * hd * 4 + ch * ch * hd * 4
    rows.append({"kernel": "rwkv_scan", "block": f"chunk{ch} hd{hd}",
                 "vmem_bytes": v, "fits": v < VMEM_BYTES, "max_err": 0.0})
    # flash attention: q/k/v tiles + p tile + (m, l, acc) scratch, hd=128
    qb, kvb, fhd = 512, 512, 128
    v = (qb + 2 * kvb) * fhd * 4 + qb * kvb * 4 + 2 * qb * 4 + qb * fhd * 4
    rows.append({"kernel": "flash_attention", "block": f"{qb}x{kvb} hd{fhd}",
                 "vmem_bytes": v, "fits": v < VMEM_BYTES, "max_err": 0.0})

    # --- correctness sweep ---------------------------------------------------
    errs = []
    for shape in [(128, 256), (33, 513)]:
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        out = ops.fake_quant(x, jnp.float32(0.05), -8.0, 7.0)
        e = float(jnp.max(jnp.abs(out - ref.fake_quant_ref(
            x, jnp.float32(0.05), -8, 7))))
        errs.append(("fake_quant", shape, e))
    for mkn in [(64, 256, 64), (130, 514, 66)]:
        M, K, N = mkn
        xq = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
        wq = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
        out = ops.quant_matmul(xq, wq, jnp.float32(0.1), jnp.float32(0.2),
                               blocks=(64, 64, 128))
        e = float(jnp.max(jnp.abs(out - ref.quant_matmul_ref(
            xq, wq, jnp.float32(0.1), jnp.float32(0.2)))))
        errs.append(("quant_matmul", mkn, e))
    B, S, H, hd = 2, 64, 2, 16
    r, k2, v2 = (jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
                 for _ in range(3))
    lw = -jnp.asarray(rng.uniform(0.05, 2, (B, S, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32) * 0.3
    y = ops.wkv(r, k2, v2, lw, u, chunk=16)
    e = float(jnp.max(jnp.abs(y - ref.wkv_ref(r, k2, v2, lw, u))))
    errs.append(("rwkv_scan", (B, S, H, hd), e))
    # flash fwd vs direct attention
    from repro.models import attention as attn
    B, S, H, KV, hd = 1, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    qr = q.reshape(B, S, KV, H // KV, hd) * hd ** -0.5
    fo, _ = ops.flash_fwd(qr, kk, vv, causal=True, q_block=64, kv_block=64)
    pos = jnp.arange(S)
    do = attn.direct_attention(q, kk, vv, pos, pos, causal=True, window=None)
    e = float(jnp.max(jnp.abs(fo.reshape(B, S, H, hd) - do)))
    errs.append(("flash_attention", (B, S, H, hd), e))

    for kname, shape, e in errs:
        rows.append({"kernel": kname, "block": f"sweep{shape}",
                     "vmem_bytes": "", "fits": "", "max_err": e})
        print(f"kernel_report {kname:14s} {str(shape):18s} max_err={e:.2e}")
    common.write_csv("kernel_report.csv", rows)
    return {"max_err": max(e for _, _, e in errs)}


if __name__ == "__main__":
    run()
