"""Roofline calibration harness: measured engine phases vs the step model.

Serves a warmed-up staggered workload through the continuous-batching
engine, then replays the measured per-phase timings (``perf_counter``-
fenced by the engine's instrumented call sites) against the
``dist.roofline.decode_step_cost`` / ``suggest_prefill_chunk`` model the
scheduler budgeted with (``repro.obs.calibrate``). Writes
``benchmarks/out/BENCH_roofline_calibration.json``:

* the measured-vs-modeled row per phase (decode step, prefill token,
  TTFT) — printed as the same table ``serve --smoke`` emits;
* the **device-table stanza**: the effective HBM bandwidth / FLOP rate
  this host actually delivered, in ``ChipSpec`` field names, ready for
  ``dist.roofline.chip_from_table``;
* the engine stats snapshot the rows were derived from.

Nothing here is regression-gated: the ratios measure the *host* (a CPU
interpreter sits orders of magnitude off a TPU v5e envelope by design).
The run itself asserts only that every ratio is finite and positive, and
that a ``chip_from_table`` round-trip accepts the stanza.

Usage: PYTHONPATH=src python -m benchmarks.run --only roofline_calibration
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import OUT_DIR
from repro.configs import smoke_config
from repro.core.policy import MPQPolicy
from repro.data import SyntheticLM
from repro.dist import roofline
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.serve import build_requests
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.obs import calibrate

BENCH_PATH = os.path.join(OUT_DIR, "BENCH_roofline_calibration.json")


def bench_preset(fast: bool = True):
    n_req = 6 if fast else 16
    return dict(arch="limpq-demo", slots=4, prompt_len=16, gen=8,
                n_requests=n_req, uniform_bits=4)


def run(fast: bool = True):
    p = bench_preset(fast)
    cfg = smoke_config(p["arch"])
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    policy = MPQPolicy.uniform(ql, p["uniform_bits"])
    bits = lm.bits_from_policy(cfg, policy, ql)
    data = SyntheticLM(cfg)
    reqs = build_requests(data, p["n_requests"], p["prompt_len"], p["gen"],
                          stagger=True)
    cache_len = p["prompt_len"] + p["gen"]

    eng = DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                       EngineConfig(slots=p["slots"], cache_len=cache_len))
    # warmup epoch: compile time in the timers would calibrate the jit
    # cache, not the device — reset() starts a fresh measured epoch
    eng.submit_all(reqs)
    eng.run()
    eng.reset()
    eng.submit_all(reqs)
    eng.run()
    stats = eng.stats.as_dict()

    report = calibrate.calibrate(
        cfg, stats, slots=p["slots"], cache_tokens=cache_len,
        kv_bits=eng.kv_bits, kv_attend=eng.kv_attend,
        w_bits_total=getattr(eng.adapter, "w_bits_total", None),
        chip=eng.ecfg.chip)
    print(calibrate.render_table(report["rows"]))
    table = report["device_table"]
    print(f"  measured device table: hbm_bytes_s={table['hbm_bytes_s']:.3e} "
          f"peak_flops={table['peak_flops']:.3e} ({table['name']})")
    assert report["finite"], \
        f"calibration produced non-finite/non-positive ratios: " \
        f"{report['rows']}"
    # the stanza must round-trip into a usable ChipSpec
    measured_chip = roofline.chip_from_table(table)
    assert measured_chip.hbm_bytes_s > 0 and measured_chip.peak_flops > 0

    out = {
        "preset": p,
        "chip": report["chip"],
        "rows": report["rows"],
        "device_table": table,
        "finite": report["finite"],
        "stats": stats,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"  -> {BENCH_PATH}")
    return out


if __name__ == "__main__":
    run()
