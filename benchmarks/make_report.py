"""Generate the EXPERIMENTS.md §Dry-run + §Roofline markdown tables from
the dry-run artifacts. Run after the sweeps:

  PYTHONPATH=src python -m benchmarks.make_report > experiments/report.md
"""
from __future__ import annotations

import glob
import json
import os


def load(dirpath):
    out = {}
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        d = json.load(open(p))
        key = (d["arch"], d["shape"], d["mesh"],
               d.get("step_kind", ""))
        out[key] = d
    return out


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_row(d, opt=None):
    r = d["roofline"]
    m = d["memory"]
    h = d["hlo_analysis"]
    dom = r["dominant"]
    cells = [
        d["arch"], d["shape"], d["mesh"],
        f"{r['compute_s']*1e3:.1f}", f"{r['memory_s']*1e3:.1f}",
        f"{r['collective_s']*1e3:.1f}", f"**{dom}**",
        f"{r['model_flops_total']:.2e}", f"{r['useful_ratio']:.2f}",
        f"{r['mfu_at_roofline']:.4f}", fmt_bytes(m["temp_bytes"]),
        f"{h['n_collectives']}",
    ]
    return "| " + " | ".join(str(c) for c in cells) + " |"


def serve_rows(path="benchmarks/out/BENCH_serve.json"):
    """Engine-throughput row protocol: one row per scheduling policy from
    the serving benchmark artifact (BENCH_serve.json), deterministic
    scheduler counters first, wall-clock tok/s last (machine-dependent)."""
    if not os.path.exists(path):
        return
    d = json.load(open(path))
    p = d["preset"]
    print("## Serving engine throughput "
          f"({p['arch']}, {p['n_requests']} reqs, slots={p['slots']}, "
          f"prefill chunk {d['prefill_chunk']})\n")
    print("| policy | decode steps | slot-steps | tokens | decode tok/s "
          "| total tok/s |")
    print("|" + "---|" * 6)
    for policy, steps, slots_key in (
            ("continuous", "continuous_decode_steps", "continuous_slot_steps"),
            ("fixed", "fixed_decode_steps", "fixed_padded_slot_steps")):
        print(f"| {policy} | {d[steps]} | {d[slots_key]} | "
              f"{d['tokens_generated']} | {d[f'{policy}_tok_per_s']:.0f} | "
              f"{d[f'{policy}_total_tok_per_s']:.0f} |")
    ident = "yes" if d.get("token_identical") else "**NO**"
    print(f"\ntoken-identical across policies: {ident}\n")


def quant_serve_rows(path="benchmarks/out/BENCH_quant_serve.json"):
    """Quantized-runtime row protocol: packed-vs-policy HBM accounting,
    bucketed prefill compiles, and the bit-aware roofline step counters
    from BENCH_quant_serve.json."""
    if not os.path.exists(path):
        return
    d = json.load(open(path))
    p = d["preset"]
    print(f"## Quantized serving runtime ({p['arch']}, "
          f"{p['n_requests']} reqs, slots={p['slots']})\n")
    print("| metric | value |")
    print("|---|---|")
    ident = "yes" if d.get("token_identical") else "**NO**"
    rows = [
        ("token-identical vs fake-quant graph", ident),
        ("packed bytes / policy accounting", f"x{d['packed_vs_policy']:.3f}"),
        ("packed bytes / fp32", f"x{d['packed_vs_fp32']:.3f}"),
        ("avg searched bits (w / a)",
         f"{d['avg_bits_w']:.2f} / {d['avg_bits_a']:.2f}"),
        ("decode steps", d["decode_steps"]),
        ("prefill shapes compiled (bucketed)",
         f"{d['prefill_compiles']} vs {d['reference_prefill_compiles']} "
         "unbucketed"),
        ("roofline step HBM bytes (fp -> quantized)",
         f"{d['step_counters']['fp']['step_hbm_bytes']:.2e} -> "
         f"{d['step_counters']['quantized']['step_hbm_bytes']:.2e}"),
        ("packed tok/s (not gated)", f"{d['packed_tok_per_s']:.0f}"),
    ]
    for k, v in rows:
        print(f"| {k} | {v} |")
    print()


def main():
    base = load("experiments/dryrun_baseline") or load("experiments/dryrun")
    print("## Generated roofline tables\n")
    for mesh, label in (("16x16", "single-pod 256 chips"),
                        ("2x16x16", "multi-pod 512 chips")):
        rows = [d for k, d in sorted(base.items())
                if k[2] == mesh and d.get("status") == "ok"]
        if not rows:
            continue
        print(f"### {label} ({mesh})\n")
        print("| arch | shape | mesh | comp ms | mem ms | coll ms | dominant"
              " | MODEL_FLOPS | useful | MFU@roof | temp GiB | #coll |")
        print("|" + "---|" * 12)
        for d in rows:
            print(roofline_row(d))
        print()
    skips = [d for d in base.values() if d.get("status") == "skipped"]
    if skips:
        print("### Skipped cells (documented rules)\n")
        for d in sorted(skips, key=lambda x: (x["arch"], x["shape"])):
            print(f"- `{d['arch']}` x `{d['shape']}`: {d['reason']}")
        print()

    opt = load("experiments/dryrun_opt")
    if opt:
        print("### Optimized (beyond-paper) cells vs baseline\n")
        print("| arch | shape | term | baseline | optimized | delta |")
        print("|" + "---|" * 6)
        for k, o in sorted(opt.items()):
            if o.get("status") != "ok":
                continue
            b = base.get(k)
            if not b or b.get("status") != "ok":
                continue
            for term in ("compute_s", "memory_s", "collective_s"):
                bv = b["roofline"][term] * 1e3
                ov = o["roofline"][term] * 1e3
                delta = (ov - bv) / bv * 100 if bv else 0.0
                print(f"| {k[0]} | {k[1]} | {term[:-2]} | {bv:.1f} ms | "
                      f"{ov:.1f} ms | {delta:+.1f}% |")
            bt = b["memory"]["temp_bytes"] / 2**30
            ot = o["memory"]["temp_bytes"] / 2**30
            print(f"| {k[0]} | {k[1]} | temp | {bt:.1f} GiB | {ot:.1f} GiB |"
                  f" {(ot-bt)/bt*100 if bt else 0:+.1f}% |")

    serve_rows()
    quant_serve_rows()


if __name__ == "__main__":
    main()
