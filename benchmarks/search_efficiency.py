"""Paper §4.3: MPQ policy search efficiency.

Part 1 (indicator training) is a once-off QAT-speed cost — measured here
per step. Part 2 (the ILP) must stay sub-second even for the biggest
assigned arch: we time solve_dp/solve_lagrangian on the REAL QLayer tables
of every assigned architecture (granite-20b: 312 QLayers x 25 combos) and
report the paper's z-device amortization: total(z) = T_train + z * T_ilp.
(Paper: ResNet18 0.06s / ResNet50 0.35s on CPU; AutoQ ~1000 GPU-hours.)
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import importance as imp
from repro.core import search
from repro.models import lm


def run(fast: bool = True):
    rows = []

    # Part 2: ILP time on every real arch (synthetic indicator values —
    # solver time does not depend on the values)
    rng = np.random.default_rng(0)
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        ql = lm.enumerate_qlayers(cfg)
        ind = {q.name: {"w": np.sort(rng.uniform(0.01, 0.2, cfg.n_bits))[::-1],
                        "a": np.sort(rng.uniform(0.01, 0.2, cfg.n_bits))[::-1]}
               for q in ql}
        budget = search.bitops_budget_for_uniform(ql, 4)
        res_dp = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                                      bitops_budget=budget, method="dp")
        res_lg = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                                      bitops_budget=budget,
                                      method="lagrangian")
        rows.append({"arch": arch, "n_qlayers": len(ql),
                     "n_choices": cfg.n_bits ** 2,
                     "ilp_dp_s": round(res_dp.elapsed_s, 4),
                     "ilp_lagrangian_s": round(res_lg.elapsed_s, 4),
                     "dp_optimal": res_dp.optimal})
        print(f"search_efficiency {arch:24s} L={len(ql):4d} "
              f"dp={res_dp.elapsed_s:.3f}s lagr={res_lg.elapsed_s:.4f}s")

    # Part 1: indicator-training step cost at demo scale
    cfg, params, ctx, batches = common.demo_setup(fast, n_batches=4)
    t0 = time.perf_counter()
    imp.train_importance(params, cfg, ctx, batches[:1], lr=0.01)
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    imp.train_importance(params, cfg, ctx, batches[1:4], lr=0.01)
    t_step = (time.perf_counter() - t0) / 3
    max_ilp = max(r["ilp_dp_s"] for r in rows)
    print(f"search_efficiency: importance step {t_step:.2f}s "
          f"(compile {t_compile:.1f}s); z-device total = T_train + z * "
          f"{max_ilp:.3f}s  — search itself needs NO training data")
    rows.append({"arch": "importance_step_s", "n_qlayers": "",
                 "n_choices": "", "ilp_dp_s": round(t_step, 3),
                 "ilp_lagrangian_s": "", "dp_optimal": ""})
    common.write_csv("search_efficiency.csv", rows)
    return {"max_ilp_dp_s": max_ilp}


if __name__ == "__main__":
    run()
