"""Aggregate experiments/dryrun/*.json into the §Roofline table.

Reads the dry-run artifacts (no compilation here) and emits the per-cell
three-term roofline with dominant bottleneck, MODEL_FLOPS/HLO ratio, and
the one-line what-would-move-it-down note per dominant term.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

NOTES = {
    "compute": "raise per-chip math throughput: larger fused matmul tiles, "
               "bf16 everywhere, avoid remat of dots",
    "memory": "cut HBM traffic: recompute attention/wkv residuals in "
              "backward (custom-vjp flash), bf16 residuals, fuse fake-quant "
              "chains",
    "collective": "reshard: fewer all-gathers (seq-parallel boundaries), "
                  "overlap ppermute matmuls, int8-compress cross-pod grads",
}


def run(fast: bool = True, out_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        m = rec["memory"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "step": rec["step_kind"],
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "useful_ratio": round(r["useful_ratio"], 3),
            "mfu_at_roofline": round(r["mfu_at_roofline"], 4),
            "temp_GiB": round(m["temp_bytes"] / 2 ** 30, 2),
            "fits_16G": m["temp_bytes"] + m["output_bytes"] < 16 * 2 ** 30,
            "note": NOTES[r["dominant"]],
        })
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    common.write_csv("roofline.csv", rows)
    if rows:
        hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'comp':>8s} "
               f"{'mem':>9s} {'coll':>8s} {'dom':10s} {'useful':>6s} "
               f"{'mfu':>6s} {'tmpGiB':>7s}")
        print(hdr)
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                  f"{r['compute_ms']:8.1f} {r['memory_ms']:9.1f} "
                  f"{r['collective_ms']:8.1f} {r['dominant']:10s} "
                  f"{r['useful_ratio']:6.3f} {r['mfu_at_roofline']:6.4f} "
                  f"{r['temp_GiB']:7.2f}")
    else:
        print("roofline_report: no dry-run artifacts found "
              "(run python -m repro.launch.dryrun first)")
    return {"n_cells": len(rows)}


if __name__ == "__main__":
    run()
