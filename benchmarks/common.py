"""Shared benchmark scaffolding: the demo model, data, CSV output."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import optim, training
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def demo_cfg(fast: bool = True):
    cfg = get_config("limpq-demo")
    if fast:
        cfg = cfg.scaled(n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=512, vocab=512)
    return cfg


def demo_setup(fast: bool = True, seed: int = 0, n_batches: int = 24,
               batch: int = 4, seq: int = 64):
    cfg = demo_cfg(fast)
    rng = jax.random.PRNGKey(seed)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    data = SyntheticLM(cfg)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(s, batch, seq).items()}
               for s in range(n_batches)]
    return cfg, params, ctx, batches


def finetune_and_eval(cfg, params, ctx, bits, train_batches, eval_batches,
                      lr=3e-3, label=""):
    opt = optim.adamw(lr, clip_norm=1.0)
    step = jax.jit(training.make_train_step(cfg, ctx, opt, bits, NO_AXES,
                                            remat=False))
    p, s = params, opt.init(params)
    for b in train_batches:
        p, s, _ = step(p, s, b)
    ev = training.evaluate(p, cfg, ctx, bits, eval_batches)
    return ev["ce"], p


def eval_no_finetune(cfg, params, ctx, bits, eval_batches):
    """Immediate CE under a policy — at micro scale the finetune can wash
    out policy differences; the direct quantization-noise CE is the
    cleaner ordering signal."""
    return training.evaluate(params, cfg, ctx, bits, eval_batches)["ce"]


def spearman(a, b) -> float:
    """Spearman rank correlation (argsort-of-argsort ranks, no tie split)."""
    import numpy as np
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    return float((ra * rb).sum() /
                 (np.sqrt((ra ** 2).sum() * (rb ** 2).sum()) + 1e-12))


def write_csv(name: str, rows: List[Dict]):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    if not rows:
        return path
    fields: List[str] = []
    for r in rows:                      # union, first-seen order
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    print(f"  -> {path}")
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
