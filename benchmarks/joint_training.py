"""Paper §3.4 / Fig. 3: one-shot joint indicator training.

Reports (a) the per-layer per-bit indicator table after one joint run,
(b) the monotonicity rate s(b) decreasing in b, and (c) the paper's
freeze-backbone finding: indicators from frozen-backbone training rank
layers the same as full-network training.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import importance as imp
from repro.models import lm


def _rank_corr(ind_a, ind_b, names, bit_idx=0):
    a = np.asarray([ind_a[n]["w"][bit_idx] for n in names])
    b = np.asarray([ind_b[n]["w"][bit_idx] for n in names])
    return common.spearman(a, b)


def run(fast: bool = True):
    cfg, params, ctx, batches = common.demo_setup(fast)
    ql = lm.enumerate_qlayers(cfg)
    names = [q.name for q in ql]
    train_b = batches[:10]

    with common.Timer() as t_frozen:
        p_frozen, hist = imp.train_importance(params, cfg, ctx, train_b,
                                              lr=0.02, freeze_backbone=True)
    ind_frozen = imp.extract_indicators(p_frozen, cfg, ql)

    with common.Timer() as t_full:
        p_full, _ = imp.train_importance(params, cfg, ctx, train_b,
                                         lr=0.02, freeze_backbone=False)
    ind_full = imp.extract_indicators(p_full, cfg, ql)

    mono = np.mean([np.all(np.diff(ind_frozen[n]["w"]) < 0) for n in names])
    rho = _rank_corr(ind_frozen, ind_full, names)
    loss0 = float(np.mean(hist[0]["loss_uniform"]))
    loss1 = float(np.mean(hist[-1]["loss_uniform"]))

    rows = []
    for n in names:
        rows.append({
            "layer": n,
            **{f"s_w@{b}b": round(float(v), 5)
               for b, v in zip(cfg.bits, ind_frozen[n]["w"])},
            **{f"s_a@{b}b": round(float(v), 5)
               for b, v in zip(cfg.bits, ind_frozen[n]["a"])},
        })
    common.write_csv("joint_training.csv", rows)
    print(f"joint_training: monotonic(s decreasing in bits) = {mono:.2f}, "
          f"frozen-vs-full rank corr = {rho:.3f}, "
          f"loss {loss0:.3f} -> {loss1:.3f}, "
          f"{t_frozen.dt:.1f}s frozen vs {t_full.dt:.1f}s full")
    return {"monotonic_frac": float(mono), "frozen_full_rank_corr": rho}


if __name__ == "__main__":
    run()
