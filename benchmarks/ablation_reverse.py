"""Paper Table 6: reversed bit-width assignment ablation ("Ours-R").

Give big-indicator (sensitive) layers FEWER bits instead of more, same
BitOps budget, identical finetune. The CE gap validates that the indicator
correlation direction is what drives the win.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import importance as imp
from repro.core import search
from repro.models import lm


def run(fast: bool = True):
    cfg, params, ctx, batches = common.demo_setup(fast, n_batches=30)
    ql = lm.enumerate_qlayers(cfg)
    train_b, eval_b = batches[:12], batches[24:]
    params, _ = imp.train_importance(params, cfg, ctx, train_b[:8], lr=0.02)
    ind = imp.extract_indicators(params, cfg, ql)

    budget = search.bitops_budget_for_uniform(ql, 3)
    rows = []
    ces, ces0 = {}, {}
    for label, rev in (("ours", False), ("ours-R", True)):
        res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                                   bitops_budget=budget, reverse=rev)
        bits = lm.bits_from_policy(cfg, res.policy, ql)
        ces0[label] = common.eval_no_finetune(cfg, params, ctx, bits, eval_b)
        ce, _ = common.finetune_and_eval(cfg, params, ctx, bits, train_b,
                                         eval_b)
        ces[label] = ce
        rows.append({"method": label, "ce": round(ce, 4),
                     "ce_immediate": round(ces0[label], 4),
                     "avg_w": round(res.policy.avg_bits()[0], 2),
                     "avg_a": round(res.policy.avg_bits()[1], 2),
                     "bitops": f"{res.bitops:.3e}"})
        print(f"ablation_reverse {label}: ce={ce:.4f} "
              f"(immediate {ces0[label]:.4f}) "
              f"avg_bits={rows[-1]['avg_w']}w/{rows[-1]['avg_a']}a")
    gap = ces["ours-R"] - ces["ours"]
    gap0 = ces0["ours-R"] - ces0["ours"]
    print(f"ablation_reverse: reversed-minus-ours CE gap = {gap:+.4f} "
          f"finetuned / {gap0:+.4f} immediate "
          f"(paper: reversed is 6.59% top-1 worse)")
    rows.append({"method": "gap(R-ours)", "ce": round(gap, 4),
                 "ce_immediate": round(gap0, 4), "avg_w": "",
                 "avg_a": "", "bitops": ""})
    common.write_csv("ablation_reverse.csv", rows)
    return {"gap": gap}


if __name__ == "__main__":
    run()
