"""End-to-end training driver example: importance -> search -> QAT finetune
with checkpointing and restart, on a scaled-down qwen3-family model.

This is the production workflow in miniature; on a real pod the SAME code
runs with ``--arch qwen3-0.6b --steps 20000`` under
``repro.launch.train`` + the 16x16 mesh (see repro/launch/dryrun.py for
the compiled production step).

Run (about 5 min on CPU):
  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import optim, training
from repro.checkpoint import CheckpointManager, StepWatchdog
from repro.configs import smoke_config
from repro.core import importance as imp
from repro.core import search
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = smoke_config("qwen3-0.6b").scaled(name="qwen3-e2e")
    print(f"model: {cfg.name} ({cfg.n_layers}L d{cfg.d_model}) — "
          f"same family/code path as the full qwen3-0.6b config")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    print(f"params: {lm.param_count(params)/1e6:.2f} M")
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    data = SyntheticLM(cfg)

    # --- phase 1: indicators (short) ----------------------------------------
    print("phase 1: joint importance training")
    bt = [{k: jnp.asarray(v) for k, v in data.batch(s, 4, args.seq).items()}
          for s in range(6)]
    params, _ = imp.train_importance(params, cfg, ctx, bt, lr=0.01)
    ql = lm.enumerate_qlayers(cfg)
    ind = imp.extract_indicators(params, cfg, ql)

    # --- phase 2: search -------------------------------------------------------
    budget = search.bitops_budget_for_uniform(ql, 4)
    res = search.search_policy(ql, ind, cfg.bits, alpha=2.0,
                               bitops_budget=budget)
    print(f"phase 2: ILP {res.elapsed_s*1e3:.1f} ms, "
          f"avg bits {res.policy.avg_bits()}")
    policy_path = os.path.join(args.ckpt, "policy.json")
    os.makedirs(args.ckpt, exist_ok=True)
    res.policy.save(policy_path)

    # --- phase 3: QAT finetune with fault tolerance ---------------------------
    print(f"phase 3: QAT finetune {args.steps} steps "
          f"(ckpt every 50 to {args.ckpt})")
    bits = lm.bits_from_policy(cfg, res.policy, ql)
    opt = optim.adamw(optim.cosine_warmup(3e-3, 10, args.steps),
                      weight_decay=2.5e-5, clip_norm=1.0)
    step = jax.jit(training.make_train_step(cfg, ctx, opt, bits, NO_AXES,
                                            remat=False))
    mgr = CheckpointManager(args.ckpt, keep_n=2)
    wd = StepWatchdog()
    opt_state = opt.init(params)
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        params = mgr.restore(latest, params)
        start = latest + 1
        print(f"  resumed from step {latest} "
              f"(deterministic data pipeline skips to step {start})")
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(s, args.batch, args.seq).items()}
        t0 = time.time()
        params, opt_state, m = step(params, opt_state, batch)
        if wd.observe(time.time() - t0):
            print(f"  [watchdog] straggler at step {s}")
        if s % 25 == 0 or s == args.steps - 1:
            print(f"  step {s:4d} loss {float(m['loss']):.4f}")
        if (s + 1) % 50 == 0:
            mgr.save(s, params, meta={"arch": cfg.name})
    mgr.save(args.steps - 1, params, meta={"arch": cfg.name}, blocking=True)
    print(f"done; checkpoints: {mgr.all_steps()}, policy: {policy_path}")


if __name__ == "__main__":
    main()
