"""Quickstart: the paper's full pipeline in one script (~2 min on CPU).

  1. joint importance-indicator training (paper §3.4, n+1 passes/step)
  2. extract the learned per-bit indicators (the scale factors)
  3. one-time ILP search under a 3-bit-level BitOps budget (Eq. 3)
  4. QAT finetune with the searched policy
  5. compare against the uniform-3-bit baseline

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import optim, training
from repro.configs import get_config
from repro.core import importance as imp
from repro.core import search
from repro.core.policy import MPQPolicy
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext


def main():
    cfg = get_config("limpq-demo").scaled(n_layers=3, d_model=128,
                                          n_heads=4, n_kv_heads=2,
                                          d_ff=512, vocab=512)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    data = SyntheticLM(cfg)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(s, 4, 64).items()}
               for s in range(26)]

    # 1. joint indicator training -------------------------------------------
    print("1) joint importance training (n+1 passes per step)...")
    params, hist = imp.train_importance(params, cfg, ctx, batches[:8],
                                        lr=0.02, freeze_backbone=True)
    print(f"   uniform-pass losses step0={hist[0]['loss_uniform']}")
    print(f"                 last  ={hist[-1]['loss_uniform']}")

    # 2. extract indicators ----------------------------------------------------
    ql = lm.enumerate_qlayers(cfg)
    ind = imp.extract_indicators(params, cfg, ql)
    print("2) indicators (first 4 layers):")
    print(imp.indicators_summary({k: ind[k] for k in list(ind)[:4]},
                                 cfg.bits))

    # 3. one-time ILP search ---------------------------------------------------
    budget = search.bitops_budget_for_uniform(ql, 3)
    res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                               bitops_budget=budget)
    print(f"3) ILP search: {res.elapsed_s*1e3:.1f} ms, solver={res.solver}, "
          f"avg bits w={res.policy.avg_bits()[0]:.2f} "
          f"a={res.policy.avg_bits()[1]:.2f} "
          f"(budget respected: {res.bitops <= budget * 1.000001})")

    # 4/5. finetune: searched policy vs uniform baseline -----------------------
    def finetune(policy, label):
        bits = lm.bits_from_policy(cfg, policy, ql)
        opt = optim.adamw(3e-3, clip_norm=1.0)
        step = jax.jit(training.make_train_step(cfg, ctx, opt, bits,
                                                NO_AXES, remat=False))
        p, s = params, opt.init(params)
        for b in batches[8:20]:
            p, s, _ = step(p, s, b)
        ce = training.evaluate(p, cfg, ctx, bits, batches[20:])["ce"]
        print(f"   {label:16s} eval CE = {ce:.4f}")
        return ce

    print("4) QAT finetune under the searched policy vs uniform 3-bit:")
    ce_ours = finetune(res.policy, "ours (ILP)")
    ce_uni = finetune(MPQPolicy.uniform(ql, 3), "uniform 3-bit")
    print(f"5) delta (uniform - ours) = {ce_uni - ce_ours:+.4f} "
          f"(positive = searched policy wins)")


if __name__ == "__main__":
    main()
