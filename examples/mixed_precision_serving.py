"""Serving with a mixed-precision policy: size-constrained search, batched
prefill + decode, and the int8 deployment path (quant_matmul kernel).

Run: PYTHONPATH=src python examples/mixed_precision_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import importance as imp
from repro.core import search
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext


def main():
    cfg = get_config("limpq-demo")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    data = SyntheticLM(cfg)
    ql = lm.enumerate_qlayers(cfg)

    # indicators (short) + size-constrained search: Table-3 style 10x rate
    bt = [{k: jnp.asarray(v) for k, v in data.batch(s, 4, 64).items()}
          for s in range(4)]
    params, _ = imp.train_importance(params, cfg, ctx, bt, lr=0.01)
    ind = imp.extract_indicators(params, cfg, ql)
    size_budget = search.size_budget_for_rate(ql, 32, rate=10.0)
    res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                               size_budget_bytes=size_budget)
    fp_bytes = sum(q.w_params for q in ql) * 4
    print(f"policy: {fp_bytes/res.size_bytes:.1f}x weight compression, "
          f"avg bits {res.policy.avg_bits()}, search {res.elapsed_s*1e3:.0f} ms")
    bits = lm.bits_from_policy(cfg, res.policy, ql)

    # batched serving: prefill + greedy decode
    B, P, G = 4, 32, 16
    prompts = {k: jnp.asarray(v) for k, v in data.batch(0, B, P).items()}
    prefill = jax.jit(lambda p, b: lm.apply_prefill(p, cfg, b, bits, ctx,
                                                    NO_AXES,
                                                    prefill_cap=P + G))
    decode = jax.jit(lambda p, t, pos, st: lm.apply_decode(
        p, cfg, t, pos, st, bits, ctx, NO_AXES))

    t0 = time.time()
    logits, state = prefill(params, prompts)
    logits.block_until_ready()
    print(f"prefill B={B} S={P}: {(time.time()-t0)*1e3:.0f} ms")
    toks = [jnp.argmax(logits, -1)]
    t0 = time.time()
    for i in range(G - 1):
        lg, state = decode(params, toks[-1][:, None].astype(jnp.int32),
                           jnp.asarray(P + i, jnp.int32), state)
        toks.append(jnp.argmax(lg, -1))
    jax.block_until_ready(toks[-1])
    dt = time.time() - t0
    print(f"decode {G-1} steps: {dt*1e3:.0f} ms "
          f"({B*(G-1)/dt:.1f} tok/s on 1 CPU core)")
    print("sample:", jnp.stack(toks, 1)[0].tolist())

    # int8 deployment path equivalence on a real projection
    from repro.core.quantizer import bit_range
    from repro.kernels import ops
    node = params["body"]["0"]["mlp_wi"]
    w = node["w"][0]
    bidx = list(cfg.bits).index(res.policy.w_bits["L000.mlp_wi"]) \
        if "L000.mlp_wi" in res.policy.w_bits else 2
    s_w = node["s_w"][0][bidx]
    b = cfg.bits[bidx]
    qmin, qmax = bit_range(int(b), True)
    wq = jnp.clip(jnp.round(w / s_w), qmin, qmax).astype(jnp.int8)
    x = jax.random.normal(rng, (16, w.shape[0]))
    s_x = jnp.float32(0.04)
    xq = jnp.clip(jnp.round(x / s_x), qmin, qmax).astype(jnp.int8)
    fused = ops.quant_matmul(xq, wq, s_x, s_w, blocks=(16, 256, 256))
    ref = (xq.astype(jnp.float32) * s_x) @ (wq.astype(jnp.float32) * s_w)
    print(f"int8 kernel vs fake-quant graph at {b} bits: "
          f"max_err={float(jnp.max(jnp.abs(fused-ref))):.2e}")


if __name__ == "__main__":
    main()
