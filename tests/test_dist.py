"""Collectives layer: compression + error feedback; shard_map overlap
kernels validated in a multi-device subprocess (main process stays at 1
device so every other test sees an unmodified backend)."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import collectives as coll


def test_int8_roundtrip_error_bound(nprng):
    g = jnp.asarray(nprng.standard_normal((64, 32)) * 0.1, jnp.float32)
    q, s = coll.compress_int8(g)
    deq = coll.decompress_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) / 2 + 1e-7


def test_error_feedback_conserves_signal(nprng):
    """EF invariant: dequant(q) + new_residual == g + old_residual."""
    g = {"w": jnp.asarray(nprng.standard_normal((16, 16)), jnp.float32)}
    r0 = {"w": jnp.asarray(nprng.standard_normal((16, 16)) * 0.01,
                           jnp.float32)}
    q, s, r1 = coll.ef_compress_tree(g, r0)
    deq = coll.ef_decompress_tree(q, s)
    np.testing.assert_allclose(np.asarray(deq["w"] + r1["w"]),
                               np.asarray(g["w"] + r0["w"]), atol=1e-5)


def test_ef_residual_shrinks_bias(nprng):
    """Accumulated EF-compressed gradients converge to the true sum."""
    gs = [jnp.asarray(nprng.standard_normal((8, 8)), jnp.float32) * 0.1
          for _ in range(50)]
    res = None
    acc = jnp.zeros((8, 8))
    for g in gs:
        q, s, res = coll.ef_compress_tree(g, res)
        acc = acc + coll.ef_decompress_tree(q, s)
    true = sum(gs)
    # without EF the worst-case bias grows with steps; with EF it stays
    # bounded by one quantization step
    assert float(jnp.max(jnp.abs(acc - true))) < 0.05


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist import collectives as coll

mesh = jax.make_mesh((4,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
w = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
with mesh:
    out = coll.psum_matmul(x, w, mesh, "model")
np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=2e-5, atol=2e-5)

x2 = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
w2 = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
with mesh:
    out2 = coll.ag_matmul_rotating(x2, w2, mesh, "model")
np.testing.assert_allclose(np.asarray(out2), np.asarray(x2 @ w2), rtol=2e-5, atol=2e-5)
print("SUBPROC_OK")
"""


@pytest.mark.slow
def test_overlap_kernels_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]
