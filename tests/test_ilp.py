"""MCKP/ILP solver tests: exactness vs brute force, feasibility, duals."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ilp


def _rand_instance(rng, L, C):
    values = rng.uniform(0.1, 5.0, (L, C))
    costs = rng.uniform(0.5, 4.0, (L, C))
    # make higher-value choices cheaper on average (like bits: low bit =
    # high indicator value = low cost)
    order = np.argsort(costs, axis=1)
    costs = np.take_along_axis(costs, order, axis=1)
    values = np.take_along_axis(values, order[:, ::-1], axis=1)
    return values, costs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(2, 4),
       st.floats(0.3, 0.95))
def test_dp_matches_bruteforce(seed, L, C, budget_frac):
    rng = np.random.default_rng(seed)
    values, costs = _rand_instance(rng, L, C)
    lo = costs.min(axis=1).sum()
    hi = costs.max(axis=1).sum()
    budget = lo + budget_frac * (hi - lo)
    bf = ilp.solve_bruteforce(values, costs, budget)
    dp = ilp.solve_dp(values, costs, budget, bins=4096)
    assert dp.feasible
    assert dp.value <= bf.value + 1e-6 or \
        abs(dp.value - bf.value) / max(abs(bf.value), 1e-9) < 5e-3


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 30), st.integers(2, 6))
def test_lagrangian_feasible_and_bounded(seed, L, C):
    rng = np.random.default_rng(seed)
    values, costs = _rand_instance(rng, L, C)
    budget = costs.min(axis=1).sum() * 1.5
    sol = ilp.solve_lagrangian(values, costs, budget)
    assert sol.feasible
    assert sol.gap >= 0.0
    # dual bound sanity: gap small relative to objective scale
    assert sol.gap <= abs(sol.value) + 1.0


def test_infeasible_raises():
    values = np.ones((3, 2))
    costs = np.ones((3, 2)) * 10
    with pytest.raises(ilp.InfeasibleError):
        ilp.solve_dp(values, costs, budget=1.0)
    with pytest.raises(ilp.InfeasibleError):
        ilp.solve_bruteforce(values, costs, budget=1.0)


def test_dp_exact_on_integral_instance():
    # hand instance with known optimum
    values = np.asarray([[3.0, 1.0], [3.0, 1.0]])
    costs = np.asarray([[1.0, 2.0], [1.0, 2.0]])
    # budget 3: can afford one expensive (cost2) + one cheap (cost1)
    sol = ilp.solve_dp(values, costs, budget=3.0, bins=64)
    assert sol.value == 4.0 and sol.cost <= 3.0


def test_dual_budget():
    rng = np.random.default_rng(7)
    values, costs_a = _rand_instance(rng, 8, 4)
    costs_b = rng.uniform(0.5, 4.0, (8, 4))
    budget_a = costs_a.min(axis=1).sum() * 1.6
    budget_b = costs_b.min(axis=1).sum() * 1.6
    sol = ilp.solve_mckp_dual(values, costs_a, budget_a, costs_b, budget_b)
    rows = np.arange(8)
    assert costs_a[rows, sol.choice].sum() <= budget_a * (1 + 1e-9)
    assert costs_b[rows, sol.choice].sum() <= budget_b * (1 + 1e-9)


def test_search_time_scales():
    """Paper §4.3: search must be sub-second even at 100+ layers."""
    import time
    rng = np.random.default_rng(0)
    values, costs = _rand_instance(rng, 120, 25)    # 120 layers, 5x5 combos
    budget = costs.min(axis=1).sum() * 2
    t0 = time.perf_counter()
    sol = ilp.solve_dp(values, costs, budget)
    dt = time.perf_counter() - t0
    assert sol.feasible
    assert dt < 5.0


# ---------------------------------------------------------------------------
# SolveReport: the ILP audit trail
# ---------------------------------------------------------------------------
def _qlayers(L=4):
    from repro.core.qspec import QLayer
    return [QLayer(name=f"blk.{i}.w", segment="body", unit=i, path=("w",),
                   in_dim=32, out_dim=64, n_mats=1,
                   macs_per_token=32.0 * 64.0, w_params=32 * 64, kind="mlp")
            for i in range(L)]


def _searched(seed=0, L=4, bits=(2, 4, 8)):
    """A real solve over synthetic indicators (monotone in bit-width,
    like the trained scales) under a mid-range size budget."""
    from repro.core import qspec, search
    rng = np.random.default_rng(seed)
    ql = _qlayers(L)
    ind = {q.name: {"w": np.sort(rng.uniform(0.1, 1.0, len(bits)))[::-1],
                    "a": np.sort(rng.uniform(0.1, 1.0, len(bits)))[::-1]}
           for q in ql}
    budget = sum(qspec.model_bits(q, 4) for q in ql) / 8.0
    res = search.search_policy(ql, ind, list(bits),
                               size_budget_bytes=budget)
    return ql, res


def test_solve_report_round_trips_json(tmp_path):
    import json
    ql, res = _searched()
    report = res.report
    rt = ilp.SolveReport.from_json(json.loads(json.dumps(report.to_json())))
    assert rt == report
    # ...and through the file API (what checkpoint/--explain-policy use)
    path = str(tmp_path / "solve_report.json")
    report.save(path)
    assert ilp.SolveReport.load(path) == report
    # the searched policy carries the same audit in its meta
    assert ilp.SolveReport.from_json(res.policy.meta["solve_report"]) \
        == report


def test_solve_report_replay_reproduces_objective():
    from repro.core.policy import MPQPolicy
    ql, res = _searched()
    report = res.report
    # rebuilding a policy from the reported bits must validate cleanly
    pb = report.policy_bits()
    policy = MPQPolicy(pb["w_bits"], pb["a_bits"]).validate(ql, report.bits)
    # replaying its size accounting reproduces the constraint's used cost
    assert policy.size_bytes(ql) * 8 == \
        pytest.approx(report.constraint("size_bits")["used"])
    assert policy.size_bytes(ql) == pytest.approx(res.size_bytes)
    # per-layer objective decomposition sums to the reported objective,
    # and each term is the candidate grid entry the chosen bits select
    assert sum(report.importance) == pytest.approx(report.objective)
    n = len(report.bits)
    for L, name in enumerate(report.layers):
        c = (report.bits.index(report.chosen_w[L]) * n
             + report.bits.index(report.chosen_a[L]))
        assert report.candidate_values[L][c] == report.importance[L]


def test_solve_report_constraints_and_binding():
    ql, res = _searched()
    report = res.report
    size = report.constraint("size_bits")
    assert size["budget"] is not None
    assert size["slack"] == pytest.approx(size["budget"] - size["used"])
    assert size["slack"] >= 0.0                   # solution is feasible
    # bitops was tracked but not constrained in this solve
    ops = report.constraint("bitops")
    assert ops["budget"] is None and ops["used"] > 0.0
    # exactly one budgeted constraint is marked binding
    assert [c["name"] for c in report.constraints if c["binding"]] \
        == ["size_bits"]
    assert report.binding == "size_bits"
    with pytest.raises(KeyError):
        report.constraint("nope")


def test_solve_report_rejects_newer_schema():
    ql, res = _searched()
    obj = res.report.to_json()
    obj["schema"] = ilp.SOLVE_REPORT_SCHEMA + 1
    with pytest.raises(ValueError):
        ilp.SolveReport.from_json(obj)


def test_solve_report_render_table():
    ql, res = _searched()
    text = res.report.render_table()
    for q in ql:
        assert q.name in text
    assert "objective" in text and "<- binding" in text
    assert "(tracked, unconstrained)" in text     # the bitops row


def test_describe_policy_report_for_hand_policy():
    from repro.core.policy import MPQPolicy
    ql = _qlayers()
    bits = [2, 4, 8]
    policy = MPQPolicy.uniform(ql, 4)
    report = ilp.describe_policy_report(ql, policy, bits,
                                        meta={"arch": "toy"})
    assert report.meta["kind"] == "describe" and report.meta["arch"] == "toy"
    assert report.chosen_w == [4] * len(ql)
    # budgets are pinned to the used costs: slack exactly 0, size binding
    size = report.constraint("size_bits")
    assert size["slack"] == 0.0 and report.binding == "size_bits"
    assert size["used"] == pytest.approx(policy.size_bytes(ql) * 8)
    # importance is unknown post-hoc: the objective decomposes to zeros
    assert report.objective == 0.0
    assert report.render_table()
