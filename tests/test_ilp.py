"""MCKP/ILP solver tests: exactness vs brute force, feasibility, duals."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ilp


def _rand_instance(rng, L, C):
    values = rng.uniform(0.1, 5.0, (L, C))
    costs = rng.uniform(0.5, 4.0, (L, C))
    # make higher-value choices cheaper on average (like bits: low bit =
    # high indicator value = low cost)
    order = np.argsort(costs, axis=1)
    costs = np.take_along_axis(costs, order, axis=1)
    values = np.take_along_axis(values, order[:, ::-1], axis=1)
    return values, costs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(2, 4),
       st.floats(0.3, 0.95))
def test_dp_matches_bruteforce(seed, L, C, budget_frac):
    rng = np.random.default_rng(seed)
    values, costs = _rand_instance(rng, L, C)
    lo = costs.min(axis=1).sum()
    hi = costs.max(axis=1).sum()
    budget = lo + budget_frac * (hi - lo)
    bf = ilp.solve_bruteforce(values, costs, budget)
    dp = ilp.solve_dp(values, costs, budget, bins=4096)
    assert dp.feasible
    assert dp.value <= bf.value + 1e-6 or \
        abs(dp.value - bf.value) / max(abs(bf.value), 1e-9) < 5e-3


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 30), st.integers(2, 6))
def test_lagrangian_feasible_and_bounded(seed, L, C):
    rng = np.random.default_rng(seed)
    values, costs = _rand_instance(rng, L, C)
    budget = costs.min(axis=1).sum() * 1.5
    sol = ilp.solve_lagrangian(values, costs, budget)
    assert sol.feasible
    assert sol.gap >= 0.0
    # dual bound sanity: gap small relative to objective scale
    assert sol.gap <= abs(sol.value) + 1.0


def test_infeasible_raises():
    values = np.ones((3, 2))
    costs = np.ones((3, 2)) * 10
    with pytest.raises(ilp.InfeasibleError):
        ilp.solve_dp(values, costs, budget=1.0)
    with pytest.raises(ilp.InfeasibleError):
        ilp.solve_bruteforce(values, costs, budget=1.0)


def test_dp_exact_on_integral_instance():
    # hand instance with known optimum
    values = np.asarray([[3.0, 1.0], [3.0, 1.0]])
    costs = np.asarray([[1.0, 2.0], [1.0, 2.0]])
    # budget 3: can afford one expensive (cost2) + one cheap (cost1)
    sol = ilp.solve_dp(values, costs, budget=3.0, bins=64)
    assert sol.value == 4.0 and sol.cost <= 3.0


def test_dual_budget():
    rng = np.random.default_rng(7)
    values, costs_a = _rand_instance(rng, 8, 4)
    costs_b = rng.uniform(0.5, 4.0, (8, 4))
    budget_a = costs_a.min(axis=1).sum() * 1.6
    budget_b = costs_b.min(axis=1).sum() * 1.6
    sol = ilp.solve_mckp_dual(values, costs_a, budget_a, costs_b, budget_b)
    rows = np.arange(8)
    assert costs_a[rows, sol.choice].sum() <= budget_a * (1 + 1e-9)
    assert costs_b[rows, sol.choice].sum() <= budget_b * (1 + 1e-9)


def test_search_time_scales():
    """Paper §4.3: search must be sub-second even at 100+ layers."""
    import time
    rng = np.random.default_rng(0)
    values, costs = _rand_instance(rng, 120, 25)    # 120 layers, 5x5 combos
    budget = costs.min(axis=1).sum() * 2
    t0 = time.perf_counter()
    sol = ilp.solve_dp(values, costs, budget)
    dt = time.perf_counter() - t0
    assert sol.feasible
    assert dt < 5.0
