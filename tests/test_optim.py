"""Optimizer correctness vs handwritten numpy references."""
import jax.numpy as jnp
import numpy as np

from repro import optim


def test_adamw_matches_numpy_reference():
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.999, 1e-8, 0.01
    opt = optim.adamw(lr, b1, b2, eps, weight_decay=wd)
    p = {"w": jnp.asarray([[1.0, -2.0]]), "b": jnp.asarray([0.5])}
    state = opt.init(p)
    m = {k: np.zeros_like(np.asarray(v)) for k, v in p.items()}
    v_ = {k: np.zeros_like(np.asarray(v)) for k, v in p.items()}
    pn = {k: np.asarray(x).copy() for k, x in p.items()}

    rng = np.random.default_rng(0)
    for t in range(1, 6):
        g = {"w": rng.standard_normal((1, 2)).astype(np.float32),
             "b": rng.standard_normal((1,)).astype(np.float32)}
        updates, state = opt.update({k: jnp.asarray(x) for k, x in g.items()},
                                    state, p)
        p = optim.apply_updates(p, updates)
        for k in pn:
            m[k] = b1 * m[k] + (1 - b1) * g[k]
            v_[k] = b2 * v_[k] + (1 - b2) * g[k] ** 2
            u = -lr * (m[k] / (1 - b1 ** t)) / (np.sqrt(v_[k] / (1 - b2 ** t)) + eps)
            if pn[k].ndim >= 2:          # default wd mask: ndim >= 2
                u = u - lr * wd * pn[k]
            pn[k] = pn[k] + u
    for k in pn:
        np.testing.assert_allclose(np.asarray(p[k]), pn[k], rtol=2e-5,
                                   atol=1e-6)


def test_sgd_momentum():
    opt = optim.sgd(0.1, momentum=0.5)
    p = jnp.asarray([1.0])
    state = opt.init(p)
    g = jnp.asarray([1.0])
    u1, state = opt.update(g, state, p)       # mom=1 -> u=-0.1
    u2, state = opt.update(g, state, p)       # mom=1.5 -> u=-0.15
    np.testing.assert_allclose(np.asarray(u1), [-0.1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u2), [-0.15], rtol=1e-6)


def test_cosine_warmup_schedule():
    s = optim.cosine_warmup(1.0, warmup_steps=10, total_steps=110)
    np.testing.assert_allclose(float(s(0)), 0.0, atol=1e-7)
    np.testing.assert_allclose(float(s(5)), 0.5, rtol=1e-6)
    np.testing.assert_allclose(float(s(10)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(s(110)), 0.0, atol=1e-6)
    mid = float(s(60))
    assert 0.45 < mid < 0.55


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, gn = optim.clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-6)
    total = np.sqrt(float(clipped["a"][0]) ** 2 + float(clipped["b"][0]) ** 2)
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_masked_freeze():
    opt = optim.masked(optim.sgd(0.1, momentum=0.0),
                       lambda path, leaf: optim.path_str(path).endswith("s_w"))
    p = {"layer": {"w": jnp.asarray([1.0]), "s_w": jnp.asarray([1.0])}}
    g = {"layer": {"w": jnp.asarray([1.0]), "s_w": jnp.asarray([1.0])}}
    updates, _ = opt.update(g, opt.init(p), p)
    assert float(updates["layer"]["w"][0]) == 0.0
    assert float(updates["layer"]["s_w"][0]) != 0.0


def test_global_norm_empty_and_scalar():
    assert float(optim.global_norm({})) == 0.0
    np.testing.assert_allclose(float(optim.global_norm(jnp.asarray(3.0))), 3.0)
