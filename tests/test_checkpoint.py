"""Checkpoint manager: atomicity, keep-N, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, StepWatchdog


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "nested": {"b": jnp.arange(5.0)},
            "scalar": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    t = _tree(2.5)
    mgr.save(10, t, meta={"arch": "x"}, blocking=True)
    assert mgr.latest_step() == 10
    got = mgr.restore(10, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.meta(10)["arch"] == "x"


def test_async_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(1, _tree(1.0))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in range(5):
        mgr.save(s, _tree(float(s)), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_atomicity_tmp_never_visible(tmp_path):
    """A tmp dir (simulated torn write) is not a restorable step."""
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    os.makedirs(tmp_path / "step_0000000099")      # no meta.json => torn
    assert mgr.all_steps() == []
    mgr.save(100, _tree(), blocking=True)
    assert mgr.all_steps() == [100]


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, _tree(), blocking=True)
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros(5)},
           "scalar": jnp.asarray(0, jnp.int32)}
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(0, bad)


def test_elastic_restore_respects_sharding_fn(tmp_path):
    """Restore places arrays via the provided sharding fn (single-device
    sharding here; the dryrun mesh exercises the multi-device path)."""
    from jax.sharding import SingleDeviceSharding
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3.0)
    mgr.save(2, t, blocking=True)
    dev = jax.devices()[0]
    got = mgr.restore(2, t, sharding_fn=lambda path: SingleDeviceSharding(dev))
    assert got["a"].sharding == SingleDeviceSharding(dev)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_donated_buffer_safety(tmp_path):
    """save() snapshots to host before returning: mutating (rebinding) the
    source afterwards must not corrupt the checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.ones((8,))}
    mgr.save(5, t)                      # async
    t["w"] = t["w"] * 0                 # "donated"/reused
    mgr.wait()
    got = mgr.restore(5, {"w": jnp.zeros((8,))})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones(8))


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=16, threshold=2.0)
    for _ in range(10):
        assert not wd.observe(0.1)
    assert wd.observe(0.5)
    assert wd.flags == 1
