"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see exactly 1 device; only launch/dryrun.py requests 512 placeholders."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # optional dep: property-test library
    import hypothesis  # noqa: F401
except ImportError:                    # container has no hypothesis — use the
    import _hypothesis_stub            # deterministic stub (same API subset)
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def nprng():
    return np.random.default_rng(0)


def make_inputs(cfg, rng, B=2, S=32):
    """Correct input dict for any arch family."""
    from repro.models.lm import FRONTEND_DIMS
    ks = jax.random.split(rng, 3)
    if cfg.frontend == "audio_stub":
        return {
            "feats": jax.random.normal(
                ks[0], (B, S, FRONTEND_DIMS["audio_stub"]), jnp.float32),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
    out = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        out["img"] = jax.random.normal(
            ks[2], (B, cfg.n_image_tokens, FRONTEND_DIMS["vision_stub"]),
            jnp.float32)
    return out
