"""MPQPolicy serialization round-trip + reverse_indicators involution."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import search
from repro.core.policy import MPQPolicy
from repro.models import lm


@pytest.fixture(scope="module")
def qlayers():
    return lm.enumerate_qlayers(get_config("limpq-demo"))


def _cyclic_policy(qlayers, bits=(2, 3, 4, 5, 6)):
    n = len(bits)
    return MPQPolicy(
        {q.name: int(bits[i % n]) for i, q in enumerate(qlayers)},
        {q.name: int(bits[(i + 1) % n]) for i, q in enumerate(qlayers)},
        meta={"kind": "cyclic", "alpha": 0.5, "note": "round-trip"})


def test_policy_json_roundtrip(tmp_path, qlayers):
    """save -> load reproduces w_bits / a_bits / meta exactly."""
    policy = _cyclic_policy(qlayers)
    path = str(tmp_path / "policy.json")
    policy.save(path)
    back = MPQPolicy.load(path)
    assert back.w_bits == policy.w_bits
    assert back.a_bits == policy.a_bits
    assert back.meta == policy.meta
    # a second trip through text form is also the identity
    again = MPQPolicy.from_json(back.to_json())
    assert again.w_bits == policy.w_bits
    assert again.a_bits == policy.a_bits
    assert again.meta == policy.meta


def test_policy_roundtrip_preserves_accounting(tmp_path, qlayers):
    policy = _cyclic_policy(qlayers)
    path = str(tmp_path / "policy.json")
    policy.save(path)
    back = MPQPolicy.load(path)
    assert back.bitops(qlayers, 128) == policy.bitops(qlayers, 128)
    assert back.size_bytes(qlayers) == policy.size_bytes(qlayers)
    assert lm.bits_from_policy(get_config("limpq-demo"), back) is not None


def _rand_indicators(qlayers, n_bits=5, seed=0):
    r = np.random.default_rng(seed)
    # distinct per-layer sums so the sensitivity ranking is a strict order
    return {q.name: {"w": r.uniform(0.1, 1.0, n_bits) + i,
                     "a": r.uniform(0.1, 1.0, n_bits) + i}
            for i, q in enumerate(qlayers)}


def test_reverse_indicators_is_involution(qlayers):
    """Rank-mirroring twice restores the original table."""
    ind = _rand_indicators(qlayers)
    rev = search.reverse_indicators(qlayers, ind)
    rev2 = search.reverse_indicators(qlayers, rev)
    for name in ind:
        np.testing.assert_array_equal(rev2[name]["w"], ind[name]["w"])
        np.testing.assert_array_equal(rev2[name]["a"], ind[name]["a"])


def test_reverse_indicators_mirrors_ranks(qlayers):
    """Most-sensitive layer receives the least-sensitive layer's row."""
    ind = _rand_indicators(qlayers)
    rev = search.reverse_indicators(qlayers, ind)
    score = {n: float(np.sum(d["w"]) + np.sum(d["a"]))
             for n, d in ind.items()}
    order = sorted(score, key=score.get)
    for i, name in enumerate(order):
        mirrored = order[len(order) - 1 - i]
        np.testing.assert_array_equal(rev[name]["w"], ind[mirrored]["w"])
        np.testing.assert_array_equal(rev[name]["a"], ind[mirrored]["a"])
    # and the multiset of indicator rows is preserved (it's a permutation)
    assert sorted(float(np.sum(d["w"])) for d in rev.values()) == \
        sorted(float(np.sum(d["w"])) for d in ind.values())
