"""End-to-end system behaviour: the full paper pipeline + drivers."""
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import importance as imp
from repro.core import search
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext


def test_full_pipeline_improves_over_reversed(tmp_path):
    """The paper's headline mechanics at micro scale: QAT with the
    ILP-searched policy must beat the REVERSED policy (Table-6 ablation
    direction) after identical finetuning."""
    from repro import optim, training
    cfg = get_config("limpq-demo").scaled(n_layers=2, d_model=64, n_heads=2,
                                          n_kv_heads=2, d_ff=256, vocab=256)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    data = SyntheticLM(cfg)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(s, 4, 64).items()}
               for s in range(14)]

    # 1) indicators
    params, _ = imp.train_importance(params, cfg, ctx, batches[:6], lr=0.02)
    ql = lm.enumerate_qlayers(cfg)
    ind = imp.extract_indicators(params, cfg, ql)

    # 2) search fwd + reversed at the same 3-bit-level budget
    budget = search.bitops_budget_for_uniform(ql, 3)
    fwd = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                               bitops_budget=budget)
    rev = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                               bitops_budget=budget, reverse=True)

    # 3) identical short finetune under each policy
    def finetune(policy):
        bits = lm.bits_from_policy(cfg, policy, ql)
        opt = optim.adamw(3e-3, clip_norm=1.0)
        step = jax.jit(training.make_train_step(cfg, ctx, opt, bits, NO_AXES,
                                                remat=False))
        p, s = params, opt.init(params)
        for b in batches[6:12]:
            p, s, m = step(p, s, b)
        ev = training.evaluate(p, cfg, ctx, bits, batches[12:])
        return ev["ce"]

    ce_fwd = finetune(fwd.policy)
    ce_rev = finetune(rev.policy)
    assert np.isfinite(ce_fwd) and np.isfinite(ce_rev)
    # direction check (micro-scale, so allow noise): fwd not worse by >2%
    assert ce_fwd <= ce_rev * 1.02


def test_train_driver_runs_and_checkpoints(tmp_path, capsys):
    from repro.launch import train as train_mod
    ck = str(tmp_path / "ck")
    train_mod.main(["--arch", "limpq-demo", "--mode", "qat", "--steps", "4",
                    "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
                    "--ckpt-every", "2"])
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(ck)
    assert mgr.latest_step() == 3


def test_importance_driver_saves_indicators(tmp_path):
    from repro.launch import train as train_mod
    out = str(tmp_path / "ind.json")
    train_mod.main(["--arch", "limpq-demo", "--mode", "importance",
                    "--steps", "2", "--batch", "2", "--seq", "32",
                    "--save-indicators", out])
    with open(out) as f:
        ind = json.load(f)
    cfg = get_config("limpq-demo")
    assert len(ind) == len(lm.enumerate_qlayers(cfg))
    first = next(iter(ind.values()))
    assert len(first["w"]) == cfg.n_bits


def test_serve_driver_runs(capsys):
    from repro.launch import serve as serve_mod
    serve_mod.main(["--arch", "limpq-demo", "--batch", "2",
                    "--prompt-len", "16", "--gen", "4"])
    out = capsys.readouterr().out
    assert "prefill" in out and "int8 quant_matmul" in out
    err = float(out.rsplit("max_err=", 1)[1])
    assert err < 1e-4
