"""Observability subsystem: metrics-registry math, trace schema
round-trips, stats/trace reconciliation on a real engine run, and the
roofline calibration loop."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.dist import roofline
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig, EngineStats
from repro.launch.scheduler import Request
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.obs import calibrate, metrics, trace


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_monotonic():
    c = metrics.Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 3.5


def test_gauge_moves_both_ways():
    g = metrics.Gauge("g")
    g.set(5)
    g.set(2)
    assert g.value == 2.0


def test_histogram_bucket_assignment():
    h = metrics.Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    # upper-bound-inclusive buckets plus the implicit overflow
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5
    assert h.sum == pytest.approx(106.0)
    d = h.as_dict()
    assert d["min"] == 0.5 and d["max"] == 100.0
    assert d["buckets"]["+inf"] == 1


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        metrics.Histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        metrics.Histogram("h", buckets=())
    with pytest.raises(ValueError):
        metrics.Histogram("h", buckets=(1.0, math.inf))


def test_histogram_percentiles():
    h = metrics.Histogram("h", buckets=(10.0, 20.0, 30.0, 40.0))
    assert h.percentile(0.5) == 0.0          # empty
    h.observe(25.0)
    # a single sample reports itself: edges clamp to observed min/max
    assert h.percentile(0.0) == pytest.approx(25.0)
    assert h.percentile(0.5) == pytest.approx(25.0)
    assert h.percentile(1.0) == pytest.approx(25.0)
    h2 = metrics.Histogram("h2", buckets=(10.0, 20.0, 30.0, 40.0))
    for v in range(1, 101):                  # uniform over (0, 100]
        h2.observe(float(v))
    # interpolated percentiles track the uniform distribution to within
    # a bucket width; p100 is exactly the observed max
    assert h2.percentile(0.50) == pytest.approx(50.0, abs=10.0)
    assert h2.percentile(0.95) == pytest.approx(95.0, abs=10.0)
    assert h2.percentile(1.0) == pytest.approx(100.0)
    with pytest.raises(ValueError):
        h2.percentile(1.5)


def test_registry_get_or_create_and_typing():
    reg = metrics.MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    reg.counter("a").inc(3)
    assert reg.value("a") == 3.0
    assert reg.value("missing") == 0.0
    with pytest.raises(TypeError):
        reg.gauge("a")
    with pytest.raises(TypeError):
        reg.histogram("a")
    reg.gauge("g").set(7)
    reg.histogram("h").observe(1.0)
    snap = reg.snapshot()
    assert snap["a"] == 3.0 and snap["g"] == 7.0
    assert snap["h"]["count"] == 1
    json.dumps(snap)  # JSON-able end to end
    assert "a" in reg and len(reg) == 3


# ---------------------------------------------------------------------------
# trace schema round-trips
# ---------------------------------------------------------------------------
def _demo_recorder():
    rec = trace.TraceRecorder()
    rec.instant("admit", track=trace.req_track(0), ts=0.0, rid=0,
                prompt_len=4)
    rec.span("prefill", 0.0, 0.5, track=trace.req_track(0), rid=0)
    rec.instant("first_token", track=trace.req_track(0), ts=0.5, rid=0,
                token=7)
    rec.span("decode_step", 0.5, 0.75, slots=1)
    rec.instant("token", track=trace.req_track(0), ts=0.75, rid=0, token=3)
    rec.instant("complete", track=trace.req_track(0), ts=0.75, rid=0)
    return rec


def test_span_rejects_negative_duration():
    rec = trace.TraceRecorder()
    with pytest.raises(ValueError):
        rec.span("x", 1.0, 0.5)


def test_jsonl_round_trip(tmp_path):
    rec = _demo_recorder()
    path = str(tmp_path / "t.jsonl")
    rec.to_jsonl(path)
    back = trace.TraceRecorder.from_jsonl(path)
    assert back.events == rec.events


def test_jsonl_rejects_unknown_schema(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"schema": 999}) + "\n")
    with pytest.raises(ValueError):
        trace.TraceRecorder.from_jsonl(path)


def test_chrome_round_trip(tmp_path):
    rec = _demo_recorder()
    obj = rec.chrome()
    assert trace.validate_chrome(obj) == []
    # thread-name metadata labels every track
    names = {m["args"]["name"] for m in obj["traceEvents"]
             if m.get("ph") == "M"}
    assert trace.ENGINE_TRACK in names and "req:0" in names
    back = trace.TraceRecorder.from_chrome(obj)
    assert [(e.name, e.track) for e in back.events] == \
        [(e.name, e.track) for e in rec.events]
    for a, b in zip(back.events, rec.events):
        assert a.ts == pytest.approx(b.ts)
        assert a.dur == pytest.approx(b.dur)
        assert a.args == b.args
    # extension-based writer: .jsonl vs chrome json
    p1, p2 = str(tmp_path / "a.jsonl"), str(tmp_path / "a.json")
    rec.write(p1)
    rec.write(p2)
    assert trace.TraceRecorder.from_jsonl(p1).events == rec.events
    assert trace.validate_chrome(json.load(open(p2))) == []


def test_request_summaries():
    rec = _demo_recorder()
    reqs = trace.request_summaries(rec.events)
    assert set(reqs) == {0}
    r = reqs[0]
    assert r["tokens"] == 2
    assert r["ttft_ms"] == pytest.approx(500.0)
    assert r["itl_ms"] == [pytest.approx(250.0)]


def test_reconcile_flags_mismatches():
    rec = _demo_recorder()
    good = {"t_decode_s": 0.25, "t_prefill_s": 0.5, "decode_steps": 1,
            "tokens_generated": 2, "admitted": 1, "completed": 1}
    assert trace.reconcile(rec, good) == []
    bad = dict(good, t_decode_s=1.0, tokens_generated=5)
    problems = trace.reconcile(rec, bad)
    assert any("t_decode_s" in p for p in problems)
    assert any("tokens_generated" in p for p in problems)


# ---------------------------------------------------------------------------
# engine integration: lifecycle spans + counters on a real run
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    bits = lm.bits_uniform(cfg, 4)
    eng = DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                       EngineConfig(slots=2, cache_len=24))
    data_rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=data_rng.integers(
                0, cfg.vocab, size=8 - i).astype(np.int32), max_new=3 + i)
            for i in range(3)]
    eng.submit_all(reqs)
    completions = eng.run()
    return dict(cfg=cfg, eng=eng, reqs=reqs, completions=completions)


def test_engine_trace_complete_lifecycles(served):
    eng = served["eng"]
    stats = eng.stats
    problems = trace.reconcile(eng.trace, stats.as_dict())
    assert problems == [], problems
    reqs = trace.request_summaries(eng.trace.events)
    assert set(reqs) == {r.rid for r in served["reqs"]}
    for rid, r in reqs.items():
        # full admit -> first_token -> tokens -> complete -> evict chain,
        # timestamps non-decreasing
        for stage in ("admit", "first_token", "complete", "evict"):
            assert stage in r, (rid, stage)
        chain = [r["admit"], r["first_token"]] + sorted(r["token_ts"]) + \
            [r["complete"], r["evict"]]
        assert all(b >= a for a, b in zip(chain, chain[1:])), (rid, chain)
        assert r["tokens"] == len(served["completions"][rid].tokens)
    # decode spans carry the fenced step timings exactly
    decode_durs = [e.dur for e in eng.trace.events
                   if e.name == "decode_step"]
    assert len(decode_durs) == stats.decode_steps
    assert sum(decode_durs) == pytest.approx(stats.t_decode_s, rel=1e-6)


def test_engine_stats_snapshot_and_latency(served):
    eng = served["eng"]
    s = eng.stats
    assert isinstance(s, EngineStats)
    assert s.tokens_generated == sum(
        len(c.tokens) for c in served["completions"].values())
    d = s.as_dict()
    for key in ("ttft_p50_ms", "ttft_p95_ms", "itl_p50_ms", "itl_p95_ms",
                "decode_step_p50_ms", "prefill_p50_ms"):
        assert key in d and d[key] > 0.0, key
    assert d["ttft_p50_ms"] <= d["ttft_p95_ms"]
    # timers are perf_counter based and cover the histograms' mass
    assert s.t_decode_s > 0.0 and s.t_prefill_s > 0.0
    # scheduler + dispatch instrumented through the same registry
    assert eng.metrics.value("scheduler.admitted") == s.admitted
    assert "scheduler.queue_depth" in eng.metrics
    assert eng.metrics.value(
        f"engine.decode_attn_route.{eng.decode_attn_route}") == 1.0


def test_engine_reset_starts_fresh_epoch(served):
    eng = served["eng"]
    old_stats = eng.stats
    old_registry = eng.metrics
    old_trace = eng.trace
    assert old_stats.completed > 0
    eng.reset()
    # new epoch: counters restart from zero, the old snapshot (and the old
    # registry/trace objects) stay frozen rather than being rewound
    assert eng.metrics is not old_registry
    assert eng.trace is not old_trace
    assert eng.stats.completed == 0
    assert eng.stats.iterations == 0
    assert old_stats.completed > 0
    assert old_registry.value("engine.completed") == old_stats.completed
    # re-serve after reset to leave the fixture engine usable
    eng.submit_all(served["reqs"])
    eng.run()
    assert eng.stats.completed == len(served["reqs"])


# ---------------------------------------------------------------------------
# roofline calibration
# ---------------------------------------------------------------------------
def test_calibrate_finite_rows_and_device_table(served):
    eng, cfg = served["eng"], served["cfg"]
    report = calibrate.calibrate(
        cfg, eng.stats.as_dict(), slots=eng.ecfg.slots,
        cache_tokens=eng.ecfg.cache_len, kv_bits=eng.kv_bits,
        kv_attend=eng.kv_attend, chip=eng.ecfg.chip)
    assert report["finite"]
    assert {r["phase"] for r in report["rows"]} == \
        {"decode_step", "prefill_token", "ttft"}
    for r in report["rows"]:
        assert math.isfinite(r["ratio"]) and r["ratio"] > 0, r
    t = report["device_table"]
    assert t["hbm_bytes_s"] > 0 and t["peak_flops"] > 0
    chip = roofline.chip_from_table(t)
    assert chip.hbm_bytes_s == pytest.approx(t["hbm_bytes_s"])
    assert chip.peak_flops == pytest.approx(t["peak_flops"])
    assert chip.ici_bytes_s == roofline.DEFAULT_CHIP.ici_bytes_s
    table = calibrate.render_table(report["rows"])
    assert "decode_step" in table and "ratio" in table


def test_chip_from_table_rejects_nonpositive():
    with pytest.raises(ValueError):
        roofline.chip_from_table({"hbm_bytes_s": 0.0})
    with pytest.raises(ValueError):
        roofline.chip_from_table({"peak_flops": -1.0})
    # bookkeeping keys ignored, name passthrough allowed
    chip = roofline.chip_from_table(
        {"name": "x-measured", "source": "unit-test"})
    assert chip.name == "x-measured"
