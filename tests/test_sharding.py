"""Partition rules: divisibility fallbacks and spec validity per arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.dist import sharding
from repro.models import lm


class FakeMesh:
    """Axis-name/shape stand-in (tests run on 1 device; specs are pure)."""
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape)
        self.shape = dict(zip(names, shape))


MESH_1POD = FakeMesh((16, 16), ("data", "model"))
MESH_2POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _axes(cfg, mesh=MESH_1POD):
    return sharding.make_axes_for(cfg, mesh)


def test_divisibility_fallbacks():
    a_star = _axes(get_config("starcoder2-7b"))
    assert a_star.th == ()               # 36 heads don't divide 16
    a_yi = _axes(get_config("yi-9b"))
    assert a_yi.th == ("model",)         # 32 heads divide
    a_hub = _axes(get_config("hubert-xlarge"))
    assert a_hub.tv == ()                # vocab 504 doesn't divide
    a_rg = _axes(get_config("recurrentgemma-2b"))
    assert a_rg.th == ()                 # 10 heads
    assert a_rg.tv == ("model",)         # 256000 divides


def test_moe_expert_vs_ffn_sharding():
    a_ds = _axes(get_config("deepseek-moe-16b"))
    assert a_ds.ep == ("model",)         # 64 experts / 16
    assert a_ds.mtp == ()
    a_mx = _axes(get_config("mixtral-8x7b"))
    assert a_mx.ep == ()                 # 8 experts don't divide 16
    assert a_mx.mtp == ("model",)        # d_ff 14336 does


def test_multipod_dp_axes():
    a = _axes(get_config("yi-9b"), MESH_2POD)
    assert a.dp == ("pod", "data")
    assert a.dp_size == 32
    assert a.tp_size == 16


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divide_shapes(arch):
    """Every sharded dim must actually divide by the axis size."""
    cfg = get_config(arch)
    axes = _axes(cfg)
    params_shape = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    specs = sharding.param_specs(cfg, params_shape, axes)

    def check(leaf, spec):
        assert isinstance(spec, P)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            ax_names = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([MESH_1POD.shape[a] for a in ax_names]))
            assert dim % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, params_shape, specs,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_projection_rules():
    cfg = get_config("yi-9b")
    axes = _axes(cfg)
    fn = sharding.param_spec_fn(cfg, axes)
    # column-parallel: out dim sharded
    assert fn("body/0/wq/w", (48, 4096, 4096)) == P(None, None, ("model",))
    assert fn("body/0/mlp_wi/w", (48, 4096, 11008)) == P(None, None, ("model",))
    # row-parallel: in dim sharded
    assert fn("body/0/wo/w", (48, 4096, 4096)) == P(None, ("model",), None)
    assert fn("body/0/mlp_wo/w", (48, 11008, 4096)) == P(None, ("model",), None)
    # banks / norms replicate
    assert fn("body/0/wq/s_w", (48, 5)) == P(None, None)
    assert fn("body/0/norm1/scale", (48, 4096)) == P(None, None)
    # vocab-sharded embedding
    assert fn("embed/w", (64000, 4096)) == P(("model",), None)


def test_moe_param_rules():
    cfg = get_config("deepseek-moe-16b")
    axes = _axes(cfg)
    fn = sharding.param_spec_fn(cfg, axes)
    # expert-parallel: expert dim sharded, in/out replicated
    assert fn("body/0/moe/wi/w", (27, 64, 2048, 1408)) == \
        P(None, ("model",), None, None)
    cfg2 = get_config("mixtral-8x7b")
    fn2 = sharding.param_spec_fn(cfg2, _axes(cfg2))
    # ffn-parallel fallback: per-expert d_ff sharded
    assert fn2("body/0/moe/wi/w", (32, 8, 4096, 14336)) == \
        P(None, None, None, ("model",))
    assert fn2("body/0/moe/wo/w", (32, 8, 14336, 4096)) == \
        P(None, None, ("model",), None)


def test_rwkv_rglru_rules():
    cfg = get_config("rwkv6-7b")
    fn = sharding.param_spec_fn(cfg, _axes(cfg))
    assert fn("body/0/wg/w", (32, 4096, 4096)) == P(None, None, ("model",))
    assert fn("body/0/cm_wv/w", (32, 14336, 4096)) == P(None, ("model",), None)
    cfg2 = get_config("recurrentgemma-2b")
    fn2 = sharding.param_spec_fn(cfg2, _axes(cfg2))
    assert fn2("body/0/rg/wx/w", (8, 2560, 2560)) == P(None, None, ("model",))
    assert fn2("body/0/rg/wo/w", (8, 2560, 2560)) == P(None, ("model",), None)


def test_zero_sharding_widens():
    cfg = get_config("yi-9b")
    axes = _axes(cfg)
    params_shape = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    z = sharding.zero_sharded_specs(cfg, params_shape, axes)
    spec = z["body"]["0"]["mlp_wi"]["w"]
    # base P(None,None,model); ZeRO adds data on the largest free dim (4096)
    assert spec == P(None, ("data",), ("model",))


def test_batch_specs_b1_replicates():
    cfg = get_config("rwkv6-7b")
    axes = _axes(cfg)
    one = jax.ShapeDtypeStruct((1, 524288), jnp.int32)
    spec = sharding.batch_specs(cfg, one, axes)
    assert spec == P(None, None)
    many = jax.ShapeDtypeStruct((256, 4096), jnp.int32)
    assert sharding.batch_specs(cfg, many, axes) == P(("data",), None)


def test_decode_state_slot_axis():
    """Per-slot engine state: the slot (batch) axis shards over dp, incl.
    the rank-2 per-slot position rows; shared position vectors replicate."""
    cfg = get_config("qwen3-0.6b")
    axes = _axes(cfg)
    state = jax.eval_shape(
        lambda: lm.init_decode_state(cfg, 32, 128, per_slot=True))
    specs = sharding.decode_state_specs(cfg, state, axes)
    cache, spec = state["body"]["0"], specs["body"]["0"]
    assert cache.pos.shape == (cfg.n_layers, 32, 128)
    assert spec.k == P(None, ("data",), None, None, None)
    assert spec.pos == P(None, ("data",), None)   # slot axis on the pos rows

    shared = jax.eval_shape(lambda: lm.init_decode_state(cfg, 32, 128))
    sspecs = sharding.decode_state_specs(cfg, shared, axes)
    assert shared["body"]["0"].pos.shape == (cfg.n_layers, 128)
    assert sspecs["body"]["0"].pos == P(None, None)  # cap dim never shards
