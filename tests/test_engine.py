"""Continuous-batching engine: token-identity with the fixed-batch path,
strictly-fewer decode steps on staggered schedules, and the slot
admission/eviction invariants (no leaks, no KV mixing) under random
arrival/finish schedules (hypothesis, stub-compatible)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.scheduler import Request, Scheduler
from repro.models import attention as attn
from repro.models import lm
from repro.models.quant_layers import QuantContext

CACHE_LEN = 16
SLOTS = 2


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("qwen3-0.6b")
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    bits = lm.bits_uniform(cfg, 3)
    # the pre-engine serving path: per-request prefill + shared-position
    # decode — the token-for-token oracle the engine must match
    prefill = jax.jit(lambda p, b: lm.apply_prefill(
        p, cfg, b, bits, ctx, NO_AXES, prefill_cap=CACHE_LEN))
    decode = jax.jit(lambda p, t, pos, s: lm.apply_decode(
        p, cfg, t, pos, s, bits, ctx, NO_AXES))
    eng = DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                       EngineConfig(slots=SLOTS, cache_len=CACHE_LEN))
    return dict(cfg=cfg, params=params, ctx=ctx, bits=bits,
                prefill=prefill, decode=decode, eng=eng)


def oracle(setup, req):
    """Fixed-path greedy decode of one request (shared scalar positions)."""
    lg, st = setup["prefill"](setup["params"],
                              {"tokens": jnp.asarray(req.tokens)[None]})
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < req.max_new:
        pos = jnp.asarray(req.prompt_len + len(toks) - 1, jnp.int32)
        lg, st = setup["decode"](setup["params"],
                                 jnp.asarray([[toks[-1]]], jnp.int32), pos, st)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def make_requests(specs):
    """specs: [(prompt_len, max_new, arrival_gap)] -> staggered Requests."""
    data_rng = np.random.default_rng(7)
    reqs, arrival = [], 0
    for i, (p, g, gap) in enumerate(specs):
        arrival += gap
        toks = data_rng.integers(0, 500, size=p).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new=g, arrival=arrival))
    return reqs


def run_engine(setup, reqs, policy):
    eng = setup["eng"]
    eng.reset(policy)
    eng.submit_all(reqs)
    out = eng.run()
    return eng, out


def cache_pos_leaves(state):
    leaves = jax.tree.flatten(
        state, is_leaf=lambda x: isinstance(x, attn.KVCache))[0]
    return [np.asarray(c.pos) for c in leaves if isinstance(c, attn.KVCache)]


# ---------------------------------------------------------------------------
def test_token_identical_and_fewer_steps_on_stagger(setup):
    specs = [(8, 6, 0), (4, 2, 0), (6, 3, 1), (4, 6, 2), (8, 2, 2)]
    reqs = make_requests(specs)
    cont, cont_out = run_engine(setup, reqs, "continuous")
    cont_stats = cont.stats
    fixed, fixed_out = run_engine(setup, reqs, "fixed")

    for r in reqs:
        want = oracle(setup, r)
        assert cont_out[r.rid].tokens == want, f"continuous != oracle rid {r.rid}"
        assert fixed_out[r.rid].tokens == want, f"fixed != oracle rid {r.rid}"
    # mixed arrivals + staggered lengths: continuous batching must finish in
    # strictly fewer decode steps than padding every round to its max
    assert cont_stats.decode_steps < fixed.stats.decode_steps
    assert cont_stats.slot_steps <= fixed.stats.padded_slot_steps


def test_sjf_policy_matches_tokens(setup):
    reqs = make_requests([(8, 3, 0), (4, 3, 0), (6, 2, 0)])
    _, sjf_out = run_engine(setup, reqs, "continuous-sjf")
    for r in reqs:
        assert sjf_out[r.rid].tokens == oracle(setup, r)


@settings(max_examples=4)
@given(st.lists(st.tuples(st.sampled_from([4, 6, 8]),   # prompt length
                          st.integers(1, 4),            # max_new
                          st.integers(0, 3)),           # arrival gap
                min_size=1, max_size=6))
def test_random_schedule_never_leaks(setup, specs):
    """Property: a random arrival/finish schedule never leaks slots, never
    mixes KV rows between sequences, and matches the fixed path
    token-for-token."""
    reqs = make_requests(specs)
    eng, out = run_engine(setup, reqs, "continuous")
    # every request completed with exactly its budget, no slot left occupied
    assert sorted(out) == [r.rid for r in reqs]
    assert all(s is None for s in eng.slots)
    assert all(len(out[r.rid].tokens) == r.max_new for r in reqs)
    # eviction invariant: after drain every cache row is fully invalidated —
    # a reused slot can only ever attend to entries its own prefill wrote
    for pos in cache_pos_leaves(eng.state):
        assert (pos == -1).all()
    # no KV mixing: any cross-slot leakage corrupts the greedy argmax chain
    for r in reqs:
        assert out[r.rid].tokens == oracle(setup, r), f"rid {r.rid}"


def test_scheduler_units():
    sched = Scheduler("fixed")
    sched.submit(Request(0, np.zeros(4, np.int32), 2))
    sched.submit(Request(1, np.zeros(4, np.int32), 2))
    assert sched.admit(0, [1], occupied=1) == []          # waits for empty
    picks = sched.admit(0, [0, 1], occupied=0)
    assert [s for _, s in picks] == [0, 1] and not sched.pending

    sched = Scheduler("continuous", prefill_chunk=4)
    sched.submit(Request(0, np.zeros(10, np.int32), 2))
    assert sched.admit(0, [0], occupied=0) == []          # credit 4 < 10
    assert sched.admit(1, [0], occupied=0) == []          # credit 8 < 10
    picks = sched.admit(2, [0], occupied=0)               # credit 12 >= 10
    assert [r.rid for r, _ in picks] == [0]

    sched = Scheduler("continuous-sjf", prefill_chunk=100)
    sched.submit(Request(0, np.zeros(8, np.int32), 1))
    sched.submit(Request(1, np.zeros(2, np.int32), 1))
    picks = sched.admit(0, [0, 1], occupied=0)
    assert [r.rid for r, _ in picks] == [1, 0]            # shortest first

    sched = Scheduler("continuous", prefill_chunk=8)
    sched.submit(Request(0, np.zeros(4, np.int32), 1, arrival=5))
    assert sched.admit(0, [0], occupied=0) == []          # not arrived yet
    assert [r.rid for r, _ in sched.admit(5, [0], occupied=0)] == [0]


def test_fixed_round_all_done_at_admission(setup):
    """Regression: a fixed-policy round whose every request finishes at
    admission (max_new=1 -> the prefill token is the whole generation) must
    release its held slots instead of tripping the drain-time leak check."""
    reqs = make_requests([(4, 1, 0), (4, 1, 0), (6, 1, 0)])
    eng, out = run_engine(setup, reqs, "fixed")
    assert sorted(out) == [0, 1, 2]
    assert all(len(out[r.rid].tokens) == 1 for r in reqs)
    assert all(s is None for s in eng.slots)
    for r in reqs:
        assert out[r.rid].tokens == oracle(setup, r)


def test_scheduler_credit_resets_between_waves():
    sched = Scheduler("continuous", prefill_chunk=4)
    sched.submit(Request(0, np.zeros(4, np.int32), 1))
    assert [r.rid for r, _ in sched.admit(0, [0], occupied=0)] == [0]
    # queue drained with banked credit; a fresh wave must start from zero
    sched.submit(Request(1, np.zeros(10, np.int32), 1))
    assert sched.admit(1, [0], occupied=0) == []       # credit 4 < 10 again
    assert sched.admit(2, [0], occupied=0) == []
    assert [r.rid for r, _ in sched.admit(3, [0], occupied=0)] == [1]


def test_engine_rejects_oversized_request(setup):
    eng = setup["eng"]
    eng.reset("continuous")
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(Request(0, np.zeros(12, np.int32), 8))  # 20 > 16


def test_engine_rejects_duplicate_rid(setup):
    eng = setup["eng"]
    eng.reset("continuous")
    eng.submit(Request(0, np.zeros(4, np.int32), 2))
    with pytest.raises(ValueError, match="already"):
        eng.submit(Request(0, np.zeros(4, np.int32), 2))


# ---------------------------------------------------------------------------
# paged KV layout: shared-prefix serving stays token-identical to ring
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def packed_setup():
    """Packed int8 session + engine factory — the only adapter the paged
    layout serves (it needs the chunked ``append`` path)."""
    from repro.core.policy import MPQPolicy
    from repro.runtime.session import QuantizedSession

    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    policy = MPQPolicy.uniform(lm.enumerate_qlayers(cfg), 4)

    def build(layout, cache_len=29):
        sess = QuantizedSession(cfg, params, policy, ctx, mode="packed",
                                kv_quant="int8")
        eng = DecodeEngine(sess.params, cfg, None, ctx, NO_AXES,
                           EngineConfig(slots=2, cache_len=cache_len,
                                        kv_quant="int8", kv_layout=layout,
                                        page_size=8), adapter=sess)
        return sess, eng

    return dict(cfg=cfg, params=params, ctx=ctx, build=build)


def test_paged_engine_token_identical_and_saves_prefill(packed_setup):
    """Three requests share a 16-token (2-page) prompt prefix, one doesn't;
    the paged engine must generate exactly the ring engine's tokens while
    re-mapping the shared pages instead of re-prefilling them — >0 FLOPs
    saved, strictly fewer prefill tokens, and ONE prefill compile shape
    (chunked append replaces the ring path's prompt-length bucketing)."""
    rng = np.random.default_rng(11)
    shared = rng.integers(1, 400, size=16)

    def mk(rid, tail, arrival=0):
        toks = np.concatenate(
            [shared, rng.integers(1, 400, size=tail)]).astype(np.int32)
        return Request(rid=rid, tokens=toks, max_new=4, arrival=arrival)

    reqs = [mk(0, 5), mk(1, 3, 1), mk(2, 7, 2),
            Request(rid=3, tokens=rng.integers(1, 400, size=9).astype(
                np.int32), max_new=4, arrival=2)]
    toks, stats = {}, {}
    from repro.runtime import dispatch
    for layout in ("ring", "paged"):
        _, eng = packed_setup["build"](layout)
        with dispatch.force_decode_attn("dequant-fp"):
            eng.submit_all(reqs)
            out = eng.run()
        toks[layout] = {r.rid: out[r.rid].tokens for r in reqs}
        stats[layout] = eng.stats
        if layout == "paged":
            eng.pool.check()            # no page leaked after the drain
            assert all(s is None for s in eng.slots)
    assert toks["paged"] == toks["ring"]
    assert stats["paged"].prefill_flops_saved > 0
    assert stats["ring"].prefill_flops_saved == 0
    assert stats["paged"].prefill_tokens < stats["ring"].prefill_tokens
    assert stats["paged"].prefill_compiles == 1
    assert stats["paged"].kv_unique_pages > 0


def test_paged_engine_validation(packed_setup):
    """The paged layout's construction-time contract: route-registry
    validation plus int8-KV and append-capable-adapter requirements."""
    cfg, params, ctx = (packed_setup[k] for k in ("cfg", "params", "ctx"))
    bits = lm.bits_uniform(cfg, 3)
    with pytest.raises(ValueError, match="kv_layout"):
        DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                     EngineConfig(slots=2, cache_len=16,
                                  kv_layout="blocked"))
    # the fake-quant reference adapter has no chunked append path
    with pytest.raises(ValueError, match="append-capable"):
        DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                     EngineConfig(slots=2, cache_len=16, kv_quant="int8",
                                  kv_layout="paged"))
    with pytest.raises(ValueError, match="int8"):
        DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                     EngineConfig(slots=2, cache_len=16, kv_quant="none",
                                  kv_layout="paged"))


def test_serve_config_validates_routes():
    """``ServeConfig`` rejects bad combinations at construction — before
    any engine or session is built."""
    from repro.launch.serve import ServeConfig

    scfg = ServeConfig(kv_layout="paged", page_size=8)
    assert scfg.engine_config().kv_layout == "paged"
    # a non-int8 engine of the same run silently serves through ring
    assert scfg.engine_config(kv_quant="fake").kv_layout == "ring"
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(kv_layout="paged", kv="fp")
    with pytest.raises(ValueError, match="kv_layout"):
        ServeConfig(kv_layout="blocked")
    with pytest.raises(ValueError, match="decode_attn"):
        ServeConfig(decode_attn="flash")
    with pytest.raises(ValueError, match="schedule"):
        ServeConfig(schedule="round-robin")
    with pytest.raises(ValueError, match="single-device"):
        ServeConfig(kv_layout="paged", mesh="2x4")


def test_roofline_scheduler_hook():
    from repro.configs import get_config
    from repro.dist import roofline

    cfg = get_config("qwen3-0.6b")
    cost = roofline.decode_step_cost(cfg, 8, cache_tokens=2048, tp_size=4)
    assert cost["compute_s"] > 0 and cost["memory_s"] > 0
    assert cost["collective_s"] > 0            # tp>1 moves activation bytes
    assert cost["step_s"] == max(cost["compute_s"], cost["memory_s"],
                                 cost["collective_s"])
    assert cost["dominant"] == "memory"        # decode re-reads every weight

    chunk = roofline.suggest_prefill_chunk(cfg, 8, cache_tokens=2048)
    assert 16 <= chunk <= 512
    # more HBM bandwidth -> smaller memory ceiling -> less free headroom
    fast_hbm = roofline.ChipSpec(name="x", hbm_bytes_s=8 * 819e9)
    assert roofline.suggest_prefill_chunk(
        cfg, 8, cache_tokens=2048, chip=fast_hbm) <= chunk
