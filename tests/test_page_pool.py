"""Property tests for the paged KV cache's host allocator (``PagePool``)
and the copy-on-write seam.

The pool's ``check()`` is the oracle: the free list and the referenced
pages must partition the id space after every operation.  On top of that:

* a random admit/share/evict workload never leaks a page — when the last
  slot releases and the prefix registry drains, every page is free again;
* a page shared by ``k`` sharers is recycled exactly when the ``k``-th
  reference drops, never earlier;
* ``fork`` + ``copy_page`` (copy-on-write) never mutates the shared
  source page, bit for bit, and exclusive pages fork in place.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime import kv_cache as qkv


def _pages_needed(plen, ps):
    return -(-plen // ps)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=4),       # pages per prompt max
       st.integers(min_value=0, max_value=5),       # rng seed
       st.integers(min_value=6, max_value=12))      # pool size
def test_random_workload_never_leaks(max_pages, seed, n_pages):
    """Admit prompts (longest-registered-prefix hit -> ref shared, alloc
    the rest, register the chain), interleave slot releases, then drain:
    the pool must end with every page free and no invariant ever broken."""
    r = np.random.RandomState(seed)
    ps = 4
    pool = qkv.PagePool(n_pages, ps)
    # a tiny prompt universe so prefixes actually collide
    vocab = [bytes([b]) * 3 for b in range(4)]
    live = {}           # slot id -> page list held by that slot
    next_slot = 0
    for _ in range(30):
        pool.check()
        if live and r.rand() < 0.4:
            slot = r.choice(list(live))
            pool.release(live.pop(slot))
            continue
        n = int(r.randint(1, max_pages + 1))
        chain = [b"".join(vocab[r.randint(len(vocab))] for _ in range(j + 1))
                 for j in range(n)]
        for j in range(1, n):   # chains must be prefix-consistent
            chain[j] = chain[j - 1] + chain[j]
        shared = list(pool.lookup_prefix(chain))
        need = n - len(shared)
        try:
            fresh, _ = pool.alloc_with_freed(need)
        except RuntimeError:
            continue            # pool genuinely full of live slots: skip
        pool.ref(shared)
        pages = shared + fresh
        pool.register_prefix(chain, pages)
        live[next_slot] = pages
        next_slot += 1
    for pages in live.values():
        pool.release(pages)
    while pool.registered_prefixes:
        pool.drop_lru_prefix()
    pool.check()
    assert pool.free_count == n_pages, "pages leaked after full drain"
    assert pool.unique_pages_in_use == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=5),       # sharers
       st.integers(min_value=0, max_value=3))       # seed (release order)
def test_refcount_zero_exactly_at_last_release(k, seed):
    """A page shared by ``k`` slots is freed by the ``k``-th release and
    only the ``k``-th — early releases recycle nothing."""
    pool = qkv.PagePool(4, 8)
    [pid], _ = pool.alloc_with_freed(1)
    for _ in range(k - 1):
        pool.ref([pid])
    order = np.random.RandomState(seed).permutation(k)
    for i, _ in enumerate(order):
        freed = pool.release([pid])
        pool.check()
        if i < k - 1:
            assert freed == [], f"page freed after {i + 1}/{k} releases"
            assert pool.refcount[pid] == k - 1 - i
        else:
            assert freed == [pid]
            assert pool.free_count == 4
    with pytest.raises(AssertionError):
        pool.release([pid])     # double free must be caught, not ignored


def test_fork_cow_never_mutates_shared_page():
    """The copy-on-write contract end to end: two sharers of one physical
    page; the writer forks (fresh id), ``copy_page`` clones the bits, and
    a subsequent write to the fork leaves the shared original untouched."""
    r = np.random.RandomState(2)
    ps, KV, hd = 4, 2, 8
    pool = qkv.PagePool(4, ps)
    cache = qkv.init_paged_kv_cache(4, ps, KV, hd, slots=1,
                                    pages_per_slot=1)
    [pid], _ = pool.alloc_with_freed(1)
    pool.ref([pid])             # second sharer

    # fill the shared page with real rows
    k = jnp.asarray(r.normal(size=(1, ps, KV, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(1, ps, KV, hd)), jnp.float32)
    cache = cache.map_slot(0, jnp.asarray([pid], jnp.int32)).append_rows(
        k, v, jnp.arange(ps, dtype=jnp.int32), 0)
    before = {f: np.asarray(getattr(cache, f)[pid]).copy()
              for f in ("k", "v", "k_scale", "v_scale", "pos")}

    new_pid, needs_copy, _ = pool.fork(pid)
    assert needs_copy and new_pid != pid
    assert pool.refcount[pid] == 1      # writer's ref moved to the fork
    pool.check()
    cache = cache.copy_page(pid, new_pid)
    for f, want in before.items():      # clone is bit-identical
        np.testing.assert_array_equal(
            np.asarray(getattr(cache, f)[new_pid]), want, f)

    # the forker overwrites its copy; the shared original must not move
    cache = cache.map_slot(0, jnp.asarray([new_pid], jnp.int32))
    k2 = jnp.asarray(r.normal(size=(1, 1, KV, hd)), jnp.float32)
    cache = cache.append_rows(k2, k2, jnp.asarray([1], jnp.int32), 0)
    for f, want in before.items():
        np.testing.assert_array_equal(np.asarray(getattr(cache, f)[pid]),
                                      want,
                                      f"{f}: shared page mutated by fork")
    assert not np.array_equal(np.asarray(cache.k[new_pid]), before["k"])

    # exclusive page: fork is the identity, no copy
    same, copy2, _ = pool.fork(new_pid)
    assert same == new_pid and not copy2


def test_alloc_evicts_lru_prefix_then_raises():
    """Allocation pressure drops registered prefixes LRU-first (returning
    the recycled ids so the engine can clear device pos rows) and raises
    only when live slots truly exhaust the pool."""
    pool = qkv.PagePool(4, 8)
    a = pool.alloc(2)
    pool.register_prefix([b"old"], [a[0]])
    pool.register_prefix([b"new"], [a[1]])
    pool.release(a)             # slots gone; only the registry pins pages
    pool.lookup_prefix([b"old"])            # "old" becomes most-recent
    ids, freed = pool.alloc_with_freed(3)   # evicts LRU "new" only
    assert freed == [a[1]] and len(ids) == 3
    assert pool.registered_prefixes == 1    # "old" survives the pressure
    pool.check()
    with pytest.raises(RuntimeError):
        pool.alloc(2)           # 3 live + 1 pinned: evicting "old" frees
        # one page, still short of two — must raise, not leak
    assert pool.registered_prefixes == 0    # the failed alloc evicted it
    pool.check()
    pool.release(ids)
    assert pool.free_count == 4


def test_register_prefix_pins_each_chain_level():
    """Every chain level pins its own pages, so a shorter shared prefix
    keeps matching after a longer one is evicted."""
    pool = qkv.PagePool(6, 8)
    pages = pool.alloc(3)
    pool.register_prefix([b"p1", b"p2", b"p3"], pages)
    pool.release(pages)         # the admitting slot leaves
    # page 0 is pinned by all three levels, page 2 by one
    assert pool.refcount[pages[0]] == 3
    assert pool.refcount[pages[2]] == 1
    assert pool.lookup_prefix([b"p1"]) == tuple(pages[:1])
    # the lookup marked the 1-page chain most-recent, so the 2-page chain
    # is LRU and goes first — the shorter prefix must keep matching
    pool.drop_lru_prefix()
    assert pool.lookup_prefix([b"p1", b"p2"]) == tuple(pages[:1])
    pool.check()


def test_pool_meta_bytes_in_paged_inventory():
    """The accounting bugfix: a paged cache's ``inventory()`` itemizes the
    slot page table AND the pool's free-list/refcount meta, and
    ``cache_bytes`` is exactly their sum — the roofline reconciliation
    gate sees the real resident footprint, not just codes."""
    ps, n_pages, KV, hd = 8, 6, 2, 4
    cache = qkv.init_paged_kv_cache(n_pages, ps, KV, hd, slots=3,
                                    pages_per_slot=2)
    inv = qkv.inventory(cache)
    assert inv["codes"] == 2 * n_pages * ps * KV * hd
    assert inv["scales"] == 2 * n_pages * ps * KV * 4
    assert inv["pos"] == n_pages * ps * 4
    assert inv["table"] == 3 * 2 * 4
    assert inv["meta"] == qkv.PagePool(n_pages, ps).meta_bytes()
    assert qkv.cache_bytes(cache) == sum(inv.values())
