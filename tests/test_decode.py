"""Serving-path integration: prefill + step-by-step decode must reproduce
the train-mode forward logits exactly (same quantization active)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext

from conftest import make_inputs

# one representative per family mechanism: qk_norm+tied, SWA+GQA fallback,
# MoE+shared experts, attention-free, hybrid recurrence, cross-attn VLM
ARCHS = ["qwen3-0.6b", "starcoder2-7b", "deepseek-moe-16b", "rwkv6-7b",
         "recurrentgemma-2b", "llama-3.2-vision-11b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    cfg = smoke_config(arch)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    B, S, P = 2, 24, 20
    inputs = make_inputs(cfg, rng, B=B, S=S)
    bits = lm.bits_uniform(cfg, 3)

    full, _ = lm.apply_train(params, cfg, inputs, bits, ctx, NO_AXES,
                             remat=False)
    pre = dict(inputs)
    pre["tokens"] = inputs["tokens"][:, :P]
    lg, state = lm.apply_prefill(params, cfg, pre, bits, ctx, NO_AXES,
                                 prefill_cap=S)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, P - 1]),
                               atol=2e-4, rtol=2e-4)
    for t in range(P, S):
        tok = inputs["tokens"][:, t:t + 1]
        lg, state = lm.apply_decode(params, cfg, tok,
                                    jnp.asarray(t, jnp.int32), state, bits,
                                    ctx, NO_AXES)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-4,
                                   err_msg=f"{arch} step {t}")


def test_decode_state_shapes(rng):
    cfg = smoke_config("mixtral-8x7b")
    state = lm.init_decode_state(cfg, batch=2, capacity=128)
    sched = lm.build_schedule(cfg)
    # windowed arch: cache capacity clamps to the sliding window
    cache = state["body"]["0"]
    assert cache.k.shape == (sched.repeats, 2,
                             min(128, cfg.sliding_window),
                             cfg.n_kv_heads, cfg.hd)


def test_encoder_only_has_no_decode():
    cfg = smoke_config("hubert-xlarge")
    from repro.configs.base import SHAPES_BY_NAME, shape_applicable
    ok, why = shape_applicable(cfg, SHAPES_BY_NAME["decode_32k"])
    assert not ok and "encoder-only" in why
