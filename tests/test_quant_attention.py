"""Fused int8 decode-attention kernel vs the dequant reference, plus the
ring-buffer cache-accounting regressions it rode in with:

* property test: random (GQA ratio, window, capacity, wraparound depth,
  evicted negative-pos slots) through the fused-interpret kernel and the
  dequant-fp reference must produce identical greedy argmax tokens and the
  same cache writes, bit for bit;
* the unified quantize-and-write helper keeps the fp/int8 x shared/per-slot
  quadrants in lockstep (a negative sentinel position can no longer clobber
  the ring's wrapped tail slot in the shared int8 layout);
* ``cache_bytes`` counts the int32 ``pos`` buffer, reconciled against
  ``dist.roofline.decode_step_cost(kv_bits=8)``'s ``kv_hbm_bytes``;
* a zero K row contributes an exactly-zero logit on both routes (the
  ``KV_SCALE_EPS`` floor multiplies, never divides).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.dist import roofline
from repro.models import attention as attn
from repro.models import lm
from repro.runtime import dispatch
from repro.runtime import kv_cache as qkv


def _build_ring_cache(r, B, cap, KV, hd, next_pos, per_slot=True):
    """Simulate per-row ring writes: row b holds the last ``cap`` of its
    ``next_pos[b]`` tokens at their wrapped slots; unwritten slots stay
    -1; ``next_pos[b] <= 0`` is an evicted/empty slot (all -1)."""
    kq = np.zeros((B, cap, KV, hd), np.int8)
    vq = np.zeros((B, cap, KV, hd), np.int8)
    ks = np.zeros((B, cap, KV), np.float32)
    vs = np.zeros((B, cap, KV), np.float32)
    pos = np.full((B, cap), -1, np.int32)
    for b, p in enumerate(next_pos):
        for t in range(max(0, p - cap), max(p, 0)):
            s = t % cap
            for dst_q, dst_s in ((kq, ks), (vq, vs)):
                cq, cs = qkv.quantize_rows(
                    jnp.asarray(r.normal(size=(KV, hd)), jnp.float32))
                dst_q[b, s], dst_s[b, s] = np.asarray(cq), np.asarray(cs)
            pos[b, s] = t
    if not per_slot:
        pos = pos[0]
    return qkv.QuantKVCache(jnp.asarray(kq), jnp.asarray(vq),
                            jnp.asarray(ks), jnp.asarray(vs),
                            jnp.asarray(pos))


def _run_both_routes(q, cache, k_new, v_new, pos, window):
    with dispatch.force_decode_attn("dequant-fp"):
        out_r, c_r = attn.decode_attention(q, cache, k_new, v_new, pos,
                                           window=window)
    with dispatch.force_decode_attn("fused-interpret"):
        out_f, c_f = attn.decode_attention(q, cache, k_new, v_new, pos,
                                           window=window)
    for f in cache._fields:     # identical write path, bit for bit
        np.testing.assert_array_equal(np.asarray(getattr(c_r, f)),
                                      np.asarray(getattr(c_f, f)), f)
    return np.asarray(out_r), np.asarray(out_f)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(1, 1), (1, 4), (2, 2), (2, 3)]),   # (KV, G)
       st.sampled_from([None, 3, 6]),                       # window
       st.integers(min_value=4, max_value=11),              # capacity
       st.integers(min_value=0, max_value=9),               # wrap depth
       st.integers(min_value=0, max_value=3),               # seed
       st.booleans())                                       # evict a row
def test_fused_interpret_token_identical_to_dequant(kvg, window, cap, wrap,
                                                    seed, evict):
    KV, G = kvg
    B, hd, H = 3, 8, KV * G
    r = np.random.RandomState(seed)
    # rows at three ring regimes: wrapped, partially filled, near-empty —
    # optionally one fully evicted (pos -1 rides the decode batch)
    next_pos = [cap + wrap, max(1, cap // 2), 1]
    if evict:
        next_pos[2] = -1
    cache = _build_ring_cache(r, B, cap, KV, hd, next_pos)
    q = jnp.asarray(r.normal(size=(B, 1, H, hd)), jnp.float32)
    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    pos = jnp.asarray([p if p >= 0 else -1 for p in next_pos], jnp.int32)

    out_r, out_f = _run_both_routes(q, cache, k_new, v_new, pos, window)
    active = [b for b, p in enumerate(next_pos) if p >= 0]
    np.testing.assert_allclose(out_f[active], out_r[active],
                               rtol=2e-5, atol=2e-6)
    # greedy "tokens": argmax of a fixed random readout over each row's
    # attention output must be bitwise identical between the routes
    W = np.random.RandomState(7).normal(size=(H * hd, 64)).astype(np.float32)
    lg_r = out_r.reshape(B, -1)[active] @ W
    lg_f = out_f.reshape(B, -1)[active] @ W
    top2 = np.sort(lg_r, axis=-1)[:, -2:]
    gap = top2[:, 1] - top2[:, 0]
    # an exact numerical tie (gap below the routes' fp agreement) is the
    # only draw where argmax could legitimately differ; never seen, but
    # don't let a measure-zero tie flake the property
    decisive = gap > 1e-4
    np.testing.assert_array_equal(lg_f.argmax(-1)[decisive],
                                  lg_r.argmax(-1)[decisive])


def test_fused_route_handles_shared_pos_layout():
    r = np.random.RandomState(3)
    B, cap, KV, G, hd = 2, 8, 2, 2, 8
    H = KV * G
    cache = _build_ring_cache(r, B, cap, KV, hd, [cap + 3, cap + 3],
                              per_slot=False)
    q = jnp.asarray(r.normal(size=(B, 1, H, hd)), jnp.float32)
    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    out_r, out_f = _run_both_routes(q, cache, k_new, v_new, cap + 3,
                                    window=5)
    np.testing.assert_allclose(out_f, out_r, rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# ring-write regressions (satellite bugfixes)
# ---------------------------------------------------------------------------
def test_negative_pos_never_clobbers_wrapped_tail_slot():
    """Regression: the shared-pos int8 branch used ``mod(pos, cap)``
    without the ``max(pos, 0)`` clamp, so a -1 sentinel wrote codes AND
    scales over the ring's tail slot ``cap - 1``. All quadrants now clamp
    to slot 0 and stamp pos -1 there (never valid to attend)."""
    r = np.random.RandomState(0)
    B, cap, KV, hd = 2, 6, 2, 8
    shared = _build_ring_cache(r, B, cap, KV, hd, [cap, cap],
                               per_slot=False)
    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    new = attn.ring_write(shared, k_new, v_new, -1)
    for f in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(getattr(new, f))[:, cap - 1],
            np.asarray(getattr(shared, f))[:, cap - 1],
            err_msg=f"{f}: tail slot clobbered by a negative-pos write")
    assert int(new.pos[0]) == -1          # clamped write marks slot 0 empty
    np.testing.assert_array_equal(np.asarray(new.pos[1:]),
                                  np.asarray(shared.pos[1:]))


@pytest.mark.parametrize("quant", [False, True])
def test_ring_write_quadrants_agree(quant):
    """One write helper serves fp/int8 x shared/per-slot: widening a
    shared cache to per-slot and writing with a constant pos vector must
    produce exactly the widened result of the shared write."""
    r = np.random.RandomState(1)
    B, cap, KV, hd = 3, 5, 2, 8
    if quant:
        shared = _build_ring_cache(r, B, cap, KV, hd, [3, 3, 3],
                                   per_slot=False)
    else:
        pos = jnp.concatenate([jnp.arange(3, dtype=jnp.int32),
                               jnp.full((cap - 3,), -1, jnp.int32)])
        shared = attn.KVCache(
            k=jnp.asarray(r.normal(size=(B, cap, KV, hd)), jnp.float32),
            v=jnp.asarray(r.normal(size=(B, cap, KV, hd)), jnp.float32),
            pos=pos)
    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    for p in (3, cap + 2, -1):            # plain, wrapped, sentinel
        from_shared = attn.cache_per_slot(attn.ring_write(
            shared, k_new, v_new, p))
        per_slot = attn.ring_write(attn.cache_per_slot(shared), k_new,
                                   v_new, jnp.full((B,), p, jnp.int32))
        for f in shared._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(from_shared, f)),
                np.asarray(getattr(per_slot, f)), err_msg=f"{f} pos={p}")


# ---------------------------------------------------------------------------
# cache-bytes accounting (satellite bugfix) vs the roofline model
# ---------------------------------------------------------------------------
def test_cache_bytes_counts_pos_buffer():
    cache = qkv.init_quant_kv_cache(4, 16, 2, 8, per_slot=True)
    codes = 2 * 4 * 16 * 2 * 8 * 1
    scales = 2 * 4 * 16 * 2 * 4
    pos = 4 * 16 * 4
    assert qkv.cache_bytes(cache) == codes + scales + pos


def test_roofline_kv_bytes_match_cache_inventory():
    """The acceptance reconciliation: ``decode_step_cost(kv_bits=8)``'s
    kv term must match the measured codes + scales + pos inventory of the
    engine's per-slot int8 caches within 5%."""
    cfg = smoke_config("limpq-demo")
    slots, cache_len = 4, 22
    state = lm.init_decode_state(cfg, slots, cache_len, per_slot=True,
                                 kv_quant="int8")
    measured = sum(
        qkv.cache_bytes(c) for c in jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, qkv.QuantKVCache))
        if isinstance(c, qkv.QuantKVCache))
    model = roofline.decode_step_cost(
        cfg, slots, cache_tokens=cache_len, kv_bits=8.0,
        kv_attend="fused")["kv_hbm_bytes"]
    assert measured > 0
    assert abs(model - measured) / measured <= 0.05, (model, measured)


def test_roofline_dequant_attend_costs_more_than_fused():
    """'int8 stored but fp-attended' must charge strictly more HBM than
    'int8 attended', and more than an honest scheduler should budget."""
    cfg = smoke_config("limpq-demo")
    fused = roofline.decode_step_cost(cfg, 4, cache_tokens=64, kv_bits=8.0,
                                      kv_attend="fused")
    deq = roofline.decode_step_cost(cfg, 4, cache_tokens=64, kv_bits=8.0,
                                    kv_attend="dequant")
    assert deq["kv_hbm_bytes"] > fused["kv_hbm_bytes"]
    assert deq["memory_s"] > fused["memory_s"]
    with pytest.raises(ValueError):
        roofline.decode_step_cost(cfg, 4, kv_bits=8.0, kv_attend="nope")


def test_force_decode_attn_route_validation():
    assert dispatch.resolve_decode_attn(backend="cpu") == "dequant-fp"
    assert dispatch.resolve_decode_attn(backend="tpu") == "fused"
    with dispatch.force_decode_attn("fused-interpret"):
        assert dispatch.resolve_decode_attn(backend="tpu") == \
            "fused-interpret"
    assert dispatch.resolve_decode_attn(backend="cpu") == "dequant-fp"
    with pytest.raises(ValueError):
        with dispatch.force_decode_attn("flash"):
            pass


# ---------------------------------------------------------------------------
# paged layout vs ring: bitwise lockstep + accounting reconciliation
# ---------------------------------------------------------------------------
def _paged_from_ring(cache, ps):
    """Identity-map a non-wrapping per-slot ring cache into the paged
    layout: slot ``b`` maps pages ``b*P .. b*P+P-1``, so logical slot
    ``j*ps + r`` is page block ``(j, r)`` — a pure reshape of the ring
    arrays."""
    B, cap, KV, hd = cache.k.shape
    assert cap % ps == 0
    P = cap // ps
    return qkv.PagedKVCache(
        k=cache.k.reshape(B * P, ps, KV, hd),
        v=cache.v.reshape(B * P, ps, KV, hd),
        k_scale=cache.k_scale.reshape(B * P, ps, KV),
        v_scale=cache.v_scale.reshape(B * P, ps, KV),
        pos=cache.pos.reshape(B * P, ps),
        page_table=jnp.arange(B * P, dtype=jnp.int32).reshape(B, P))


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([(1, 2), (2, 2)]),       # (KV, G)
       st.integers(min_value=0, max_value=3),   # seed
       st.sampled_from([4, 8]))                 # page size
def test_paged_decode_bitwise_identical_to_ring(kvg, seed, ps):
    """The tentpole's numerics contract: the same logical rows served
    through the page table must produce bit-identical decode attention on
    BOTH routes (the dequant path attends ``gather()``'s dense view; the
    fused path gathers by page index inside the kernel grid), and the
    decode write must land at the same logical row, bit for bit.  The
    dequant route is exactly the ring graph after ``gather()`` — bitwise
    — while the fused kernel partitions the flash accumulation by page
    instead of ring block, so its contract is the serving one: greedy
    argmax identity (plus the routes' usual fp agreement).  A sentinel
    (-1) slot is the one write divergence by design: ring clamps the
    write to slot 0, paged drops it — both rows stay unattendable."""
    KV, G = kvg
    B, hd, H, P = 3, 8, KV * G, 2
    cap = P * ps
    r = np.random.RandomState(seed)
    next_pos = [cap - 1, max(1, cap // 2), -1]  # nearly full, half, evicted
    ring = _build_ring_cache(r, B, cap, KV, hd, next_pos)
    paged = _paged_from_ring(ring, ps)
    q = jnp.asarray(r.normal(size=(B, 1, H, hd)), jnp.float32)
    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    pos = jnp.asarray(next_pos, jnp.int32)
    active = [b for b, p in enumerate(next_pos) if p >= 0]
    W = np.random.RandomState(7).normal(size=(H * hd, 64)).astype(np.float32)
    for route in ("dequant-fp", "fused-interpret"):
        with dispatch.force_decode_attn(route):
            out_r, c_r = attn.decode_attention(q, ring, k_new, v_new, pos,
                                               window=None)
            out_p, c_p = attn.decode_attention(q, paged, k_new, v_new, pos,
                                               window=None)
        out_r, out_p = np.asarray(out_r)[active], np.asarray(out_p)[active]
        if route == "dequant-fp":
            np.testing.assert_array_equal(out_p, out_r, route)
        else:
            np.testing.assert_allclose(out_p, out_r, rtol=2e-5, atol=2e-6)
            lg_r, lg_p = out_r.reshape(len(active), -1) @ W, \
                out_p.reshape(len(active), -1) @ W
            top2 = np.sort(lg_r, axis=-1)[:, -2:]
            decisive = top2[:, 1] - top2[:, 0] > 1e-4
            np.testing.assert_array_equal(lg_p.argmax(-1)[decisive],
                                          lg_r.argmax(-1)[decisive], route)
        g = c_p.gather()
        np.testing.assert_array_equal(np.asarray(g.pos),
                                      np.asarray(c_r.pos), route)
        for f in ("k", "v", "k_scale", "v_scale"):
            np.testing.assert_array_equal(
                np.asarray(getattr(g, f))[active],
                np.asarray(getattr(c_r, f))[active], f"{route}:{f}")


def test_roofline_paged_kv_bytes_match_inventory():
    """Paged counterpart of the ring reconciliation: with every pool page
    unique-touched, ``decode_step_cost(unique_pages=..., page_size=...)``
    must match the measured paged inventory (codes + scales + pos + page
    table + pool meta, meta once per tree) within 5% — the pool's host
    free-list/refcount meta is deliberately the only uncharged part."""
    cfg = smoke_config("limpq-demo")
    slots, cache_len, ps = 4, 24, 8
    state = lm.init_decode_state(cfg, slots, cache_len, per_slot=True,
                                 kv_quant="int8")
    ring_leaves = [
        c for c in jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, qkv.QuantKVCache))
        if isinstance(c, qkv.QuantKVCache)]
    layout = qkv.KVCacheLayout(kind="paged", quant="int8", page_size=ps)
    paged = [layout.alloc(slots, cache_len, c.k.shape[2], c.k.shape[3],
                          per_slot=True) for c in ring_leaves]
    measured = sum(qkv.cache_bytes(c) for c in paged) \
        - (len(paged) - 1) * paged[0].inventory()["meta"]
    model = roofline.decode_step_cost(
        cfg, slots, cache_tokens=cache_len, kv_bits=8.0, kv_attend="fused",
        unique_pages=layout.pool_pages(slots, cache_len),
        page_size=ps)["kv_hbm_bytes"]
    assert measured > 0
    assert abs(model - measured) / measured <= 0.05, (model, measured)


def test_roofline_paged_term_validation():
    """Shared prefixes shrink the modeled KV traffic (fewer unique pages
    touched), and the paged kwargs validate: a paged cost needs a positive
    page size and int8-or-narrower KV."""
    cfg = smoke_config("limpq-demo")
    kw = dict(cache_tokens=24, kv_bits=8.0, kv_attend="fused")
    full = roofline.decode_step_cost(cfg, 4, unique_pages=15, page_size=8,
                                     **kw)
    shared = roofline.decode_step_cost(cfg, 4, unique_pages=3, page_size=8,
                                       **kw)
    assert shared["kv_hbm_bytes"] < full["kv_hbm_bytes"]
    with pytest.raises(ValueError):
        roofline.decode_step_cost(cfg, 4, unique_pages=3, **kw)
    with pytest.raises(ValueError):
        roofline.decode_step_cost(cfg, 4, cache_tokens=24, kv_bits=16.0,
                                  unique_pages=3, page_size=8)


# ---------------------------------------------------------------------------
# KV_SCALE_EPS zero-row audit (satellite)
# ---------------------------------------------------------------------------
def test_zero_k_row_contributes_exactly_zero_logits():
    """A zero K row quantizes to codes 0 with the eps-floored scale; both
    the fused fold ``(q . codes) * s`` and the reference ``q . (codes * s)``
    must land at exactly 0.0 — no ``0 * eps^-1`` term ever forms."""
    r = np.random.RandomState(5)
    B, cap, KV, hd = 2, 6, 2, 8
    H = 2 * KV
    cache = _build_ring_cache(r, B, cap, KV, hd, [4, 4])
    zq, zs = qkv.quantize_rows(jnp.zeros((KV, hd), jnp.float32))
    assert np.all(np.asarray(zq) == 0)
    np.testing.assert_array_equal(np.asarray(zs),
                                  np.full((KV,), qkv.KV_SCALE_EPS,
                                          np.float32))
    k = np.asarray(cache.k).copy()
    ks = np.asarray(cache.k_scale).copy()
    k[:, 2], ks[:, 2] = np.asarray(zq), np.asarray(zs)   # zero row, slot 2
    cache = cache._replace(k=jnp.asarray(k), k_scale=jnp.asarray(ks))

    q = np.asarray(r.normal(size=(B, 1, H, hd)), np.float32)
    # both routes' logit math for the zero row, mirrored exactly
    qc = q.reshape(B, KV, 2, hd) * (hd ** -0.5)
    fused_logit = np.einsum("bkgd,bkd->bkg", qc,
                            k[:, 2].astype(np.float32)) * ks[:, 2, :, None]
    ref_logit = np.einsum("bkgd,bkd->bkg", qc,
                          k[:, 2].astype(np.float32) * ks[:, 2, :, None])
    assert np.all(fused_logit == 0.0) and np.all(ref_logit == 0.0)

    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    out_r, out_f = _run_both_routes(jnp.asarray(q), cache, k_new, v_new,
                                    jnp.asarray([4, 4], jnp.int32), None)
    assert np.isfinite(out_r).all() and np.isfinite(out_f).all()
    np.testing.assert_allclose(out_f, out_r, rtol=2e-5, atol=2e-6)
