"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced config runs one forward/train step on CPU with correct shapes and
no NaNs — under fp, uniform-bit, random-bit, and ILP-policy bit routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim, training
from repro.configs import ASSIGNED_ARCHS, smoke_config
from repro.core.policy import MPQPolicy
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext

from conftest import make_inputs

B, S = 2, 32


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request, rng):
    cfg = smoke_config(request.param)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    inputs = make_inputs(cfg, rng, B=B, S=S)
    return cfg, params, ctx, inputs


def test_forward_shapes_and_finite(arch_setup):
    cfg, params, ctx, inputs = arch_setup
    bits = lm.bits_uniform(cfg, 2)
    logits, aux = lm.apply_train(params, cfg, inputs, bits, ctx, NO_AXES,
                                 remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


def test_fp_and_random_paths(arch_setup, rng):
    cfg, params, ctx, inputs = arch_setup
    loss_fp, _ = lm.loss_fn(params, cfg, inputs, None, ctx, NO_AXES,
                            remat=False)
    loss_rnd, _ = lm.loss_fn(params, cfg, inputs,
                             lm.bits_random(cfg, rng), ctx, NO_AXES,
                             remat=False)
    assert bool(jnp.isfinite(loss_fp)) and bool(jnp.isfinite(loss_rnd))


def test_policy_bits_route(arch_setup):
    cfg, params, ctx, inputs = arch_setup
    ql = lm.enumerate_qlayers(cfg)
    policy = MPQPolicy({q.name: cfg.bits[i % cfg.n_bits]
                        for i, q in enumerate(ql)},
                       {q.name: cfg.bits[(i + 1) % cfg.n_bits]
                        for i, q in enumerate(ql)})
    bits = lm.bits_from_policy(cfg, policy, ql)
    loss, _ = lm.loss_fn(params, cfg, inputs, bits, ctx, NO_AXES, remat=False)
    assert bool(jnp.isfinite(loss))


def test_one_train_step_updates(arch_setup):
    cfg, params, ctx, inputs = arch_setup
    bits = lm.bits_uniform(cfg, 2)
    opt = optim.adamw(1e-3, clip_norm=1.0)
    step = training.make_train_step(cfg, ctx, opt, bits, NO_AXES, remat=False)
    new_params, _, metrics = step(params, opt.init(params), inputs)
    assert bool(jnp.isfinite(metrics["loss"]))
    # at least the embedding-ish leaves moved
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(new_params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


def test_qlayer_enumeration_matches_params(arch_setup):
    """Every QLayer path must resolve to a real param node with banks of
    the right arity; counts match the schedule."""
    cfg, params, ctx, _ = arch_setup
    ql = lm.enumerate_qlayers(cfg)
    assert len({q.name for q in ql}) == len(ql)
    sched = lm.build_schedule(cfg)
    for q in ql:
        seg, idx = q.segment.split(".")
        node = params[seg][idx]
        for k in q.path:
            node = node[k]
        assert "s_w" in node and "s_a" in node
        n = node["s_w"].shape[-1]
        assert n == cfg.n_bits
        if seg == "body":
            assert node["s_w"].shape[0] == sched.repeats
            assert 0 <= q.unit < sched.repeats


def test_remat_path_matches(arch_setup):
    cfg, params, ctx, inputs = arch_setup
    bits = lm.bits_uniform(cfg, 3)
    l1, _ = lm.loss_fn(params, cfg, inputs, bits, ctx, NO_AXES, remat=False)
    l2, _ = lm.loss_fn(params, cfg, inputs, bits, ctx, NO_AXES, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
