"""Attention paths: flash == direct, windows, GQA, decode cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn


def _qkv(rng, B=2, S=1024, H=4, KV=2, hd=16, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 200),
                                           (False, None)])
def test_flash_matches_direct(rng, causal, window):
    q, k, v = _qkv(rng)
    pos = jnp.arange(q.shape[1])
    ref = attn.direct_attention(q, k, v, pos, pos, causal=causal,
                                window=window)
    out = attn.flash_attention(q, k, v, causal=causal, window=window,
                               q_block=128, kv_block=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_sliding_window_subquadratic_slice(rng):
    """The windowed kv slice must produce identical results to full direct
    attention with the same window."""
    q, k, v = _qkv(rng, S=2048)
    pos = jnp.arange(2048)
    ref = attn.direct_attention(q, k, v, pos, pos, causal=True, window=256)
    out = attn.flash_attention(q, k, v, causal=True, window=256,
                               q_block=256, kv_block=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_gqa_grouping(rng):
    """GQA must equal MHA with kv heads repeated."""
    B, S, H, KV, hd = 2, 64, 8, 2, 16
    q, k, v = _qkv(rng, B=B, S=S, H=H, KV=KV, hd=hd)
    pos = jnp.arange(S)
    out = attn.direct_attention(q, k, v, pos, pos, causal=True, window=None)
    k_rep = jnp.repeat(k, H // KV, axis=2)
    v_rep = jnp.repeat(v, H // KV, axis=2)
    ref = attn.direct_attention(q, k_rep, v_rep, pos, pos, causal=True,
                                window=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_decode_cache_ring_buffer(rng):
    """Windowed ring buffer decode == direct attention over the window."""
    B, H, KV, hd, window = 1, 2, 1, 8, 4
    S = 10
    q_all, k_all, v_all = _qkv(rng, B=B, S=S, H=H, KV=KV, hd=hd)
    cache = attn.init_kv_cache(B, window, KV, hd, dtype=jnp.float32)
    outs = []
    for t in range(S):
        out, cache = attn.decode_attention(
            q_all[:, t:t + 1], cache, k_all[:, t:t + 1], v_all[:, t:t + 1],
            jnp.asarray(t, jnp.int32), window=window)
        outs.append(out)
    got = jnp.concatenate(outs, axis=1)
    pos = jnp.arange(S)
    ref = attn.direct_attention(q_all, k_all, v_all, pos, pos, causal=True,
                                window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_empty_slots_masked(rng):
    """Fresh cache slots (pos = -1) must not contribute."""
    B, KV, hd, cap = 1, 1, 8, 6
    q = jax.random.normal(rng, (B, 1, 2, hd))
    k1 = jax.random.normal(jax.random.PRNGKey(1), (B, 1, KV, hd))
    v1 = jax.random.normal(jax.random.PRNGKey(2), (B, 1, KV, hd))
    cache = attn.init_kv_cache(B, cap, KV, hd, dtype=jnp.float32)
    out, _ = attn.decode_attention(q, cache, k1, v1,
                                   jnp.asarray(0, jnp.int32), window=None)
    # attending over exactly one valid slot => output == v of that slot
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]), np.asarray(v1[0, 0, 0]),
                               atol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_flash_custom_vjp_values_and_grads(rng, causal, window):
    """FA2-style custom-vjp path == direct attention, values AND grads."""
    B, S, H, KV, hd = 2, 256, 4, 2, 16
    q, k, v = _qkv(rng, B=B, S=S, H=H, KV=KV, hd=hd)
    pos = jnp.arange(S)

    def f_direct(q, k, v):
        return jnp.sum(jnp.sin(attn.direct_attention(
            q, k, v, pos, pos, causal=causal, window=window)))

    def f_cv(q, k, v):
        return jnp.sum(jnp.sin(attn.flash_attention_cv(
            q, k, v, causal=causal, window=window, q_block=64, kv_block=64)))

    o_d = attn.direct_attention(q, k, v, pos, pos, causal=causal,
                                window=window)
    o_c = attn.flash_attention_cv(q, k, v, causal=causal, window=window,
                                  q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_d), atol=2e-5,
                               rtol=2e-5)
    g_d = jax.grad(f_direct, argnums=(0, 1, 2))(q, k, v)
    g_c = jax.grad(f_cv, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_c, g_d):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=2e-5)
