"""repro.runtime — packing round-trips (property-tested), kernel dispatch
exactness vs the fake-quant graph, int8 KV-cache equivalence, policy schema
gating, bit-aware roofline ordering, and the packed serving session
end-to-end through the continuous-batching engine."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.core.policy import MPQPolicy
from repro.core.quantizer import bit_range, fake_quant
from repro.dist import roofline
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.scheduler import Request, bucket_length
from repro.models import attention as attn
from repro.models import lm
from repro.models.quant_layers import QuantContext, qdense_init, qeinsum
from repro.runtime import dispatch, kv_cache as qkv, packing
from repro.runtime.session import QuantizedSession, summarize


# ===========================================================================
# packing
# ===========================================================================
@settings(max_examples=12, deadline=None)
@given(st.sampled_from([2, 3, 4, 8]),          # searched bit-widths
       st.integers(1, 19),                     # rows (odd counts included)
       st.integers(1, 11),                     # channels (odd counts)
       st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(bits, rows, cols, seed):
    """Property: unpack(pack(q, bits)) == q on the signed grid, any shape."""
    r = np.random.default_rng(seed)
    qmin, qmax = bit_range(bits, True)
    q = r.integers(qmin, qmax + 1, size=(rows, cols)).astype(np.int8)
    back = np.asarray(packing.unpack_codes(
        packing.pack_codes(q, bits), bits, q.size)).reshape(rows, cols)
    np.testing.assert_array_equal(back, q)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([2, 4]), st.integers(1, 17), st.integers(1, 9),
       st.integers(0, 2 ** 31 - 1))
def test_kernel_layout_roundtrip(bits, rows, cols, seed):
    """nib4 / quad2 layouts round-trip with odd contraction dims (padding
    rows are sliced back off)."""
    r = np.random.default_rng(seed)
    qmin, qmax = bit_range(bits, True)
    q = r.integers(qmin, qmax + 1, size=(rows, cols)).astype(np.int8)
    if bits == 4:
        back = packing.unpack_nib4(packing.pack_nib4(q), rows)
    else:
        back = packing.unpack_quad2(packing.pack_quad2(q), rows)
    np.testing.assert_array_equal(np.asarray(back), q)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_pack_linear_matches_fake_quant(bits):
    """Dequantized packed weights == the fake-quant graph's values, bitwise
    (per-tensor trained scale), and storage is ceil(n*bits/8) + padding."""
    r = np.random.default_rng(bits)
    w = r.normal(size=(13, 9)).astype(np.float32)   # odd dims on purpose
    s = np.float32(0.05)
    pl = packing.pack_linear(w, bits, s, 6, 0.02)
    ref = fake_quant(jnp.asarray(w), jnp.asarray(s), *bit_range(bits, True))
    np.testing.assert_array_equal(np.asarray(pl.dequant()), np.asarray(ref))
    ideal = (w.size * bits + 7) // 8
    assert pl.packed_bytes >= ideal
    # padding overhead is at most one row of the packed layout
    assert pl.packed_bytes <= ideal + w.shape[-1] + 1


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 8])
@pytest.mark.parametrize("dim,count", [(0, 4), (1, 2)])
def test_shard_aware_packing_bit_identical_per_shard(bits, dim, count):
    """pack_linear(shard_dim=, shard_count=): every shard's slab of the
    packed codes equals packing that weight shard independently, and the
    whole thing round-trips/dequantizes exactly like the plain packing."""
    r = np.random.default_rng(bits)
    w = r.normal(size=(24, 8)).astype(np.float32)
    s = np.float32(0.05)
    plain = packing.pack_linear(w, bits, s, 6, 0.02)
    sh = packing.pack_linear(w, bits, s, 6, 0.02, shard_dim=dim,
                             shard_count=count)
    np.testing.assert_array_equal(np.asarray(sh.unpack()),
                                  np.asarray(plain.unpack()))
    np.testing.assert_array_equal(np.asarray(sh.dequant()),
                                  np.asarray(plain.dequant()))
    axis = 0 if sh.layout == "bitstream" else dim
    slabs = np.split(np.asarray(sh.codes), count, axis=axis)
    for slab, ws in zip(slabs, np.split(w, count, axis=dim)):
        indep = packing.pack_linear(ws, bits, s, 6, 0.02)
        np.testing.assert_array_equal(slab.reshape(-1),
                                      np.asarray(indep.codes).reshape(-1))
    assert sh.per_shard_bytes * count == sh.packed_bytes
    assert plain.per_shard_bytes == plain.packed_bytes


def test_sharded_nib4_layout_not_w4_eligible():
    """A per-shard re-broken nib4 layout (odd per-shard rows) must not
    feed the w4 kernel, which consumes the PLAIN byte stream — it falls
    back to the unpack-based int8 route; plain packing stays w4-eligible."""
    r = np.random.default_rng(0)
    w = r.normal(size=(12, 8)).astype(np.float32)
    sharded = packing.pack_linear(w, 4, np.float32(0.05), 6, 0.02,
                                  shard_dim=0, shard_count=4)
    assert sharded.sharded_layout()
    assert dispatch.kernel_eligible("bsd,de->bse", sharded) == "pallas-int8"
    plain = packing.pack_linear(w, 4, np.float32(0.05), 6, 0.02)
    assert not plain.sharded_layout()
    assert dispatch.kernel_eligible("bsd,de->bse", plain) == "pallas-w4"


class _Mesh2x4:
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 4}


def test_packed_specs_shard_every_code_leaf():
    """Under a 2x4 mesh every packed projection of the demo arch shards its
    codes (no replicated sub-byte storage left), column-parallel scales
    shard with their out dim, and the per-shard accounting lands on
    policy.size_bytes / tp exactly (all dims divide -> zero padding)."""
    from repro.dist import sharding
    from repro.models.quant_layers import QuantContext as QC

    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QC.make(cfg.bits, cfg.quant_act_signed, compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    policy = MPQPolicy.uniform(ql, 4)
    axes = sharding.make_axes_for(cfg, _Mesh2x4(), shard_seq=False)
    assert axes.tp_size == 4
    sess = QuantizedSession(cfg, params, policy, ctx, axes, kv_quant="int8")

    specs = sharding.packed_specs(cfg, sess.params, axes)
    leaves = {}
    for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=packing.is_packed)[0]:
        if packing.is_packed(s):
            leaves["/".join(str(getattr(k, "key", k)) for k in path)] = s
    assert len(leaves) == len(ql)
    for name, s in leaves.items():
        assert any(e is not None for e in tuple(s.codes)), (name, s.codes)
    # column-parallel scale shards, row-parallel scale replicates
    assert tuple(leaves["sites/000/wq"].scale) == (("model",),)
    assert tuple(leaves["sites/000/wo"].scale) == (None,)
    # per-shard accounting: every leaf sharded 4-ways, dims all divide
    per_shard = sess.packed_bytes(per_shard=True)
    assert per_shard * 4 == sess.packed_bytes()
    assert per_shard == policy.size_bytes(ql, per_shard=4)
    assert policy.size_bytes(ql) == policy.size_bytes(ql, per_shard=1)


def test_pack_linear_per_channel_reduces_error():
    r = np.random.default_rng(0)
    w = (r.normal(size=(16, 8)) * r.uniform(0.1, 4.0, size=8)).astype(
        np.float32)
    s = np.float32(np.abs(w).max() / 7.0)
    pt = packing.pack_linear(w, 4, s, 8, 0.02)
    pc = packing.pack_linear(w, 4, s, 8, 0.02, per_channel=True)
    err_pt = float(jnp.sum((pt.dequant() - w) ** 2))
    err_pc = float(jnp.sum((pc.dequant() - w) ** 2))
    assert pc.per_channel and not pt.per_channel
    assert err_pc <= err_pt


# ===========================================================================
# kernels + dispatch
# ===========================================================================
def test_quant_matmul_w4_packed_equivalence():
    """Interpret-mode quant_matmul on nib4-packed int4 weights == the fp
    reference, including non-tile-aligned shapes."""
    from repro.kernels import ops
    r = np.random.default_rng(3)
    M, K, N = 5, 26, 11
    xq = r.integers(-31, 32, size=(M, K)).astype(np.int8)
    wq = r.integers(-8, 8, size=(K, N)).astype(np.int8)
    wp = packing.pack_nib4(wq)
    s_x, s_w = np.float32(0.05), np.float32(0.07)
    out = ops.quant_matmul_w4(jnp.asarray(xq), wp, s_x, s_w, k=K,
                              blocks=(8, 8, 8))
    ref = (xq.astype(np.float32) * s_x) @ (wq.astype(np.float32) * s_w)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6, atol=1e-6)


@pytest.fixture(scope="module")
def qctx():
    return QuantContext.make((2, 3, 4, 5, 6), True,
                             compute_dtype=jnp.float32)


def _packed_from_bank(p, w_idx, a_idx, bits, qctx):
    from repro.runtime.session import effective_weight_scale
    wb = int(bits[w_idx])
    s_w = effective_weight_scale(p["s_w"], w_idx, p["w"].size, wb)
    return packing.pack_linear(p["w"], wb, s_w, int(bits[a_idx]),
                               jnp.asarray(p["s_a"])[a_idx])


def test_dispatch_fallback_bitwise_exact(qctx):
    """dequant-then-fp dispatch == the fake-quant qeinsum, bitwise, for
    both weight orientations (column- and row-parallel eqns)."""
    bits = (2, 3, 4, 5, 6)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(2, 5, 13)), jnp.float32)
    for eqn_in, w_idx, a_idx in (
            ("bsd,de->bse", 2, 3),           # kernel-form orientation
            ("bse,ed->bsd", 1, 0)):          # row-parallel: fallback-only
        p = qdense_init(jax.random.PRNGKey(w_idx), 13, 9, bits) \
            if eqn_in.startswith("bsd") else \
            qdense_init(jax.random.PRNGKey(w_idx), 5, 13, bits)
        xx = x if eqn_in.startswith("bsd") else \
            jnp.asarray(r.normal(size=(2, 4, 5)), jnp.float32)
        ref = qeinsum(eqn_in, xx, p, {"w": w_idx, "a": a_idx}, qctx)
        pl = _packed_from_bank(p, w_idx, a_idx, bits, qctx)
        got = dispatch.packed_qeinsum(eqn_in, xx, pl, qctx,
                                      impl="dequant-fp")
        assert bool(jnp.all(ref == got)), float(jnp.max(jnp.abs(ref - got)))


def test_dispatch_moe_stacked_fallback(qctx):
    """3-D expert-stacked packed weights (DISTINCT per-expert bank scales,
    the (E,1,1) broadcast form) go through the exact fallback bitwise."""
    from repro.runtime.session import effective_weight_scale
    bits = (2, 3, 4, 5, 6)
    r = np.random.default_rng(2)
    p = qdense_init(jax.random.PRNGKey(9), 7, 5, bits, stacked=(3,))
    p["s_w"] = p["s_w"] * jnp.asarray([1.0, 1.6, 0.5])[:, None]
    p["s_a"] = p["s_a"] * jnp.asarray([1.0, 2.0, 0.7])[:, None]
    x = jnp.asarray(r.normal(size=(3, 4, 7)), jnp.float32)   # (E, T, d)
    ref = qeinsum("etd,edf->etf", x, p, {"w": 1, "a": 2}, qctx)
    s_w = effective_weight_scale(p["s_w"], 1, p["w"].size,
                                 int(bits[1]), w_ndim=3)
    assert s_w.shape == (3, 1, 1)
    pl = packing.pack_linear(p["w"], int(bits[1]), s_w, int(bits[2]),
                             jnp.asarray(p["s_a"])[..., 2])
    assert dispatch.kernel_eligible("etd,edf->etf", pl) is None
    got = dispatch.packed_qeinsum("etd,edf->etf", x, pl, qctx)
    assert bool(jnp.all(ref == got)), float(jnp.max(jnp.abs(ref - got)))


def test_dispatch_kernel_routes_close(qctx):
    """Forced Pallas routes (int8 + packed-int4) agree with the fallback to
    int32-accumulation tolerance."""
    bits = (2, 3, 4, 5, 6)
    p = qdense_init(jax.random.PRNGKey(5), 16, 12, bits)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 3, 16)),
                    jnp.float32)
    pl = _packed_from_bank(p, 2, 3, bits, qctx)      # 4-bit -> nib4 layout
    assert pl.layout == "nib4"
    assert dispatch.kernel_eligible("bsd,de->bse", pl) == "pallas-w4"
    ref = dispatch.packed_qeinsum("bsd,de->bse", x, pl, qctx,
                                  impl="dequant-fp")
    for impl in ("pallas-w4", "pallas-int8"):
        with dispatch.force_impl(impl):
            got = dispatch.packed_qeinsum("bsd,de->bse", x, pl, qctx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # off-TPU auto-resolution stays on the exact fallback
    assert dispatch.resolve("bsd,de->bse", pl) == "dequant-fp"


# ===========================================================================
# int8 KV cache
# ===========================================================================
def test_kv_quantize_dequantize_matches_fake():
    r = np.random.default_rng(4)
    x = jnp.asarray(r.normal(size=(2, 7, 3, 8)), jnp.float32)
    q, s = qkv.quantize_rows(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 7, 3)
    np.testing.assert_array_equal(np.asarray(qkv.dequantize(q, s)),
                                  np.asarray(qkv.fake_quant_kv(x)))
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


@pytest.mark.parametrize("per_slot", [True, False])
def test_decode_attention_int8_equals_fake(per_slot):
    """decode_attention over a QuantKVCache == decode_attention over an fp
    cache holding the fake-quantized values — both position layouts."""
    r = np.random.default_rng(6)
    B, cap, KV, hd, H = 3, 6, 2, 8, 4
    k_rows = jnp.asarray(r.normal(size=(B, cap, KV, hd)), jnp.float32)
    v_rows = jnp.asarray(r.normal(size=(B, cap, KV, hd)), jnp.float32)
    pos0 = jnp.asarray(np.tile(np.arange(cap), (B, 1)) if per_slot
                       else np.arange(cap), jnp.int32)
    kq, ks = qkv.quantize_rows(k_rows)
    vq, vs = qkv.quantize_rows(v_rows)
    qcache = qkv.QuantKVCache(kq, vq, ks, vs, pos0)
    fcache = attn.KVCache(qkv.fake_quant_kv(k_rows),
                          qkv.fake_quant_kv(v_rows), pos0)
    q = jnp.asarray(r.normal(size=(B, 1, H, hd)), jnp.float32)
    k_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, KV, hd)), jnp.float32)
    pos = jnp.full((B,), cap - 1, jnp.int32) if per_slot \
        else jnp.asarray(cap - 1, jnp.int32)
    out_q, cache_q = attn.decode_attention(
        q, qcache, k_new, v_new, pos, window=None)
    out_f, cache_f = attn.decode_attention(
        q, fcache, qkv.fake_quant_kv(k_new), qkv.fake_quant_kv(v_new), pos,
        window=None)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_f))
    assert isinstance(cache_q, qkv.QuantKVCache)
    np.testing.assert_array_equal(np.asarray(cache_q.pos),
                                  np.asarray(cache_f.pos))


def test_quant_cache_state_plumbing():
    """init/per-slot/trim/specs all treat QuantKVCache like KVCache."""
    cfg = smoke_config("limpq-demo")
    st8 = lm.init_decode_state(cfg, 2, 8, per_slot=True, kv_quant="int8")
    caches = [c for c in jax.tree.leaves(
        st8, is_leaf=lambda x: isinstance(x, attn.CACHE_TYPES))
        if isinstance(c, attn.CACHE_TYPES)]
    assert caches and all(isinstance(c, qkv.QuantKVCache) for c in caches)
    # shared-pos prefill state widens to per-slot, and bucketed-prefill
    # trimming invalidates pad rows past the true length
    shared = attn.build_prefill_cache(
        jnp.ones((2, 4, 2, 8)), jnp.ones((2, 4, 2, 8)), 4, 8,
        kv_quant="int8")
    wide = attn.cache_per_slot(shared)
    assert wide.pos.shape == (2, 8)
    trimmed = lm.trim_decode_state(wide, 3)
    assert int(trimmed.pos[0, 3]) == -1 and int(trimmed.pos[0, 2]) == 2
    # slot-axis partition specs shard the code/scale slot dim over data
    from repro.dist import sharding

    class _Mesh:
        axis_names = ("data",)
        shape = {"data": 2}

    axes = sharding.make_axes_for(cfg, _Mesh())
    specs = sharding.decode_state_specs(cfg, st8, axes)
    flat_state = jax.tree_util.tree_flatten_with_path(st8)[0]
    flat_specs = jax.tree.flatten(specs)[0]
    assert len(flat_state) == len(flat_specs)
    for (path, leaf), spec in zip(flat_state, flat_specs):
        entries = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        for dim, ax in zip(leaf.shape, entries):
            if ax is not None:
                assert dim % axes.dp_size == 0
        body = str(getattr(path[0], "key", "")) == "body"
        slot_dim = 1 if body else 0
        if leaf.ndim >= 2 + slot_dim:                # per-slot leaf
            assert entries[slot_dim] == axes.dp


# ===========================================================================
# policy schema + validation
# ===========================================================================
def test_policy_json_has_schema_version():
    ql = lm.enumerate_qlayers(smoke_config("limpq-demo"))
    pol = MPQPolicy.uniform(ql, 4)
    d = json.loads(pol.to_json())
    assert d["schema"] == MPQPolicy.SCHEMA_VERSION
    # pre-versioning files (schema absent) still load
    del d["schema"]
    assert MPQPolicy.from_json(json.dumps(d)).w_bits == pol.w_bits


def test_policy_unknown_schema_rejected():
    ql = lm.enumerate_qlayers(smoke_config("limpq-demo"))
    d = json.loads(MPQPolicy.uniform(ql, 4).to_json())
    d["schema"] = MPQPolicy.SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema"):
        MPQPolicy.from_json(json.dumps(d))


def test_policy_stale_layer_names_fail_loudly():
    cfg = smoke_config("limpq-demo")
    ql = lm.enumerate_qlayers(cfg)
    pol = MPQPolicy.uniform(ql, 4)
    stale = MPQPolicy(
        {("X" + n if i == 0 else n): b
         for i, (n, b) in enumerate(pol.w_bits.items())},
        {("X" + n if i == 0 else n): b
         for i, (n, b) in enumerate(pol.a_bits.items())})
    with pytest.raises(ValueError, match="unknown layer names"):
        lm.bits_from_policy(cfg, stale, ql)
    bad_bits = MPQPolicy(dict(pol.w_bits), dict(pol.a_bits))
    bad_bits.w_bits[ql[0].name] = 7          # not in the searched set
    with pytest.raises(ValueError, match="bit-widths"):
        bad_bits.validate(ql, bits=cfg.bits)


# ===========================================================================
# bit-aware roofline + bucketing
# ===========================================================================
def test_decode_step_cost_orders_quantized_below_fp():
    """Pinned ordering: fp16 weights + bf16 KV cost more HBM time than a
    packed policy + int8 KV, and int8 KV alone beats bf16 KV."""
    cfg = smoke_config("limpq-demo")
    ql = lm.enumerate_qlayers(cfg)
    pol = MPQPolicy.uniform(ql, 4)
    fp = roofline.decode_step_cost(cfg, 4, cache_tokens=64,
                                   avg_weight_bits=16.0, kv_bits=16.0)
    kv8 = roofline.decode_step_cost(cfg, 4, cache_tokens=64,
                                    avg_weight_bits=16.0, kv_bits=8.0)
    packed = roofline.decode_step_cost(
        cfg, 4, cache_tokens=64, kv_bits=8.0,
        w_bits_total=pol.size_bytes(ql) * 8.0)
    assert kv8["memory_s"] < fp["memory_s"]
    assert packed["memory_s"] < kv8["memory_s"]
    assert fp["compute_s"] == packed["compute_s"]
    # quantized serving lowers the decode step's memory ceiling, so the
    # "free" compute headroom — and with it the prefill-token budget —
    # shrinks: the scheduler must see the quantized bytes, not fp ones
    c_fp = roofline.suggest_prefill_chunk(cfg, 4, cache_tokens=64,
                                          avg_weight_bits=16.0, kv_bits=16.0)
    c_q = roofline.suggest_prefill_chunk(cfg, 4, cache_tokens=64,
                                         kv_bits=8.0,
                                         w_bits_total=pol.size_bytes(ql) * 8.0)
    assert c_q <= c_fp


def test_bucket_length():
    assert [bucket_length(n) for n in (1, 7, 8, 9, 16, 33)] == \
        [8, 8, 8, 16, 16, 64]
    assert bucket_length(5, min_bucket=2) == 8


# ===========================================================================
# serving session end-to-end
# ===========================================================================
@pytest.fixture(scope="module")
def serving():
    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    bits_seq = sorted(int(b) for b in cfg.bits)
    n = len(bits_seq)
    policy = MPQPolicy(
        {q.name: bits_seq[i % n] for i, q in enumerate(ql)},
        {q.name: bits_seq[(i + 1) % n] for i, q in enumerate(ql)},
        meta={"kind": "cyclic-test"})
    r = np.random.default_rng(7)
    reqs = [Request(rid=i, tokens=r.integers(0, 500, size=p).astype(np.int32),
                    max_new=g, arrival=0)
            for i, (p, g) in enumerate([(8, 4), (4, 3), (6, 4)])]
    return dict(cfg=cfg, params=params, ctx=ctx, ql=ql, policy=policy,
                reqs=reqs)


def _run(engine, reqs):
    engine.submit_all(reqs)
    out = engine.run()
    return {r.rid: out[r.rid].tokens for r in reqs}


def test_session_packed_serves_token_identical(serving):
    """The tentpole gate: packed weights + int8 KV + bucketed prefill
    through the engine == the fake-quant lm reference graph, greedy
    token-for-token; HBM bytes match the policy's accounting."""
    s = serving
    sess = QuantizedSession(s["cfg"], s["params"], s["policy"], s["ctx"],
                            mode="packed", kv_quant="int8")
    ecfg = EngineConfig(slots=2, cache_len=16, kv_quant="int8",
                        bucket_prompts=True)
    eng = DecodeEngine(sess.params, s["cfg"], None, s["ctx"], NO_AXES, ecfg,
                       adapter=sess)
    packed_out = _run(eng, s["reqs"])

    bits = lm.bits_from_policy(s["cfg"], s["policy"], s["ql"])
    ref = DecodeEngine(s["params"], s["cfg"], bits, s["ctx"], NO_AXES,
                       EngineConfig(slots=2, cache_len=16, kv_quant="fake"))
    ref_out = _run(ref, s["reqs"])
    assert packed_out == ref_out

    # bucketing bounded the prefill shapes: prompts 8/4/6 -> buckets {8}
    assert eng.stats.prefill_compiles == 1
    assert ref.stats.prefill_compiles == 3

    info = summarize(sess)
    assert abs(info["packed_vs_policy"] - 1.0) <= 0.05
    assert info["compression_vs_fp32"] > 5.0
    assert sess.w_bits_total == pytest.approx(info["policy_bytes"] * 8.0)


def test_session_from_checkpoint_bundle(serving, tmp_path):
    """save_serving_bundle -> QuantizedSession.from_checkpoint restores an
    identical packed model (codes + scales bitwise equal)."""
    from repro import checkpoint as ckpt
    s = serving
    ckpt.save_serving_bundle(str(tmp_path), 3, s["params"], s["policy"])
    sess = QuantizedSession.from_checkpoint(
        str(tmp_path), s["cfg"], ctx=s["ctx"], kv_quant="int8")
    direct = QuantizedSession(s["cfg"], s["params"], s["policy"], s["ctx"],
                              kv_quant="int8")
    for a, b in zip(jax.tree.leaves(sess.params),
                    jax.tree.leaves(direct.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_session_packed_moe_arch_token_identical():
    """Expert-stacked (MoE) packed weights serve token-identically too —
    per-expert bank scales take the (E,1,1) broadcast packing path."""
    cfg = smoke_config("mixtral-8x7b")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    bits_seq = sorted(int(b) for b in cfg.bits)
    n = len(bits_seq)
    policy = MPQPolicy(
        {q.name: bits_seq[i % n] for i, q in enumerate(ql)},
        {q.name: bits_seq[(i + 1) % n] for i, q in enumerate(ql)})
    r = np.random.default_rng(11)
    reqs = [Request(rid=i, tokens=r.integers(0, 500, size=p).astype(np.int32),
                    max_new=g, arrival=0)
            for i, (p, g) in enumerate([(6, 3), (4, 3)])]
    sess = QuantizedSession(cfg, params, policy, ctx, mode="packed",
                            kv_quant="int8")
    eng = DecodeEngine(sess.params, cfg, None, ctx, NO_AXES,
                       EngineConfig(slots=2, cache_len=12, kv_quant="int8"),
                       adapter=sess)
    packed_out = _run(eng, reqs)
    bits = lm.bits_from_policy(cfg, policy, ql)
    ref = DecodeEngine(params, cfg, bits, ctx, NO_AXES,
                       EngineConfig(slots=2, cache_len=12, kv_quant="fake"))
    assert packed_out == _run(ref, reqs)


def test_session_rejects_foreign_policy(serving):
    s = serving
    other = smoke_config("rwkv6-7b")     # different layer paths entirely
    foreign = MPQPolicy.uniform(lm.enumerate_qlayers(other), 4)
    with pytest.raises(ValueError, match="does not match"):
        QuantizedSession(s["cfg"], s["params"], foreign, s["ctx"])


def test_from_checkpoint_validates_before_restore(serving, tmp_path):
    """A bundle saved for one arch restored against another must fail with
    the MPQPolicy.validate message (same path as bits_from_policy), not a
    missing-array error from the checkpoint reader."""
    from repro import checkpoint as ckpt
    s = serving
    ckpt.save_serving_bundle(str(tmp_path), 0, s["params"], s["policy"])
    other = smoke_config("rwkv6-7b")
    with pytest.raises(ValueError, match="does not match"):
        QuantizedSession.from_checkpoint(str(tmp_path), other, ctx=s["ctx"])


def test_activation_code_reuse_counts_and_stays_exact(serving):
    """Satellite (ISSUE 4): under a uniform policy wq/wk/wv (and the two
    gate-path MLP inputs) share one quantized activation per site — the
    engine reports the elided quantize ops, and greedy tokens stay
    identical to the per-layer-quantizing fake-quant reference."""
    s = serving
    ql = s["ql"]
    uniform = MPQPolicy.uniform(ql, 4)
    sess = QuantizedSession(s["cfg"], s["params"], uniform, s["ctx"],
                            mode="packed", kv_quant="int8")
    # pack-time tagging grouped projections with equal (a_bits, bank value)
    tagged = [pl.a_group for pl in packing.packed_leaves(sess.params)]
    assert any(tagged)
    eng = DecodeEngine(sess.params, s["cfg"], None, s["ctx"], NO_AXES,
                       EngineConfig(slots=2, cache_len=16, kv_quant="int8"),
                       adapter=sess)
    packed_out = _run(eng, s["reqs"])
    # per compile: wq/wk/wv save 2, mlp_wg+mlp_wi save 1 -> 3 per site
    assert eng.stats.act_quant_reused > 0
    assert eng.stats.act_quant_reused % (3 * len(sess.sites)) == 0

    bits = lm.bits_from_policy(s["cfg"], uniform, ql)
    ref = DecodeEngine(s["params"], s["cfg"], bits, s["ctx"], NO_AXES,
                       EngineConfig(slots=2, cache_len=16, kv_quant="fake"))
    assert packed_out == _run(ref, s["reqs"])


def test_mixed_policy_qkv_never_share_a_group(serving):
    """The cyclic test policy gives wq/wk/wv distinct a_bits — the shared
    hidden state must NOT be reused across them (reuse never crosses
    bit-widths or bank values), so their tags are pairwise distinct."""
    s = serving
    sess = QuantizedSession(s["cfg"], s["params"], s["policy"], s["ctx"],
                            mode="packed", kv_quant="int8")
    for key, sp in sess.params["sites"].items():
        trio = [sp[n].a_group for n in ("wq", "wk", "wv")]
        named = [t for t in trio if t]
        assert len(named) == len(set(named)), (key, trio)
