"""Deterministic stand-in for `hypothesis` when it isn't installed.

conftest.py registers this module as ``hypothesis`` (and its
``strategies`` namespace) only if the real package is unavailable, so the
property tests still execute: each ``@given`` test runs ``max_examples``
seeded pseudo-random examples. No shrinking, no database — just coverage.
Installing real hypothesis transparently takes precedence.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: seq[r.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10, **_kw):
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements.draw(r) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strats):
    return _Strategy(lambda r: tuple(s.draw(r) for s in strats))


def just(value):
    return _Strategy(lambda r: value)


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans,
    sampled_from=sampled_from, lists=lists, tuples=tuples, just=just)


def settings(**kw):
    def deco(fn):
        fn._stub_settings = dict(kw)
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # like real hypothesis, the strategies fill the RIGHTMOST
        # parameters; anything left of them (pytest fixtures) passes
        # through — so bind draws by name, not position
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        drawn_names = names[len(names) - len(strats):]

        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = int(getattr(run, "_stub_settings", {}).get("max_examples", 20))
            rnd = random.Random(0xC0FFEE)
            for _ in range(n):
                draws = {k: s.draw(rnd) for k, s in zip(drawn_names, strats)}
                fn(*args, **kwargs, **draws)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution
        keep = [p for k, p in sig.parameters.items() if k not in drawn_names]
        run.__signature__ = sig.replace(parameters=keep)
        run.__dict__.pop("__wrapped__", None)
        return run
    return deco
