"""HLO analyzer: trip-count scaling, collective parsing, XLA calibration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import hlo


def test_scan_vs_unroll_flops_equal():
    def body(x, w):
        return jnp.dot(x, w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(10):
            x = jnp.dot(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    cs = hlo.analyze(jax.jit(scanned).lower(x, ws).compile().as_text())
    cu = hlo.analyze(jax.jit(unrolled).lower(x, ws).compile().as_text())
    expect = 10 * 2 * 128 ** 3
    np.testing.assert_allclose(cs.dot_flops, expect)
    np.testing.assert_allclose(cu.dot_flops, expect)
    assert 10 in cs.trip_counts


def test_matches_xla_cost_analysis_on_unrolled():
    """On a while-free graph the analyzer must agree with XLA exactly."""
    def f(x, w1, w2):
        h = jnp.maximum(x @ w1, 0.0)
        return jnp.sum((h @ w2) ** 2)

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(64, 128), (128, 256), (256, 64)]]
    comp = jax.jit(jax.grad(f, argnums=(1, 2))).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):        # jax<=0.4.x returns [dict]
        ca = ca[0]
    mine = hlo.analyze(comp.as_text())
    np.testing.assert_allclose(mine.flops, ca["flops"], rtol=1e-6)
    # bytes: XLA's fusion choices vary slightly between runs; agreement
    # within 15% calibrates the estimator without pinning the exact plan
    np.testing.assert_allclose(mine.bytes_hbm, ca["bytes accessed"],
                               rtol=0.15)


def test_gqa_einsum_flops():
    def f(q, k):
        return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                          preferred_element_type=jnp.float32)

    q = jax.ShapeDtypeStruct((2, 64, 4, 2, 32), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((2, 64, 4, 32), jnp.bfloat16)
    c = hlo.analyze(jax.jit(f).lower(q, k).compile().as_text())
    np.testing.assert_allclose(c.dot_flops, 2 * 2 * 4 * 2 * 64 * 64 * 32)


def test_collective_parsing_synthetic():
    """Hand-written HLO with known collectives and replica groups."""
    txt = """
HloModule test

ENTRY %main (p0: f32[1024,256]) -> f32[1024,256] {
  %p0 = f32[1024,256]{1,0} parameter(0)
  %ar = f32[1024,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096,256]{1,0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[1024,256]{1,0} reduce-scatter(%ag), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  ROOT %cp = f32[1024,256]{1,0} collective-permute(%rs), source_target_pairs={{0,1},{1,2}}
}
"""
    c = hlo.analyze(txt)
    B = 1024 * 256 * 4
    assert c.n_collectives == 4
    np.testing.assert_allclose(c.by_collective["all-reduce"], 2 * 0.75 * B)
    np.testing.assert_allclose(c.by_collective["all-gather"], 0.75 * 4 * B)
    np.testing.assert_allclose(c.by_collective["reduce-scatter"],
                               0.75 * 4 * B)
    np.testing.assert_allclose(c.by_collective["collective-permute"], B)


def test_wide_tuple_comment_stripping():
    """/*index=N*/ comments inside wide tuple types must not hide whiles."""
    txt = """
HloModule t

%body (x: (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2])) -> (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2]) {
  %x = (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], /*index=5*/f32[2,2], f32[2,2]) parameter(0)
  %g0 = f32[2,2]{1,0} get-tuple-element(%x), index=1
  %g1 = f32[2,2]{1,0} get-tuple-element(%x), index=2
  %d = f32[2,2]{1,0} dot(%g0, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], /*index=5*/f32[2,2], f32[2,2]) tuple(%g0)
}

%cond (x: (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2])) -> pred[] {
  %x2 = (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], /*index=5*/f32[2,2], f32[2,2]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (p: (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2], f32[2,2])) -> s32[] {
  %p = (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], /*index=5*/f32[2,2], f32[2,2]) parameter(0)
  %w = (s32[], f32[2,2], f32[2,2], f32[2,2], f32[2,2], /*index=5*/f32[2,2], f32[2,2]) while(%p), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = s32[] get-tuple-element(%w), index=0
}
"""
    c = hlo.analyze(txt)
    np.testing.assert_allclose(c.dot_flops, 7 * 2 * 2 * 2 * 2)
    assert 7 in c.trip_counts


def test_slice_semantics():
    """dynamic-slice reads the slice, not the whole operand."""
    def f(big, idx):
        return jax.lax.dynamic_slice_in_dim(big, idx, 4, axis=0)

    big = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    c = hlo.analyze(jax.jit(f).lower(
        big, jax.ShapeDtypeStruct((), jnp.int32)).compile().as_text())
    assert c.bytes_hbm < 3 * 4 * 256 * 4 + 4096   # ~2x slice bytes, not 1MB
