"""LSQ quantizer unit + property tests (paper Eq. 1 + Esser et al. grads)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import quantizer as qz


def test_round_ste_value_and_grad():
    x = jnp.asarray([-1.6, -0.4, 0.4, 1.6])
    assert jnp.allclose(qz.round_ste(x), jnp.round(x))
    g = jax.grad(lambda v: jnp.sum(qz.round_ste(v)))(x)
    assert jnp.allclose(g, 1.0)     # straight-through


def test_bit_range():
    assert qz.bit_range(4, signed=True) == (-8, 7)
    assert qz.bit_range(4, signed=False) == (0, 15)
    assert qz.bit_range(2, signed=True) == (-2, 1)


def test_fake_quant_values():
    v = jnp.asarray([-3.0, -0.26, -0.24, 0.0, 0.26, 3.0])
    s = jnp.asarray(0.5)
    out = qz.fake_quant(v, s, -2, 1)
    # v/s = [-6, -.52, -.48, 0, .52, 6] -> clip [-2,1] -> round -> * s
    np.testing.assert_allclose(out, [-1.0, -0.5, 0.0, 0.0, 0.5, 0.5])


def test_lsq_scale_gradient_matches_formula():
    """d v_q / d s == round(v/s) - v/s inside the clip range, qmin/qmax
    outside (the LSQ vjp), obtained compositionally from the STE pair."""
    v = jnp.asarray([-5.0, -1.3, -0.2, 0.7, 1.9, 5.0])
    s = jnp.asarray(0.6)
    qmin, qmax = -4, 3

    g = jax.jacobian(lambda s_: qz.fake_quant(v, s_, qmin, qmax))(s)
    vs = v / s
    inside = (vs > qmin) & (vs < qmax)
    expected = jnp.where(inside, jnp.round(vs) - vs,
                         jnp.clip(vs, qmin, qmax))
    np.testing.assert_allclose(np.asarray(g), np.asarray(expected), rtol=1e-5)


def test_indexed_bank_selects_and_routes_grad():
    tables = qz.BitTables.make((2, 3, 4), signed=True)
    bank = jnp.asarray([0.5, 0.25, 0.125])
    v = jnp.linspace(-1, 1, 64)

    for idx, b in enumerate((2, 3, 4)):
        out = qz.fake_quant_indexed(v, bank, idx, tables, numel=v.size)
        qmin, qmax = qz.bit_range(b, True)
        ref = qz.fake_quant(v, bank[idx], qmin, qmax)
        np.testing.assert_allclose(out, ref, rtol=1e-6)

    g = jax.grad(lambda b_: jnp.sum(
        qz.fake_quant_indexed(v, b_, 1, tables, numel=v.size)))(bank)
    assert g[1] != 0.0 and g[0] == 0.0 and g[2] == 0.0   # only selected entry


def test_indexed_bank_stacked_moe():
    """(E, n) banks select per-expert scales that broadcast against w."""
    tables = qz.BitTables.make((2, 4), signed=True)
    bank = jnp.asarray([[0.5, 0.25], [1.0, 0.125]])      # E=2, n=2
    w = jnp.ones((2, 3, 3))
    out = qz.fake_quant_indexed(w, bank, 1, tables, numel=w.size)
    np.testing.assert_allclose(out[0], qz.fake_quant(w[0], 0.25, -8, 7))
    np.testing.assert_allclose(out[1], qz.fake_quant(w[1], 0.125, -8, 7))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.floats(0.01, 2.0),
       st.lists(st.floats(-10, 10), min_size=1, max_size=50))
def test_property_quant_error_bound(bits, s, vals):
    """|Q(v) - v| <= s/2 for v inside the clip range."""
    qmin, qmax = qz.bit_range(bits, True)
    v = jnp.asarray(vals, jnp.float32)
    out = qz.fake_quant(v, jnp.asarray(s, jnp.float32), qmin, qmax)
    inside = (v / s >= qmin) & (v / s <= qmax)
    err = jnp.abs(out - v)
    assert bool(jnp.all(jnp.where(inside, err <= s / 2 + 1e-5, True)))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.floats(0.01, 2.0),
       st.lists(st.floats(-10, 10), min_size=1, max_size=50))
def test_property_idempotent(bits, s, vals):
    """Q(Q(v)) == Q(v)."""
    qmin, qmax = qz.bit_range(bits, True)
    s = jnp.asarray(s, jnp.float32)
    v = jnp.asarray(vals, jnp.float32)
    q1 = qz.fake_quant(v, s, qmin, qmax)
    q2 = qz.fake_quant(q1, s, qmin, qmax)
    np.testing.assert_allclose(q1, q2, atol=1e-5)


def test_init_scales():
    w = jnp.ones((4, 4)) * 2.0
    s = qz.init_scale_from_stats(w, 7)
    np.testing.assert_allclose(s, 2 * 2.0 / np.sqrt(7), rtol=1e-6)
    np.testing.assert_allclose(qz.init_scale_same(4), 0.1 / 4)
