"""Self-speculative decoding: the low-bit draft proposes, the searched
policy verifies.  Gates the bitwise KV contract (a verify step and any
rejection-pattern rollback reproduce sequential decode's cache exactly),
engine token identity against non-speculative decode on both KV layouts
and both spec launch paths, the construction-time guards, the roofline
round model, and trace/stats reconciliation of the spec counters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.core.policy import MPQPolicy
from repro.dist import roofline
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.scheduler import Request
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.runtime import dispatch
from repro.runtime import kv_cache as qkv
from repro.runtime.session import QuantizedSession, SpecSession


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    # mixed searched target (alternating 4/6-bit weights, 4-bit acts): the
    # draft must repack THESE weights, not a uniform toy
    policy = MPQPolicy({q.name: (4 if i % 2 else 6) for i, q in enumerate(ql)},
                       {q.name: 4 for q in ql})
    sess = SpecSession(cfg, params, policy, ctx, draft_w_bits=2,
                       kv_quant="int8")
    return dict(cfg=cfg, params=params, ctx=ctx, policy=policy, sess=sess,
                qlayers=ql)


def _caches(state):
    out = []

    def rec(x):
        if isinstance(x, qkv.CACHE_TYPES):
            out.append(x)
        return x

    jax.tree.map(rec, state,
                 is_leaf=lambda x: isinstance(x, qkv.CACHE_TYPES))
    return out


def _assert_kv_bitwise(sa, sb, what=""):
    """Bitwise cache equality: pos stamps exactly, codes + write-time
    scales on every live (pos >= 0) row.  Paged caches compare through
    the dense per-slot gather so a permuted physical page-id assignment
    (rollback returns tail pages to the free list) cannot mask or fake a
    logical difference."""
    ca, cb = _caches(sa), _caches(sb)
    assert len(ca) == len(cb) and ca
    for i, (a, b) in enumerate(zip(ca, cb)):
        if isinstance(a, qkv.PagedKVCache):
            a, b = a.gather(), b.gather()
        pa, pb = np.asarray(a.pos), np.asarray(b.pos)
        assert np.array_equal(pa, pb), f"{what} pos leaf {i}"
        m = pa >= 0
        for f in ("k", "v", "k_scale", "v_scale"):
            assert np.array_equal(np.asarray(getattr(a, f))[m],
                                  np.asarray(getattr(b, f))[m]), \
                f"{what} {f} leaf {i}"


def _sequential_reference(sess, toks, pos, states0, cuts):
    """Non-speculative reference: decode one token at a time, freezing each
    slot's state once it has consumed ``cuts[i]`` tokens — the cache a
    plain engine holds after decoding exactly the accepted prefix."""
    B, S = toks.shape
    st_ref = states0
    for j in range(S):
        _, st_next = sess.decode(sess.params, toks[:, j:j + 1], pos[:, j],
                                 st_ref)
        active = np.asarray(cuts) > j

        def sel(new, old):
            if isinstance(new, qkv.CACHE_TYPES):
                keep = jnp.asarray(active)

                def pick(arr_n, arr_o):
                    k = keep.reshape((-1,) + (1,) * (arr_n.ndim - 1))
                    return jnp.where(k, arr_n, arr_o)

                return new._replace(**{f: pick(getattr(new, f),
                                               getattr(old, f))
                                       for f in new._fields})
            return new

        st_ref = jax.tree.map(sel, st_next, st_ref,
                              is_leaf=lambda x: isinstance(x,
                                                           qkv.CACHE_TYPES))
    return st_ref


# ---------------------------------------------------------------------------
# session layer: verify == sequential decode, bitwise
# ---------------------------------------------------------------------------
def test_verify_bitwise_matches_sequential(setup):
    """One verify step over S tokens returns the same logits AND writes the
    same KV rows, bit for bit, as S one-token decode steps."""
    sess, cfg = setup["sess"], setup["cfg"]
    B, S = 2, 3
    states0 = sess.init_state(B, 16, jnp.float32, per_slot=True)
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    st_seq, seq_logits = states0, []
    for j in range(S):
        lj, st_seq = sess.decode(sess.params, toks[:, j:j + 1], pos[:, j],
                                 st_seq)
        seq_logits.append(np.asarray(lj))
    lv, st_ver = sess.verify(sess.params, toks, pos, states0)
    for j in range(S):
        assert np.array_equal(np.asarray(lv[:, j]), seq_logits[j]), j
    _assert_kv_bitwise(st_seq, st_ver, "verify")

    # the draft pack runs through the SAME decode adapter (one runtime,
    # two policies) and is a different function of the same weights
    ld, _ = sess.decode(sess.draft_params, toks[:, :1], pos[:, 0], states0)
    assert ld.shape == seq_logits[0].shape


@settings(max_examples=4)
@given(st.integers(0, 10_000),            # token seed
       st.sampled_from([2, 3, 4]),        # verified row count S = k + 1
       st.integers(0, 4), st.integers(0, 4))   # per-slot accepted rows
def test_rollback_any_rejection_pattern(setup, seed, S, cut0, cut1):
    """Property: after a verify step and a rollback at ANY per-slot cut —
    including cut=0 (everything rejected) and cut=S (everything accepted)
    — the cache is bitwise identical to a non-speculative session that
    decoded only the accepted tokens."""
    sess, cfg = setup["sess"], setup["cfg"]
    B = 2
    cuts = np.minimum([cut0, cut1], S).astype(np.int32)
    states0 = sess.init_state(B, 16, jnp.float32, per_slot=True)
    r = np.random.default_rng(seed)
    toks = jnp.asarray(r.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    _, st_ver = sess.verify(sess.params, toks, pos, states0)
    rolled = lm.rollback_decode_state(st_ver, jnp.asarray(cuts))
    st_ref = _sequential_reference(sess, toks, pos, states0, cuts)
    _assert_kv_bitwise(rolled, st_ref, f"cuts={cuts.tolist()}")


# ---------------------------------------------------------------------------
# engine layer: token identity + KV identity vs a non-speculative engine
# ---------------------------------------------------------------------------
def _requests(cfg, n=3):
    rng = np.random.default_rng(7)
    shared = rng.integers(1, cfg.vocab, size=16)   # 2 full 8-token pages

    def mk(rid, tail, max_new, arrival=0):
        toks = np.concatenate(
            [shared, rng.integers(1, cfg.vocab, size=tail)]).astype(np.int32)
        return Request(rid=rid, tokens=toks, max_new=max_new,
                       arrival=arrival)

    reqs = [mk(0, 5, 6), mk(1, 3, 5, 1),
            Request(rid=2, tokens=rng.integers(
                1, cfg.vocab, size=9).astype(np.int32), max_new=4,
                arrival=2)]
    return reqs[:n]


def _engine(setup, layout, spec_k, *, slots=2, cache_len=29, trace=True):
    sess = setup["sess"]
    eng = DecodeEngine(sess.params, setup["cfg"], None, setup["ctx"],
                       NO_AXES,
                       EngineConfig(slots=slots, cache_len=cache_len,
                                    kv_quant="int8", kv_layout=layout,
                                    page_size=8, speculate=spec_k,
                                    trace=trace), adapter=sess)
    return eng


@pytest.mark.parametrize("layout", ["ring", "paged"])
def test_engine_spec_token_identical(setup, layout):
    """The speculating engine emits exactly the non-speculative engine's
    greedy tokens (paged: on COW-shared prefix pages), books per-request
    acceptance into Completions, and its trace reconciles against the
    spec counters."""
    reqs = _requests(setup["cfg"])
    with dispatch.force_decode_attn("dequant-fp"):
        base = _engine(setup, layout, 0)
        base.submit_all(reqs)
        base_out = base.run()
        spec = _engine(setup, layout, 3)
        spec.submit_all(reqs)
        spec_out = spec.run()

    for r in reqs:
        assert spec_out[r.rid].tokens == base_out[r.rid].tokens, r.rid
    s = spec.stats
    assert s.spec_rounds > 0 and s.spec_draft_tokens > 0
    assert 0.0 <= s.spec_accept_rate <= 1.0
    assert s.spec_accepted_tokens <= s.spec_draft_tokens
    # aggregate counters are exactly the per-request attribution
    assert sum(c.spec_drafted for c in spec_out.values()) \
        == s.spec_draft_tokens
    assert sum(c.spec_accepted for c in spec_out.values()) \
        == s.spec_accepted_tokens
    assert all(c.spec_drafted == c.spec_accepted == 0
               for c in base_out.values())
    # drain invariant survives rollback: no slot can attend any row — the
    # ring wipes pos stamps; paged unmaps every table entry (pages still
    # registered in the prefix registry keep their stamps for LRU reuse)
    for c in _caches(spec.state):
        if isinstance(c, qkv.PagedKVCache):
            assert (np.asarray(c.page_table) == -1).all()
        else:
            assert (np.asarray(c.pos) == -1).all()
    # trace <-> stats: one spec_verify instant per round, token sums match
    from repro.obs import trace as obs_trace
    problems = obs_trace.reconcile(spec.trace, s.as_dict())
    assert problems == [], problems
    verifies = [e for e in spec.trace.events if e.name == "spec_verify"]
    assert len(verifies) == s.spec_rounds
    if layout == "paged":
        spec.pool.check()                 # rollback leaked no pages
        assert s.prefill_flops_saved > 0  # COW prefix reuse still fired


def test_engine_spec_fused_launch_identical(setup):
    """trace=False takes the single fused draft+verify launch (the path the
    bench times); it must stay token-identical to the traced 2-launch
    path and to non-speculative decode."""
    reqs = _requests(setup["cfg"], n=2)
    with dispatch.force_decode_attn("dequant-fp"):
        base = _engine(setup, "ring", 0, trace=False)
        base.submit_all(reqs)
        base_out = base.run()
        spec = _engine(setup, "ring", 3, trace=False)
        spec.submit_all(reqs)
        spec_out = spec.run()
    assert spec.trace is None
    for r in reqs:
        assert spec_out[r.rid].tokens == base_out[r.rid].tokens, r.rid
    assert spec.stats.spec_rounds > 0


def test_engine_spec_fused_interpret_route(setup):
    """The fused-interpret decode-attention route (the kernel program the
    TPU path runs) holds the same identity on the paged layout — the
    serve-smoke CI combination."""
    reqs = _requests(setup["cfg"], n=2)
    with dispatch.force_decode_attn("fused-interpret"):
        base = _engine(setup, "paged", 0)
        base.submit_all(reqs)
        base_out = base.run()
        spec = _engine(setup, "paged", 3)
        spec.submit_all(reqs)
        spec_out = spec.run()
    for r in reqs:
        assert spec_out[r.rid].tokens == base_out[r.rid].tokens, r.rid
    assert spec.stats.spec_draft_tokens > 0


def test_engine_spec_kv_bitwise_midflight(setup):
    """Mid-flight (before eviction wipes the slot) the speculating engine's
    cache is bitwise identical to a non-speculative engine that decoded
    the same accepted tokens — draft rows past the rejection leave no
    residue.  Paged, page_size=8, prompt 13: rounds cross page
    boundaries at rows 16 and 24, so the rollback drops partial tail
    pages."""
    rng = np.random.default_rng(3)
    req = Request(rid=0, tokens=rng.integers(
        1, setup["cfg"].vocab, size=13).astype(np.int32), max_new=16)
    with dispatch.force_decode_attn("dequant-fp"):
        spec = _engine(setup, "paged", 3, slots=1, cache_len=32)
        spec.submit(req)
        for now in range(3):               # prefill + 3 spec rounds
            assert spec.step(now)
        slot = spec.slots[0]
        assert slot is not None and not slot.done
        g = len(slot.gen)
        assert g >= 4                      # >= 1 emitted token per round

        base = _engine(setup, "paged", 0, slots=1, cache_len=32)
        base.submit(req)
        now = 0
        while base.slots[0] is None or len(base.slots[0].gen) < g:
            assert base.step(now)  # admits at step 0, then 1 token/step
            now += 1
    assert base.slots[0].gen == slot.gen
    _assert_kv_bitwise(spec.state, base.state, "midflight")


# ---------------------------------------------------------------------------
# construction-time guards
# ---------------------------------------------------------------------------
def test_spec_guards(setup):
    cfg, params, ctx = setup["cfg"], setup["params"], setup["ctx"]
    # the draft grid must reuse trained indicator-bank scales: only
    # searched bit-widths exist in the bank
    with pytest.raises(ValueError, match="searched bit set"):
        SpecSession(cfg, params, setup["policy"], ctx, draft_w_bits=7,
                    kv_quant="int8")
    # a single-policy adapter has nothing to draft with
    mono = QuantizedSession(cfg, params, setup["policy"], ctx,
                            mode="packed", kv_quant="int8")
    with pytest.raises(ValueError, match="dual-policy"):
        DecodeEngine(mono.params, cfg, None, ctx, NO_AXES,
                     EngineConfig(slots=2, cache_len=16, kv_quant="int8",
                                  speculate=2), adapter=mono)

    from repro.launch.serve import ServeConfig
    ok = ServeConfig(speculate=4, policy_path="searched.json")
    assert ok.engine_config(speculate=ok.speculate).speculate == 4
    assert ok.engine_config().speculate == 0   # reference engines never draft
    with pytest.raises(ValueError, match="--policy"):
        ServeConfig(speculate=2)
    with pytest.raises(ValueError, match="greedy"):
        ServeConfig(speculate=2, policy_path="p.json", sampling="sample")
    with pytest.raises(ValueError, match="sampling"):
        ServeConfig(sampling="nucleus")
    with pytest.raises(ValueError, match="int8"):
        ServeConfig(speculate=2, policy_path="p.json", kv="fp")
    with pytest.raises(ValueError, match="single-device"):
        ServeConfig(speculate=2, policy_path="p.json", mesh="2x4")
    with pytest.raises(ValueError, match="draft-bits"):
        ServeConfig(speculate=2, policy_path="p.json", draft_bits=1)
    with pytest.raises(ValueError, match="speculate"):
        ServeConfig(speculate=-1)


# ---------------------------------------------------------------------------
# roofline: the draft-k/verify-once round model
# ---------------------------------------------------------------------------
def test_roofline_spec_round_model(setup):
    cfg, policy, ql = setup["cfg"], setup["policy"], setup["qlayers"]
    kw = dict(cache_tokens=48, kv_bits=8.0, kv_attend="dequant",
              w_bits_total=policy.size_bytes(ql) * 8.0)
    single = roofline.decode_step_cost(cfg, 4, **kw)
    spec = roofline.decode_step_cost(cfg, 4, spec_k=4, draft_w_bits=2.0,
                                     **kw)
    # the round re-reads the tiny draft pack k times but the full target
    # pack only once; on the demo preset that beats k single steps
    assert spec["draft_hbm_bytes"] > 0 and single["draft_hbm_bytes"] == 0
    assert spec["hbm_bytes"] > single["hbm_bytes"]
    assert spec["step_s"] < 4 * single["step_s"]
    with pytest.raises(ValueError, match="spec_k"):
        roofline.decode_step_cost(cfg, 4, spec_k=-1, **kw)
    with pytest.raises(ValueError, match="sub-8-bit"):
        roofline.decode_step_cost(cfg, 4, spec_k=2, draft_w_bits=0.0, **kw)
    # a speculating engine's iteration carries more compute, so the free
    # prefill headroom per iteration cannot shrink below the single-step
    # budget on a memory-bound demo model
    chunk0 = roofline.suggest_prefill_chunk(cfg, 4, **kw)
    chunk4 = roofline.suggest_prefill_chunk(cfg, 4, spec_k=4,
                                            draft_w_bits=2.0, **kw)
    assert chunk4 >= chunk0
