"""RWKV6 / RG-LRU mixers: chunked vs sequential oracles, state carrying."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as rec


def _wkv_inputs(rng, B=2, S=64, H=2, hd=8):
    ks = jax.random.split(rng, 4)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    lw = -jax.random.uniform(ks[3], (B, S, H, hd), minval=0.02, maxval=3.0)
    u = jax.random.normal(jax.random.PRNGKey(9), (H, hd)) * 0.5
    return r, k, v, lw, u


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_wkv_chunked_matches_scan(rng, chunk):
    r, k, v, lw, u = _wkv_inputs(rng)
    S0 = jnp.zeros((2, 2, 8, 8), jnp.float32)
    y_ref, s_ref = rec.wkv_scan_ref(r, k, v, lw, u, S0)
    y, s = rec.wkv_chunked(r, k, v, lw, u, S0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-4)


def test_wkv_chunked_nonzero_initial_state(rng):
    r, k, v, lw, u = _wkv_inputs(rng, S=32)
    S0 = jax.random.normal(rng, (2, 2, 8, 8)) * 0.3
    y_ref, s_ref = rec.wkv_scan_ref(r, k, v, lw, u, S0)
    y, s = rec.wkv_chunked(r, k, v, lw, u, S0, chunk=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-4)


def test_wkv_strong_decay_stable(rng):
    """Very strong decay (log w at clamp floor) must not produce inf/nan —
    the chunked form only ever exponentiates non-positive numbers."""
    r, k, v, lw, u = _wkv_inputs(rng, S=64)
    lw = jnp.full_like(lw, rec.MIN_LOG_W)
    S0 = jnp.zeros((2, 2, 8, 8), jnp.float32)
    y, s = rec.wkv_chunked(r, k, v, lw, u, S0, chunk=16)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(s)))


def test_rglru_scan_matches_sequential(rng):
    B, S, W = 2, 33, 16
    ks = jax.random.split(rng, 2)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.1, maxval=0.99)
    bx = jax.random.normal(ks[1], (B, S, W))
    h = rec.rglru_scan(a, bx, None)
    # sequential reference
    hs = []
    prev = jnp.zeros((B, W))
    for t in range(S):
        prev = a[:, t] * prev + bx[:, t]
        hs.append(prev)
    ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_rglru_initial_state(rng):
    B, S, W = 1, 8, 4
    a = jnp.full((B, S, W), 0.5)
    bx = jnp.zeros((B, S, W))
    h0 = jnp.ones((B, W))
    h = rec.rglru_scan(a, bx.copy(), h0)
    # pure decay of h0: h_t = 0.5^{t+1}
    expect = 0.5 ** jnp.arange(1, S + 1)
    np.testing.assert_allclose(np.asarray(h[0, :, 0]), np.asarray(expect),
                               rtol=1e-5)


def test_causal_conv1d_state_carry(rng):
    B, S, W, cw = 1, 12, 4, 4
    u = jax.random.normal(rng, (B, S, W))
    w = jax.random.normal(jax.random.PRNGKey(3), (cw, W))
    b = jnp.zeros((W,))
    full, _ = rec._causal_conv1d(u, w, b, None)
    # split into two halves with carried state
    y1, st = rec._causal_conv1d(u[:, :6], w, b, None)
    y2, _ = rec._causal_conv1d(u[:, 6:], w, b, st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=1e-5, rtol=1e-5)


def test_token_shift():
    x = jnp.arange(6, dtype=jnp.float32).reshape(1, 6, 1)
    xs = rec.token_shift(x, None)
    np.testing.assert_allclose(np.asarray(xs[0, :, 0]), [0, 0, 1, 2, 3, 4])
    prev = jnp.full((1, 1, 1), 9.0)
    xs2 = rec.token_shift(x, prev)
    np.testing.assert_allclose(np.asarray(xs2[0, :, 0]), [9, 0, 1, 2, 3, 4])
