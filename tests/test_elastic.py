"""Elastic precision serving: indicator-bank fingerprinting, variant-bank
construction, the admission-time ILP controller (deterministic given
frozen signals, load-aware, hysteretic), drain-then-swap engine
invariants — property-tested over random arrival schedules on BOTH the
ring and paged KV layouts — and the swap-epoch trace reconcile."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.dist.axes import NO_AXES
from repro.launch import elastic
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.scheduler import Request, Scheduler
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.obs import metrics, trace
from repro.runtime import packing
from repro.runtime.session import ElasticSession, bank_fingerprint

CACHE_LEN = 32
SLOTS = 2
BUDGETS = (3.0, 4.0, 6.0)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    family = bank_fingerprint(params)
    bank = elastic.build_variant_bank(ql, cfg.bits, BUDGETS, family=family)
    sess = ElasticSession(cfg, params, bank.policies, ctx, active=bank.full)
    return dict(cfg=cfg, params=params, ctx=ctx, ql=ql, family=family,
                bank=bank, sess=sess)


def _requests(cfg, specs, seed=7):
    """specs: [(prompt_len, max_new, arrival_gap)] -> staggered Requests."""
    data_rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for i, (p, g, gap) in enumerate(specs):
        arrival += gap
        toks = data_rng.integers(0, cfg.vocab, size=p).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new=g, arrival=arrival))
    return reqs


def _run_elastic(setup, reqs, layout="ring"):
    """One elastic serve over the module bank; always restarts on the
    largest variant so every run sees the same downshift opportunity."""
    cfg, bank, sess = setup["cfg"], setup["bank"], setup["sess"]
    sess.set_active(bank.full)
    ctrl = elastic.ElasticController(cfg, bank, slots=SLOTS,
                                     cache_len=CACHE_LEN)
    eng = DecodeEngine(
        sess.params, cfg, None, setup["ctx"], NO_AXES,
        EngineConfig(slots=SLOTS, cache_len=CACHE_LEN, kv_quant="int8",
                     kv_layout=layout),
        adapter=sess, elastic=ctrl)
    eng.submit_all(reqs)
    return eng, ctrl, eng.run()


def _check_against_references(setup, reqs, out):
    """Every completion must be bitwise identical to its STAMPED variant's
    offline single-policy run — the elastic invariant: a swap changes who
    serves the next request, never what an admitted request decodes."""
    cfg, bank = setup["cfg"], setup["bank"]
    per_variant = {}
    for c in out.values():
        assert c.policy_id in bank.policies, c.policy_id
        per_variant.setdefault(c.policy_id, []).append(c.rid)
    for pid, rids in sorted(per_variant.items()):
        vbits = lm.bits_from_policy(cfg, bank.policies[pid])
        ref = DecodeEngine(
            setup["params"], cfg, vbits, setup["ctx"], NO_AXES,
            EngineConfig(slots=SLOTS, cache_len=CACHE_LEN, kv_quant="fake"))
        ref.submit_all([r for r in reqs if r.rid in set(rids)])
        ref_out = ref.run()
        for rid in rids:
            assert out[rid].tokens == ref_out[rid].tokens, (pid, rid)
    return per_variant


# ---------------------------------------------------------------------------
# indicator-bank fingerprint + family-stamped validate
# ---------------------------------------------------------------------------
def test_bank_fingerprint_deterministic_and_scale_sensitive(setup):
    params = setup["params"]
    assert bank_fingerprint(params) == setup["family"]
    assert len(setup["family"]) == 16

    def bump(path, leaf):
        key = str(getattr(path[-1], "key", getattr(path[-1], "name",
                                                   path[-1])))
        return leaf * 1.5 if key == "s_w" else leaf

    other = jax.tree_util.tree_map_with_path(bump, params)
    assert bank_fingerprint(other) != setup["family"]


def test_validate_accepts_family_and_rejects_foreign(setup):
    pol = next(iter(setup["bank"].policies.values()))
    assert pol.meta["indicator_family"] == setup["family"]
    pol.validate(setup["ql"], bits=setup["cfg"].bits, family=setup["family"])
    with pytest.raises(ValueError, match="family"):
        pol.validate(setup["ql"], bits=setup["cfg"].bits, family="0" * 16)
    # an unstamped policy predates the bank machinery: it must still pass
    bare = copy.deepcopy(pol)
    bare.meta.pop("indicator_family", None)
    bare.validate(setup["ql"], bits=setup["cfg"].bits, family="0" * 16)


# ---------------------------------------------------------------------------
# variant bank
# ---------------------------------------------------------------------------
def test_variant_bank_budgets_stamps_and_monotone_sizes(setup):
    bank, family = setup["bank"], setup["family"]
    assert list(bank.policies) == [elastic.variant_id(b) for b in BUDGETS]
    sizes = [bank.size_bits[pid] for pid in bank.policies]
    assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)
    assert bank.full == elastic.variant_id(max(BUDGETS))
    assert bank.floor == elastic.variant_id(min(BUDGETS))
    for budget, (pid, pol) in zip(BUDGETS, bank.policies.items()):
        assert pol.meta["policy_id"] == pid
        assert pol.meta["avg_bits_budget"] == budget
        assert pol.meta["indicator_family"] == family
        # the searched assignment respects its average-bit budget
        assert pol.avg_bits()[0] <= budget + 1e-9


def test_variant_bank_rejects_degenerate_budgets(setup):
    ql, bits = setup["ql"], setup["cfg"].bits
    with pytest.raises(ValueError):
        elastic.build_variant_bank(ql, bits, (4.0,))
    with pytest.raises(ValueError):
        elastic.build_variant_bank(ql, bits, (4.0, 4.0))
    with pytest.raises(ValueError):
        elastic.build_variant_bank(ql, bits, (4.0, 99.0))


def test_elastic_session_rejects_foreign_family_and_tiny_bank(setup):
    cfg, params, ctx = setup["cfg"], setup["params"], setup["ctx"]
    foreign = {pid: copy.deepcopy(pol)
               for pid, pol in setup["bank"].policies.items()}
    next(iter(foreign.values())).meta["indicator_family"] = "0" * 16
    with pytest.raises(ValueError, match="family"):
        ElasticSession(cfg, params, foreign, ctx)
    one = {"w4": next(iter(setup["bank"].policies.values()))}
    with pytest.raises(ValueError, match=">= 2"):
        ElasticSession(cfg, params, one, ctx)
    with pytest.raises(ValueError, match="active"):
        ElasticSession(cfg, params, setup["bank"].policies, ctx,
                       active="w99")


# ---------------------------------------------------------------------------
# admission-time controller
# ---------------------------------------------------------------------------
def test_controller_deterministic_given_frozen_signals(setup):
    bank = setup["bank"]
    ctrl = elastic.ElasticController(setup["cfg"], bank, slots=SLOTS,
                                     cache_len=CACHE_LEN)
    signals = dict(active=bank.full, queue_depth=3, occupied=SLOTS,
                   slots=SLOTS, deferred=1)
    d1 = ctrl.decide(**signals)
    d2 = ctrl.decide(**signals)
    assert d1.target == d2.target
    assert d1.budget_bits == d2.budget_bits
    assert d1.report.chosen_w == d2.report.chosen_w
    assert d1.report.chosen_a == d2.report.chosen_a
    assert d1.solve_ms > 0.0  # wall clock only enters the telemetry


def test_controller_downshifts_under_load_and_holds_upshift(setup):
    bank = setup["bank"]
    ctrl = elastic.ElasticController(setup["cfg"], bank, slots=SLOTS,
                                     cache_len=CACHE_LEN)
    idle = ctrl.decide(active=bank.full, queue_depth=0, occupied=0,
                       slots=SLOTS)
    assert idle.target == bank.full
    loaded = ctrl.decide(active=bank.full, queue_depth=6, occupied=SLOTS,
                         slots=SLOTS, deferred=2)
    assert bank.size_bits[loaded.target] < bank.size_bits[bank.full]
    # hysteresis: while ANYTHING is queued the controller never upshifts —
    # re-raising precision under backlog would immediately re-queue
    held = ctrl.decide(active=bank.floor, queue_depth=1, occupied=0,
                       slots=SLOTS)
    assert held.target == bank.floor
    clear = ctrl.decide(active=bank.floor, queue_depth=0, occupied=0,
                        slots=SLOTS)
    assert clear.target == bank.full


# ---------------------------------------------------------------------------
# drain-then-swap engine: the deterministic ramp
# ---------------------------------------------------------------------------
RAMP = [(8, 6, 0)] + [(8, 6, 1)] * 7  # one request per tick, 2 slots


def test_ramp_downshifts_drains_and_matches_references(setup, monkeypatch):
    reqs = _requests(setup["cfg"], RAMP)
    # the hot-path contract: NOTHING repacks after the session is built —
    # swaps device_put pre-packed trees
    calls = {"n": 0}
    real = packing.pack_linear

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(packing, "pack_linear", counting)
    eng, ctrl, out = _run_elastic(setup, reqs)
    assert calls["n"] == 0, "policy swap repacked weights on the hot path"

    stats = eng.stats
    assert stats.policy_swaps >= 1
    assert stats.policy_swaps_down >= 1
    assert stats.ilp_solves >= 1
    assert ctrl.max_solve_ms > 0.0
    # at least one admission round held while in-flight slots drained
    assert stats.admissions_deferred_swap >= 1
    assert sorted(out) == [r.rid for r in reqs]
    assert all(s is None for s in eng.slots)
    per_variant = _check_against_references(setup, reqs, out)
    assert len(per_variant) >= 2  # the ramp actually exercised a swap
    assert stats.active_policy == setup["sess"].active_policy
    problems = trace.reconcile(eng.trace, stats.as_dict())
    assert problems == [], problems


# ---------------------------------------------------------------------------
# satellite property: arbitrary swap points never perturb in-flight
# requests — ring AND paged layouts
# ---------------------------------------------------------------------------
@settings(max_examples=4)
@given(st.lists(st.tuples(st.sampled_from([4, 6, 8]),   # prompt length
                          st.integers(1, 4),            # max_new
                          st.integers(0, 2)),           # arrival gap
                min_size=2, max_size=7))
def test_swap_points_never_perturb_inflight_kv(setup, specs):
    """Property: whatever arrival pattern (hence whatever swap points the
    controller picks), every request completes under exactly one variant,
    its tokens bitwise match that variant's single-policy reference, and
    the KV contract holds — no slot leaks (ring) and no page-refcount
    leaks beyond the pinned prefix registry (paged)."""
    reqs = _requests(setup["cfg"], specs, seed=11)
    for layout in ("ring", "paged"):
        eng, _, out = _run_elastic(setup, reqs, layout=layout)
        assert sorted(out) == [r.rid for r in reqs], layout
        assert all(s is None for s in eng.slots), layout
        _check_against_references(setup, reqs, out)
        problems = trace.reconcile(eng.trace, eng.stats.as_dict())
        assert problems == [], (layout, problems)
        if layout == "paged":
            # every remaining reference is a prefix-registry pin: slots
            # released everything they held, swaps flushed stale chains
            pinned = sum(len(chain)
                         for chain in eng.pool._registry.values())
            assert sum(eng.pool.refcount) == pinned


# ---------------------------------------------------------------------------
# scheduler hold + engine wiring guards
# ---------------------------------------------------------------------------
def test_scheduler_hold_defers_without_dropping():
    reg = metrics.MetricsRegistry()
    sched = Scheduler(prefill_chunk=64, metrics=reg)
    sched.submit(Request(rid=0, tokens=np.zeros(4, np.int32), max_new=2))
    assert sched.admit(0, [0, 1], 0, hold=True) == []
    assert sched.has_pending()
    assert reg.value("scheduler.admissions_deferred_swap") == 1
    admitted = sched.admit(1, [0, 1], 0)
    assert [r.rid for r, _ in admitted] == [0]


def test_engine_rejects_elastic_without_bank_adapter(setup):
    cfg = setup["cfg"]
    ctrl = elastic.ElasticController(cfg, setup["bank"], slots=SLOTS,
                                     cache_len=CACHE_LEN)
    bits = lm.bits_uniform(cfg, 4)
    with pytest.raises(ValueError, match="variant-bank"):
        DecodeEngine(setup["params"], cfg, bits, setup["ctx"], NO_AXES,
                     EngineConfig(slots=SLOTS, cache_len=CACHE_LEN),
                     elastic=ctrl)


# ---------------------------------------------------------------------------
# swap-epoch trace reconcile (synthetic)
# ---------------------------------------------------------------------------
def _swap_trace(initial=True, stamp="w3", span=False):
    """Two requests: rid 0 decodes under w6, a swap to w3 lands between
    them, rid 1's first token is stamped ``stamp``. ``span`` mis-stamps
    rid 0's second token as w3 (a request crossing variants)."""
    rec = trace.TraceRecorder()
    if initial:
        rec.instant("policy_swap", ts=0.0, to="w6", initial=True,
                    iteration=-1)
    t0 = trace.req_track(0)
    rec.instant("admit", track=t0, ts=0.1, rid=0, prompt_len=4)
    rec.span("prefill", 0.1, 0.2, track=t0, rid=0)
    rec.instant("first_token", track=t0, ts=0.2, rid=0, token=1,
                policy="w6")
    rec.span("decode_step", 0.2, 0.3, slots=1)
    rec.instant("token", track=t0, ts=0.3, rid=0, token=2,
                policy="w3" if span else "w6")
    rec.instant("complete", track=t0, ts=0.3, rid=0)
    rec.instant("policy_swap", ts=0.4, to="w3", from_policy="w6",
                iteration=5)
    t1 = trace.req_track(1)
    rec.instant("admit", track=t1, ts=0.5, rid=1, prompt_len=4)
    rec.span("prefill", 0.5, 0.6, track=t1, rid=1)
    rec.instant("first_token", track=t1, ts=0.6, rid=1, token=3,
                policy=stamp)
    rec.instant("complete", track=t1, ts=0.6, rid=1)
    return rec


def _swap_stats(**over):
    base = {"t_decode_s": 0.1, "t_prefill_s": 0.2, "decode_steps": 1,
            "tokens_generated": 3, "admitted": 2, "completed": 2,
            "policy_swaps": 1, "active_policy": "w3"}
    base.update(over)
    return base


def test_reconcile_accepts_clean_swap_epochs():
    assert trace.reconcile(_swap_trace(), _swap_stats()) == []


def test_reconcile_flags_token_stamped_outside_its_epoch():
    problems = trace.reconcile(_swap_trace(stamp="w6"), _swap_stats())
    assert any("swap epoch" in p for p in problems)


def test_reconcile_flags_request_spanning_variants():
    problems = trace.reconcile(_swap_trace(span=True), _swap_stats())
    assert any("span policy variants" in p for p in problems)


def test_reconcile_flags_missing_initial_epoch_marker():
    problems = trace.reconcile(_swap_trace(initial=False), _swap_stats())
    assert any("initial" in p for p in problems)


def test_reconcile_flags_swap_count_and_active_policy_drift():
    problems = trace.reconcile(_swap_trace(), _swap_stats(policy_swaps=2))
    assert any("policy_swap events" in p for p in problems)
    problems = trace.reconcile(_swap_trace(),
                               _swap_stats(active_policy="w6"))
    assert any("active_policy" in p for p in problems)
