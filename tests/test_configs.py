"""Assigned-architecture configs must match the brief EXACTLY."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_config
from repro.configs.base import SHAPES_BY_NAME, shape_applicable

EXPECT = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
}


def test_all_ten_assigned():
    assert set(ASSIGNED_ARCHS) == set(EXPECT)


@pytest.mark.parametrize("name", sorted(EXPECT))
def test_exact_dims(name):
    cfg = get_config(name)
    L, d, H, KV, ff, V = EXPECT[name]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == ff
    assert cfg.vocab == V
    assert cfg.bits == (2, 3, 4, 5, 6)      # paper §4.1 search space


def test_family_flags():
    assert get_config("mixtral-8x7b").moe.n_experts == 8
    assert get_config("mixtral-8x7b").moe.top_k == 2
    assert get_config("deepseek-moe-16b").moe.n_experts == 64
    assert get_config("deepseek-moe-16b").moe.top_k == 6
    assert get_config("deepseek-moe-16b").moe.n_shared == 2
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("hubert-xlarge").encoder_only
    assert not get_config("hubert-xlarge").causal
    assert get_config("rwkv6-7b").family == "ssm"
    assert get_config("recurrentgemma-2b").block_pattern == ("rec", "rec", "attn")
    assert get_config("llama-3.2-vision-11b").cross_attn_every == 5


def test_shape_skip_rules():
    """DESIGN.md §5 skip list."""
    runs_500k = {"starcoder2-7b", "mixtral-8x7b", "rwkv6-7b",
                 "recurrentgemma-2b"}
    for name in EXPECT:
        cfg = get_config(name)
        ok, _ = shape_applicable(cfg, SHAPES_BY_NAME["long_500k"])
        assert ok == (name in runs_500k), name
    ok, _ = shape_applicable(get_config("hubert-xlarge"),
                             SHAPES_BY_NAME["decode_32k"])
    assert not ok


def test_smoke_configs_are_small():
    for name in EXPECT:
        cfg = smoke_config(name)
        assert cfg.d_model <= 128 and cfg.vocab <= 512
        assert cfg.n_layers <= 8


def test_cell_count():
    """40 grid cells; 33 runnable after documented skips."""
    total = runnable = 0
    for name in EXPECT:
        cfg = get_config(name)
        for sname, shape in SHAPES_BY_NAME.items():
            total += 1
            runnable += shape_applicable(cfg, shape)[0]
    assert total == 40
    assert runnable == 33
