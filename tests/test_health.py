"""Quantization health telemetry + live export + threshold monitor:
pack-time saturation/utilization math, the Prometheus/JSONL export
surfaces, edge-triggered alerting, KV-scale drift, latency attribution,
and the scheduler's page-pool deferral — all host-side, none of it
allowed to touch token identity (the serve smokes gate that end)."""
import collections

import numpy as np
import pytest

from repro.core.quantizer import bit_range
from repro.obs import export, health, monitor, trace
from repro.obs.metrics import MetricsRegistry


# ---------------------------------------------------------------------------
# pack-time site health
# ---------------------------------------------------------------------------
def _self_calibrated(w, bits):
    """Per-channel scales from the weights themselves (max|w| / qmax) —
    'packed from its own calibration data', the zero-saturation case."""
    qmax = bit_range(bits, True)[1]
    return np.abs(w).max(axis=tuple(range(w.ndim - 1))) / qmax


def test_site_health_zero_saturation_on_self_calibrated_scale():
    rng = np.random.default_rng(0)
    for bits in (2, 4, 8):
        w = rng.normal(size=(16, 24)).astype(np.float32)
        h = health.site_health(w, bits, _self_calibrated(w, bits))
        assert h["saturation_rate"] == 0.0
        assert h["n_saturated"] == 0
        # the covering scale is tight: utilization ~1 by construction
        assert h["scale_utilization"] == pytest.approx(1.0, rel=1e-5)
        assert h["n_values"] == w.size and h["w_bits"] == bits


def test_site_health_counts_clipped_values():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(32, 32)).astype(np.float32)
    s = _self_calibrated(w, 4) * 0.25  # undersized scale -> clipping
    h = health.site_health(w, 4, s)
    assert h["saturation_rate"] > 0.0
    assert h["scale_utilization"] > 1.0
    assert h["n_saturated"] == round(h["saturation_rate"] * h["n_values"])


def test_site_health_edge_values_not_saturated():
    # a value landing exactly ON qmax rounds inside the grid: not clipped
    qmax = bit_range(4, True)[1]
    w = np.array([[1.0 * qmax, -1.0 * qmax, 0.5]])
    h = health.site_health(w, 4, np.float32(1.0))
    assert h["saturation_rate"] == 0.0
    assert h["scale_utilization"] == pytest.approx(1.0)
    # just past the round-boundary it IS clipped
    h2 = health.site_health(np.array([[qmax + 0.51]]), 4, np.float32(1.0))
    assert h2["n_saturated"] == 1


def test_pack_summary_and_publish():
    rng = np.random.default_rng(2)
    sites = {}
    for i, bits in enumerate((2, 4, 8)):
        w = rng.normal(size=(8, 8)).astype(np.float32)
        s = _self_calibrated(w, bits) * (0.5 if i == 0 else 1.0)
        sites[f"L{i}.w"] = health.site_health(w, bits, s)
    summary = health.pack_summary(sites)
    assert summary["sites"] == 3
    assert summary["saturation_rate_max"] == max(
        h["saturation_rate"] for h in sites.values())
    reg = MetricsRegistry()
    published = health.publish_pack_health(reg, sites)
    assert published == summary
    assert reg.value("quant.saturation_rate_max") == \
        summary["saturation_rate_max"]
    assert reg.value("quant.scale_utilization_p50") == \
        summary["scale_utilization_p50"]
    for name in sites:
        assert f"quant.saturation_rate.{name}" in reg
    assert reg.get("quant.saturation_rate").count == 3
    assert reg.get("quant.scale_utilization").count == 3


def test_pack_summary_empty():
    s = health.pack_summary({})
    assert s["sites"] == 0 and s["saturation_rate_max"] == 0.0


# ---------------------------------------------------------------------------
# KV-scale drift
# ---------------------------------------------------------------------------
FakeCache = collections.namedtuple("FakeCache", ["k_scale", "v_scale"])


def test_kv_scale_drift_tracks_population_mean():
    d = health.KVScaleDrift()
    tree = {"a": FakeCache(np.full((4, 8), 0.5, np.float32),
                           np.full((4, 8), 0.5, np.float32))}
    assert d.update(tree) is None           # first sample: no baseline
    assert d.update(tree) == pytest.approx(0.0)   # stationary: ~0 drift
    shifted = {"a": FakeCache(np.full((4, 8), 1.0, np.float32),
                              np.full((4, 8), 1.0, np.float32))}
    assert d.update(shifted) == pytest.approx(1.0)  # mean doubled
    assert d.last["rows"] == 64
    reg = MetricsRegistry()
    d.publish(reg, 1.0)
    assert reg.value("quant.kv_scale_mean") == pytest.approx(1.0)
    assert reg.value("quant.kv_scale_drift_max") == pytest.approx(1.0)
    d.publish(reg, 0.25)                     # running max keeps the worst
    assert reg.value("quant.kv_scale_drift_max") == pytest.approx(1.0)


def test_kv_scale_drift_ignores_zero_rows_and_fp_caches():
    d = health.KVScaleDrift()
    # unwritten rows hold scale 0 — they must not drag the mean down
    half = np.zeros((2, 8), np.float32)
    half[0] = 0.5
    tree = [FakeCache(half, half), {"fp": np.zeros(3)}]
    assert d.update(tree) is None
    assert d.last["rows"] == 16              # only the nonzero rows
    assert d.update({"empty": np.zeros(3)}) is None  # no caches at all


# ---------------------------------------------------------------------------
# latency attribution + roofline drift
# ---------------------------------------------------------------------------
def test_attribute_latency_routes_to_histograms():
    reg = MetricsRegistry()
    health.attribute_latency(reg, "matmul", "packed-int8", 0.002)
    health.attribute_latency(reg, "matmul", "fp", 0.004)
    health.attribute_latency(reg, "matmul", "packed-int8", 0.003)
    h = reg.get("dispatch.latency_ms.matmul.packed-int8")
    assert h.count == 2 and h.sum == pytest.approx(5.0)
    assert reg.get("dispatch.latency_ms.matmul.fp").count == 1


def test_roofline_drift_worst_factor_both_directions():
    rows = [{"phase": "a", "ratio": 4.0}, {"phase": "b", "ratio": 0.1},
            {"phase": "c", "ratio": float("nan")}]
    assert health.roofline_drift(rows) == pytest.approx(10.0)
    assert health.roofline_drift([]) == 1.0
    assert health.roofline_drift([{"ratio": 1.0}]) == 1.0


def test_dominant_route_from_registry():
    from repro.runtime import dispatch
    reg = MetricsRegistry()
    assert dispatch.dominant_route(reg) == "fp"   # nothing counted yet
    reg.counter("dispatch.route.fp").inc(2)
    reg.counter("dispatch.route.packed-int8").inc(5)
    assert dispatch.dominant_route(reg) == "packed-int8"
    reg.counter("dispatch.decode_attn.fused-interpret").inc()
    assert dispatch.dominant_route(reg, "decode_attn") == "fused-interpret"


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
def _demo_registry():
    reg = MetricsRegistry()
    reg.counter("engine.decode_steps", help="steps").inc(7)
    reg.gauge("engine.kv_pool_free_pages").set(3)
    h = reg.histogram("engine.decode_step_ms", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 9.0):
        h.observe(v)
    return reg


def test_prometheus_text_parses_and_matches_snapshot():
    reg = _demo_registry()
    text = export.prometheus_text(reg)
    samples = export.samples_as_dict(export.parse_prometheus_text(text))
    assert samples["repro_engine_decode_steps_total"] == 7.0
    assert samples["repro_engine_kv_pool_free_pages"] == 3.0
    # histogram: cumulative buckets, +Inf == count, sum matches registry
    buckets = samples["repro_engine_decode_step_ms_bucket"]
    assert buckets[(("le", "1"),)] == 1.0
    assert buckets[(("le", "2"),)] == 2.0
    assert buckets[(("le", "4"),)] == 3.0
    assert buckets[(("le", "+Inf"),)] == 4.0
    assert samples["repro_engine_decode_step_ms_count"] == 4.0
    snap = reg.snapshot()
    assert samples["repro_engine_decode_step_ms_sum"] == \
        pytest.approx(snap["engine.decode_step_ms"]["sum"])
    assert samples["repro_engine_decode_steps_total"] == \
        snap["engine.decode_steps"]
    # help/type comment lines present
    assert "# HELP repro_engine_decode_steps_total steps" in text
    assert "# TYPE repro_engine_decode_step_ms histogram" in text


def test_prometheus_line_format_is_strict():
    export.parse_prometheus_text("ok_metric 1.0\n# comment\n")
    with pytest.raises(ValueError):
        export.parse_prometheus_text("bad metric line\n")
    with pytest.raises(ValueError):
        export.parse_prometheus_text('m{le=unquoted} 1\n')


def test_prom_name_sanitizes_dots():
    assert export.prom_name("engine.decode_steps") == \
        "repro_engine_decode_steps"
    assert export.prom_name("a-b c", prefix="") == "a_b_c"
    # every emitted name must satisfy the prometheus grammar
    reg = _demo_registry()
    for name, _, _ in export.parse_prometheus_text(
            export.prometheus_text(reg)):
        assert export.prom_name(name, prefix="") == name


def test_write_prometheus_round_trips(tmp_path):
    reg = _demo_registry()
    path = str(tmp_path / "m.prom")
    text = export.write_prometheus(reg, path)
    assert open(path).read() == text
    assert export.parse_prometheus_text(text)


# ---------------------------------------------------------------------------
# JSONL metrics streamer
# ---------------------------------------------------------------------------
def test_streamer_emits_first_tick_and_close(tmp_path):
    reg = _demo_registry()
    path = str(tmp_path / "s.jsonl")
    s = export.MetricsStreamer(path, interval_s=10.0)
    assert s.tick(reg, now=0.0)          # first tick always emits
    assert not s.tick(reg, now=1.0)      # inside the interval: gated
    reg.counter("engine.decode_steps").inc()
    s.close(reg, now=2.0)                # close force-emits the final state
    snaps = export.read_jsonl_snapshots(path)
    assert len(snaps) >= 2
    assert [o["seq"] for o in snaps] == list(range(len(snaps)))
    assert snaps[0]["metrics"]["engine.decode_steps"] == 7.0
    assert snaps[-1]["metrics"]["engine.decode_steps"] == 8.0
    assert not s.tick(reg)               # closed stream: inert


def test_streamer_interval_gating(tmp_path):
    reg = _demo_registry()
    s = export.MetricsStreamer(str(tmp_path / "s.jsonl"), interval_s=0.5)
    assert s.tick(reg, now=0.0)
    assert not s.tick(reg, now=0.4)
    assert s.tick(reg, now=0.5)          # interval elapsed
    s.close(reg, now=0.6)
    assert s.seq == 3
    with pytest.raises(ValueError):
        export.MetricsStreamer(str(tmp_path / "x.jsonl"), interval_s=-1)


def test_read_jsonl_snapshots_rejects_gaps(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write('{"ts": 0.0, "seq": 0, "metrics": {}}\n')
        f.write('{"ts": 1.0, "seq": 2, "metrics": {}}\n')
    with pytest.raises(ValueError):
        export.read_jsonl_snapshots(path)
    with open(path, "w") as f:
        f.write('{"ts": 0.0, "seq": 0}\n')
    with pytest.raises(ValueError):
        export.read_jsonl_snapshots(path)


# ---------------------------------------------------------------------------
# threshold monitor
# ---------------------------------------------------------------------------
def test_watcher_fires_exactly_at_boundary():
    reg = MetricsRegistry()
    w = monitor.saturation_watcher(ceiling=0.25)
    reg.gauge("quant.saturation_rate_max").set(0.2499)
    assert w.evaluate(reg) is None
    reg.gauge("quant.saturation_rate_max").set(0.25)   # inclusive: fires
    assert w.evaluate(reg) == pytest.approx(0.25)
    pool = monitor.pool_pressure_watcher(2.0)
    reg.gauge("engine.kv_pool_available_pages").set(3)
    assert pool.evaluate(reg) is None
    reg.gauge("engine.kv_pool_available_pages").set(2)  # at the floor
    assert pool.evaluate(reg) == pytest.approx(2.0)


def test_watcher_skips_unregistered_metric():
    reg = MetricsRegistry()
    w = monitor.roofline_drift_watcher(8.0)
    assert w.evaluate(reg) is None        # gauge never set: never fires
    with pytest.raises(ValueError):
        monitor.Watcher("bad", "m", "==", 1.0)


def test_monitor_edge_triggered_alerts_into_registry_and_trace():
    reg = MetricsRegistry()
    rec = trace.TraceRecorder()
    mon = monitor.Monitor([monitor.saturation_watcher(0.25)])
    g = reg.gauge("quant.saturation_rate_max")

    g.set(0.1)
    assert mon.check(reg, rec) == []
    g.set(0.3)
    fired = mon.check(reg, rec, now=1.0)
    assert len(fired) == 1
    a = fired[0]
    assert a.name == "saturation_ceiling" and a.severity == "critical"
    assert a.value == pytest.approx(0.3) and a.ts == 1.0
    # still violating: edge-triggered, no second alert
    assert mon.check(reg, rec) == []
    # clears, re-arms, fires again on the next violation
    g.set(0.2)
    assert mon.check(reg, rec) == []
    g.set(0.4)
    assert len(mon.check(reg, rec, now=2.0)) == 1
    # alerts land in the registry counters...
    assert reg.value(monitor.ALERTS_FIRED) == 2.0
    assert reg.value(f"{monitor.ALERTS_FIRED}.saturation_ceiling") == 2.0
    # ...in the monitor's own record...
    assert mon.fired_count == 2
    assert [d["value"] for d in mon.as_dicts()] == [
        pytest.approx(0.3), pytest.approx(0.4)]
    # ...and as instant events on the engine track of the trace
    alerts = [e for e in rec.events if e.name == "alert"]
    assert len(alerts) == 2
    assert alerts[0].track == trace.ENGINE_TRACK
    assert alerts[0].args["watcher"] == "saturation_ceiling"
    assert alerts[0].args["metric"] == "quant.saturation_rate_max"


def test_default_monitor_watcher_set():
    mon = monitor.default_monitor()
    assert {w.name for w in mon.watchers} == \
        {"saturation_ceiling", "roofline_drift"}
    mon = monitor.default_monitor(pool_min_free=1)
    assert {w.name for w in mon.watchers} == \
        {"saturation_ceiling", "roofline_drift", "pool_pressure"}


# ---------------------------------------------------------------------------
# scheduler page-pool deferral (pure python: no engine needed)
# ---------------------------------------------------------------------------
def test_scheduler_defers_admission_on_pool_pressure():
    from repro.launch.scheduler import Request, Scheduler
    reg = MetricsRegistry()
    sch = Scheduler("continuous", prefill_chunk=100, metrics=reg)
    for i in range(3):
        sch.submit(Request(rid=i, tokens=np.arange(4, dtype=np.int32),
                           max_new=2))
    # pool can cover one admission (need 2 of 3 obtainable), not two
    out = sch.admit(0, free_slots=[0, 1, 2], occupied=0,
                    page_budget=3, page_need=2)
    assert len(out) == 1
    assert reg.value("scheduler.admissions_deferred_pool") == 1.0
    # pressure released: the deferred requests admit in FIFO order
    out = sch.admit(1, free_slots=[1, 2], occupied=1,
                    page_budget=10, page_need=2)
    assert [r.rid for r, _ in out] == [1, 2]
    assert reg.value("scheduler.admissions_deferred_pool") == 1.0
    # no budget passed (ring layout): pressure check is inert
    sch.submit(Request(rid=9, tokens=np.arange(4, dtype=np.int32),
                       max_new=2))
    assert len(sch.admit(2, free_slots=[0], occupied=2)) == 1


def test_pagepool_available_counts_reclaimable():
    from repro.runtime.kv_cache import PagePool
    pool = PagePool(n_pages=4, page_size=8)
    a = pool.alloc(1)
    b = pool.alloc(1)
    assert pool.free_count == 2
    assert pool.reclaimable_count == 0       # live refs: not evictable
    assert pool.available_count == 2
    # registry-only pins are LRU-evictable -> reclaimable
    pool.register_prefix([b"k1"], a)
    pool.release(b)
    pool.release(a)                          # a survives via its pin
    assert pool.free_count == 3
    assert pool.reclaimable_count == 1
    assert pool.available_count == 4


# ---------------------------------------------------------------------------
# prefix_hit trace events reconcile against the stats counters
# ---------------------------------------------------------------------------
def _paged_trace(with_hit_event=True):
    rec = trace.TraceRecorder()
    tr = trace.req_track(0)
    rec.instant("admit", track=tr, ts=0.0, rid=0, prompt_len=8,
                prefix_hit_tokens=8)
    if with_hit_event:
        rec.instant("prefix_hit", track=tr, ts=0.0, rid=0, pages_reused=1,
                    tokens=8, flops_saved=100.0)
    rec.instant("first_token", track=tr, ts=0.1, rid=0, token=1)
    rec.span("decode_step", 0.1, 0.2, slots=1)
    rec.instant("token", track=tr, ts=0.2, rid=0, token=2)
    rec.instant("complete", track=tr, ts=0.2, rid=0)
    return rec


def test_reconcile_accepts_matching_prefix_hits():
    stats = {"t_decode_s": 0.1, "t_prefill_s": 0.0, "decode_steps": 1,
             "tokens_generated": 2, "admitted": 1, "completed": 1,
             "prefix_hit_tokens": 8, "prefill_flops_saved": 100.0}
    assert trace.reconcile(_paged_trace(), stats) == []


def test_reconcile_flags_prefix_hit_mismatches():
    stats = {"t_decode_s": 0.1, "t_prefill_s": 0.0, "decode_steps": 1,
             "tokens_generated": 2, "admitted": 1, "completed": 1,
             "prefix_hit_tokens": 8, "prefill_flops_saved": 100.0}
    # a remap admission with no prefix_hit event is under-counted
    problems = trace.reconcile(_paged_trace(with_hit_event=False), stats)
    assert any("prefix_hit" in p for p in problems)
    # token/FLOP totals diverging from the counters is flagged too
    bad = dict(stats, prefix_hit_tokens=4, prefill_flops_saved=50.0)
    problems = trace.reconcile(_paged_trace(), bad)
    assert any("prefix_hit tokens" in p for p in problems)
    assert any("flops_saved" in p for p in problems)


# ---------------------------------------------------------------------------
# packed session: health computed from the scales packing actually used
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_session_pack_health_zero_saturation_per_channel():
    """per_channel packing derives scales from the weights themselves
    (max|w|/qmax) — its own calibration data — so saturation is exactly
    zero at every site and the saturation watcher can never fire."""
    import jax
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.core.policy import MPQPolicy
    from repro.models import lm
    from repro.models.quant_layers import QuantContext
    from repro.runtime.session import QuantizedSession

    cfg = smoke_config("limpq-demo")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    policy = MPQPolicy.uniform(ql, 4)
    sess = QuantizedSession(cfg, params, policy, ctx, mode="packed",
                            kv_quant="int8", per_channel=True)
    assert len(sess.pack_health) == len(ql)
    for name, h in sess.pack_health.items():
        assert h["saturation_rate"] == 0.0, (name, h)
        assert h["scale_utilization"] <= 1.0 + 1e-6, (name, h)
    summary = health.pack_summary(sess.pack_health)
    assert summary["saturation_rate_max"] == 0.0
    reg = MetricsRegistry()
    health.publish_pack_health(reg, sess.pack_health)
    mon = monitor.default_monitor()
    assert mon.check(reg) == []
