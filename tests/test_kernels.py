"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype
sweeps + gradient equivalence with the core STE composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizer as qz
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(64, 130), (7, 257), (300,), (4, 33, 65),
                                   (1, 1), (513,)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fake_quant_forward(nprng, shape, bits):
    v = jnp.asarray(nprng.standard_normal(shape), jnp.float32)
    s = jnp.asarray(0.07, jnp.float32)
    qmin, qmax = qz.bit_range(bits, True)
    out = ops.fake_quant(v, s, float(qmin), float(qmax))
    expect = ref.fake_quant_ref(v, s, qmin, qmax)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fake_quant_dtypes(nprng, dtype):
    v = jnp.asarray(nprng.standard_normal((32, 256)), dtype)
    s = jnp.asarray(0.1, jnp.float32)
    out = ops.fake_quant(v, s, -8.0, 7.0)
    # the kernel divides/rounds in f32 regardless of storage dtype, so the
    # oracle must too (bf16-division boundary cases differ by one grid step)
    expect = ref.fake_quant_ref(v.astype(jnp.float32), s, -8, 7).astype(dtype)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=0)


def test_fake_quant_grads_match_core(nprng):
    """Kernel custom-vjp == autodiff of the STE composition in core."""
    v = jnp.asarray(nprng.standard_normal((48, 96)), jnp.float32)
    s = jnp.asarray(0.09, jnp.float32)
    qmin, qmax = -8.0, 7.0

    def f_kernel(v, s):
        return jnp.sum(jnp.cos(ops.fake_quant(v, s, qmin, qmax)))

    def f_core(v, s):
        return jnp.sum(jnp.cos(qz.fake_quant(v, s, qmin, qmax)))

    gv1, gs1 = jax.grad(f_kernel, argnums=(0, 1))(v, s)
    gv2, gs2 = jax.grad(f_core, argnums=(0, 1))(v, s)
    np.testing.assert_allclose(np.asarray(gv1), np.asarray(gv2), atol=1e-6)
    np.testing.assert_allclose(float(gs1), float(gs2), rtol=1e-3)


def test_fake_quant_bwd_vs_ref_formula(nprng):
    v = jnp.asarray(nprng.standard_normal((33, 65)) * 3, jnp.float32)
    s = jnp.asarray(0.2, jnp.float32)
    g = jnp.asarray(nprng.standard_normal((33, 65)), jnp.float32)
    _, vjp = jax.vjp(lambda v_, s_: ops.fake_quant(v_, s_, -4.0, 3.0), v, s)
    dv, ds = vjp(g)
    dv_ref, ds_ref = ref.fake_quant_grads_ref(v, s, g, -4, 3)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_ref), atol=1e-6)
    np.testing.assert_allclose(float(ds), float(ds_ref), rtol=1e-3)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mkn", [(64, 128, 64), (100, 300, 50),
                                 (257, 513, 129), (8, 1024, 16), (1, 128, 1)])
def test_quant_matmul_exact(nprng, mkn):
    M, K, N = mkn
    xq = jnp.asarray(nprng.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(nprng.integers(-127, 128, (K, N)), jnp.int8)
    sx, sw = jnp.float32(0.02), jnp.float32(0.005)
    out = ops.quant_matmul(xq, wq, sx, sw, blocks=(64, 64, 128))
    expect = ref.quant_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=0,
                               atol=0)


def test_quant_matmul_matches_fake_quant_path(nprng):
    """The deployment contract: int8 execution == fake-quant training graph
    when bits <= 8 (paper's deployability argument, TPU form)."""
    K, N = 96, 64
    w = jnp.asarray(nprng.standard_normal((K, N)), jnp.float32)
    x = jnp.asarray(nprng.standard_normal((8, K)), jnp.float32)
    s_w = jnp.float32(0.05)
    s_x = jnp.float32(0.11)
    qmin, qmax = qz.bit_range(4, True)
    # training graph: fake-quant both, f32 matmul
    ref_out = qz.fake_quant(x, s_x, qmin, qmax) @ qz.fake_quant(w, s_w, qmin, qmax)
    # deployment: int8 codes + fused kernel
    xq = ops.quantize_int8(x, s_x, bits=4)
    wq = ops.quantize_int8(w, s_w, bits=4)
    out = ops.quant_matmul(xq, wq, s_x, s_w, blocks=(8, 96, 64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv wkv
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bshd", [(2, 64, 2, 8), (1, 96, 4, 16),
                                  (3, 32, 1, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv_kernel_vs_ref(nprng, bshd, chunk):
    B, S, H, hd = bshd
    if S % chunk:
        pytest.skip("S % chunk != 0")
    r, k, v = (jnp.asarray(nprng.standard_normal((B, S, H, hd)), jnp.float32)
               for _ in range(3))
    lw = -jnp.asarray(nprng.uniform(0.01, 2.0, (B, S, H, hd)), jnp.float32)
    u = jnp.asarray(nprng.standard_normal((H, hd)), jnp.float32) * 0.5
    y = ops.wkv(r, k, v, lw, u, chunk=chunk)
    ye = ref.wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4,
                               rtol=2e-4)


def test_wkv_kernel_strong_decay(nprng):
    B, S, H, hd = 1, 32, 2, 8
    r, k, v = (jnp.asarray(nprng.standard_normal((B, S, H, hd)), jnp.float32)
               for _ in range(3))
    lw = jnp.full((B, S, H, hd), -8.0)
    u = jnp.zeros((H, hd), jnp.float32)
    y = ops.wkv(r, k, v, lw, u, chunk=16)
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# flash attention forward kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_flash_fwd_kernel_vs_direct(nprng, causal, window):
    from repro.models import attention as attn
    B, S, H, KV, hd = 2, 256, 4, 2, 32
    G = H // KV
    q = jnp.asarray(nprng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(nprng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(nprng.standard_normal((B, S, KV, hd)), jnp.float32)
    qr = q.reshape(B, S, KV, G, hd) * hd ** -0.5
    out, lse = ops.flash_fwd(qr, k, v, causal=causal, window=window,
                             q_block=64, kv_block=64)
    pos = jnp.arange(S)
    ref_out = attn.direct_attention(q, k, v, pos, pos, causal=causal,
                                    window=window)
    np.testing.assert_allclose(np.asarray(out.reshape(B, S, H, hd)),
                               np.asarray(ref_out), atol=2e-5, rtol=2e-5)
    # lse must match the pure-JAX fwd (it feeds the recompute backward)
    _, lse_ref = attn._flash_fwd_lse(qr, k, v, causal=causal, window=window,
                                     q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=1e-5, rtol=1e-5)
