"""Synthetic data pipeline: determinism, host sharding, skip-to-step."""
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticLM


def test_deterministic():
    cfg = get_config("limpq-demo")
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    b1 = d1.batch(3, 4, 32)
    b2 = d2.batch(3, 4, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_steps_differ():
    cfg = get_config("limpq-demo")
    d = SyntheticLM(cfg)
    assert not np.array_equal(d.batch(0, 4, 32)["tokens"],
                              d.batch(1, 4, 32)["tokens"])


def test_host_sharding_disjoint_and_consistent():
    """Union of per-host slices == the global batch (elastic restart can
    re-slice without replay)."""
    cfg = get_config("limpq-demo")
    d = SyntheticLM(cfg)
    full = d.batch(7, 8, 16)["tokens"]
    parts = [d.batch(7, 8, 16, host_id=h, n_hosts=4)["tokens"]
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_skip_to_step():
    cfg = get_config("limpq-demo")
    d = SyntheticLM(cfg)
    seq = list(d.batches(5, 2, 16))
    restarted = list(d.batches(2, 2, 16, start_step=3))
    np.testing.assert_array_equal(seq[3]["tokens"], restarted[0]["tokens"])
    np.testing.assert_array_equal(seq[4]["tokens"], restarted[1]["tokens"])


def test_learnable_structure():
    """The Markov grammar must make next-token prediction beatable: the
    empirical bigram entropy is well below the unigram entropy."""
    cfg = get_config("limpq-demo")
    d = SyntheticLM(cfg, DataConfig(markov_weight=0.8))
    toks = d.batch(0, 16, 256)["tokens"].reshape(-1)
    # top-8 successor mass of the most common token
    tok0 = np.bincount(toks).argmax()
    succ = toks[1:][toks[:-1] == tok0]
    top8 = np.sort(np.bincount(succ, minlength=cfg.vocab))[-8:].sum()
    assert top8 / max(len(succ), 1) > 0.5     # successors are concentrated


def test_audio_and_vlm_inputs():
    cfg = smoke_config("hubert-xlarge")
    d = SyntheticLM(cfg)
    b = d.batch(0, 2, 16)
    assert b["feats"].shape == (2, 16, 512)
    assert b["labels"].shape == (2, 16)
    assert b["labels"].max() < cfg.vocab

    cfgv = smoke_config("llama-3.2-vision-11b")
    dv = SyntheticLM(cfgv)
    bv = dv.batch(0, 2, 16)
    assert bv["img"].shape == (2, cfgv.n_image_tokens, 1280)
