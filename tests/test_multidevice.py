"""Multi-device integration (subprocess with 8 placeholder devices):

1. the SHARDED train step (real mesh, partition rules, in_shardings,
   with_sharding_constraint hints) produces the same loss and the same
   updated params as single-device execution — the distribution layer is
   numerics-preserving;
2. a checkpoint written from one mesh restores onto a DIFFERENT mesh
   (elastic scaling) and reproduces the loss exactly.

Runs in a subprocess so the main pytest process keeps exactly 1 device.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim, training
from repro.configs import smoke_config
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.dist import sharding
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext

cfg = smoke_config("qwen3-0.6b")
rng = jax.random.PRNGKey(0)
params = lm.init_params(rng, cfg)
ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                        compute_dtype=jnp.float32)
data = SyntheticLM(cfg)
batch = {k: jnp.asarray(v) for k, v in data.batch(0, 4, 64).items()}
bits = lm.bits_uniform(cfg, 2)
opt = optim.adamw(1e-3, clip_norm=1.0)

# ---- single-device reference ----------------------------------------------
step_ref = training.make_train_step(cfg, ctx, opt, bits, NO_AXES, remat=False)
p_ref, _, m_ref = step_ref(params, opt.init(params), batch)
loss_ref = float(m_ref["loss"])

# ---- sharded: 2-way data x 4-way model --------------------------------------
mesh = jax.make_mesh((2, 4), ("data", "model"))
axes = sharding.make_axes_for(cfg, mesh, shard_seq=False)
pspecs = sharding.param_specs(cfg, params, axes)
bspecs = sharding.batch_specs(cfg, batch, axes)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))

step = training.make_train_step(cfg, ctx, opt, bits, axes, remat=False)
with mesh:
    params_s = jax.device_put(params, named(pspecs))
    batch_s = jax.device_put(batch, named(bspecs))
    jitted = jax.jit(step, in_shardings=(named(pspecs), None, named(bspecs)),
                     out_shardings=(named(pspecs), None, None))
    p_new, _, m = jitted(params_s, opt.init(params), batch_s)
loss_sharded = float(m["loss"])
assert abs(loss_sharded - loss_ref) < 1e-4, (loss_sharded, loss_ref)

# updated params match the single-device step
for path, a in jax.tree_util.tree_flatten_with_path(p_new)[0]:
    b = p_ref
    for k in path:
        b = b[getattr(k, "key", getattr(k, "idx", None))]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                               rtol=2e-3)

# ---- elastic restore onto a DIFFERENT mesh ---------------------------------
import tempfile
ckdir = tempfile.mkdtemp()
mgr = CheckpointManager(ckdir)
mgr.save(0, p_new, blocking=True)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))      # reshaped topology
axes2 = sharding.make_axes_for(cfg, mesh2, shard_seq=False)
pspecs2 = sharding.param_specs(cfg, params, axes2)
flat_specs = {}
for path, spec in jax.tree_util.tree_flatten_with_path(
        pspecs2, is_leaf=lambda x: isinstance(x, P))[0]:
    key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                   for k in path)
    flat_specs[key] = spec
with mesh2:
    restored = mgr.restore(0, params, sharding_fn=lambda p: NamedSharding(
        mesh2, flat_specs[p]))
    loss2, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b, bits, ctx, axes2,
                                               remat=False))(restored, batch)
# same params -> same loss as the post-step eval on mesh 1
with mesh:
    loss1, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b, bits, ctx, axes,
                                               remat=False))(p_new, batch_s)
assert abs(float(loss1) - float(loss2)) < 1e-4, (float(loss1), float(loss2))
print("MULTIDEVICE_OK", loss_ref, loss_sharded)
"""


@pytest.mark.slow
def test_sharded_step_matches_single_device_and_elastic_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MULTIDEVICE_OK" in out.stdout, (out.stdout[-1000:],
                                            out.stderr[-3000:])


# ---------------------------------------------------------------------------
# quantized serving under a real mesh (8 host devices, 2-way dp x 4-way tp)
# ---------------------------------------------------------------------------
_QSERVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax

from repro.dist import sharding
from repro.runtime import packing, sharded_smoke

ref, sharded = sharded_smoke.run_sharded_vs_single()
sess, eng, axes = sharded["session"], sharded["engine"], sharded["axes"]
got = sharded["tokens"]
assert axes.tp_size == 4 and axes.dp_size == 2, (axes.tp_size, axes.dp_size)

# (b) greedy tokens identical to the single-device session
assert got == ref, {r: (ref[r], got[r]) for r in ref if ref[r] != got[r]}

# (a) per-shard packed bytes ~= policy.size_bytes / tp within padding
# (every limpq-demo dim divides, so the plan budget equals the ideal)
per_shard = sess.packed_bytes(per_shard=True)
budget = sess.policy.size_bytes(sess.qlayers, per_shard=axes.tp_size)
assert budget == sess.per_shard_policy_bytes(), "demo arch must fully shard"
assert per_shard <= budget * 1.05, (per_shard, budget)
assert per_shard * axes.tp_size <= sess.packed_bytes() * 1.01

# (c) no replicated codes leaf, in the specs or on the devices
specs = sharding.packed_specs(sharded["cfg"], sess.params, axes)
spec_leaves = [s for s in jax.tree.leaves(specs, is_leaf=packing.is_packed)
               if packing.is_packed(s)]
assert spec_leaves
for s in spec_leaves:
    assert any(e is not None for e in tuple(s.codes)), s
placed = [p for p in jax.tree.leaves(eng.params, is_leaf=packing.is_packed)
          if packing.is_packed(p)]
assert placed
for p in placed:
    assert not p.codes.sharding.is_fully_replicated, p.shape
    assert p.shard_count == axes.tp_size, (p.shape, p.shard_count)

print("QSERVE_MESH_OK", per_shard, int(budget))
"""


@pytest.mark.slow
def test_quantized_serving_sharded_over_host_mesh():
    """Tentpole gate (ISSUE 4): the packed session under a 2x4 host mesh
    serves greedy-token-identically to the single-device session, its
    codes shard over tp (nothing replicates), and per-chip packed bytes
    land on ``policy.size_bytes / tp`` within padding."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _QSERVE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "QSERVE_MESH_OK" in out.stdout, (out.stdout[-1000:],
                                            out.stderr[-3000:])
