"""Multi-device integration (subprocess with 8 placeholder devices):

1. the SHARDED train step (real mesh, partition rules, in_shardings,
   with_sharding_constraint hints) produces the same loss and the same
   updated params as single-device execution — the distribution layer is
   numerics-preserving;
2. a checkpoint written from one mesh restores onto a DIFFERENT mesh
   (elastic scaling) and reproduces the loss exactly.

Runs in a subprocess so the main pytest process keeps exactly 1 device.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim, training
from repro.configs import smoke_config
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.dist import sharding
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext

cfg = smoke_config("qwen3-0.6b")
rng = jax.random.PRNGKey(0)
params = lm.init_params(rng, cfg)
ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                        compute_dtype=jnp.float32)
data = SyntheticLM(cfg)
batch = {k: jnp.asarray(v) for k, v in data.batch(0, 4, 64).items()}
bits = lm.bits_uniform(cfg, 2)
opt = optim.adamw(1e-3, clip_norm=1.0)

# ---- single-device reference ----------------------------------------------
step_ref = training.make_train_step(cfg, ctx, opt, bits, NO_AXES, remat=False)
p_ref, _, m_ref = step_ref(params, opt.init(params), batch)
loss_ref = float(m_ref["loss"])

# ---- sharded: 2-way data x 4-way model --------------------------------------
mesh = jax.make_mesh((2, 4), ("data", "model"))
axes = sharding.make_axes_for(cfg, mesh, shard_seq=False)
pspecs = sharding.param_specs(cfg, params, axes)
bspecs = sharding.batch_specs(cfg, batch, axes)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))

step = training.make_train_step(cfg, ctx, opt, bits, axes, remat=False)
with mesh:
    params_s = jax.device_put(params, named(pspecs))
    batch_s = jax.device_put(batch, named(bspecs))
    jitted = jax.jit(step, in_shardings=(named(pspecs), None, named(bspecs)),
                     out_shardings=(named(pspecs), None, None))
    p_new, _, m = jitted(params_s, opt.init(params), batch_s)
loss_sharded = float(m["loss"])
assert abs(loss_sharded - loss_ref) < 1e-4, (loss_sharded, loss_ref)

# updated params match the single-device step
for path, a in jax.tree_util.tree_flatten_with_path(p_new)[0]:
    b = p_ref
    for k in path:
        b = b[getattr(k, "key", getattr(k, "idx", None))]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                               rtol=2e-3)

# ---- elastic restore onto a DIFFERENT mesh ---------------------------------
import tempfile
ckdir = tempfile.mkdtemp()
mgr = CheckpointManager(ckdir)
mgr.save(0, p_new, blocking=True)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))      # reshaped topology
axes2 = sharding.make_axes_for(cfg, mesh2, shard_seq=False)
pspecs2 = sharding.param_specs(cfg, params, axes2)
flat_specs = {}
for path, spec in jax.tree_util.tree_flatten_with_path(
        pspecs2, is_leaf=lambda x: isinstance(x, P))[0]:
    key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                   for k in path)
    flat_specs[key] = spec
with mesh2:
    restored = mgr.restore(0, params, sharding_fn=lambda p: NamedSharding(
        mesh2, flat_specs[p]))
    loss2, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b, bits, ctx, axes2,
                                               remat=False))(restored, batch)
# same params -> same loss as the post-step eval on mesh 1
with mesh:
    loss1, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b, bits, ctx, axes,
                                               remat=False))(p_new, batch_s)
assert abs(float(loss1) - float(loss2)) < 1e-4, (float(loss1), float(loss2))
print("MULTIDEVICE_OK", loss_ref, loss_sharded)
"""


@pytest.mark.slow
def test_sharded_step_matches_single_device_and_elastic_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MULTIDEVICE_OK" in out.stdout, (out.stdout[-1000:],
                                            out.stderr[-3000:])
