"""Paper-core integration: joint indicator training -> extraction -> ILP
search -> policy execution, plus the Table-6 reversed ablation mechanics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import importance as imp
from repro.core import search
from repro.core.policy import MPQPolicy
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext


@pytest.fixture(scope="module")
def demo():
    cfg = get_config("limpq-demo").scaled(n_layers=2, d_model=64, n_heads=2,
                                          n_kv_heads=2, d_ff=256, vocab=256)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    data = SyntheticLM(cfg)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(s, 4, 64).items()}
               for s in range(6)]
    params, hist = imp.train_importance(params, cfg, ctx, batches, lr=0.02,
                                        freeze_backbone=True)
    ql = lm.enumerate_qlayers(cfg)
    ind = imp.extract_indicators(params, cfg, ql)
    return cfg, params, ctx, ql, ind, batches, hist


def test_joint_training_runs_n_plus_1(demo):
    cfg, *_, hist = demo
    assert hist[0]["loss_uniform"].shape == (cfg.n_bits,)
    assert np.isfinite(hist[-1]["loss_random"])


def test_freeze_backbone_only_moves_indicators(demo):
    """With freeze_backbone, weights stay put; banks move."""
    cfg, params, ctx, ql, ind, batches, _ = demo
    rng = jax.random.PRNGKey(1)
    p0 = lm.init_params(rng, cfg)
    opt = imp.importance_optimizer(0.05, freeze_backbone=True)
    step = jax.jit(imp.make_importance_step(cfg, ctx, opt, NO_AXES,
                                            remat=False))
    p1, _, _ = step(p0, opt.init(p0), batches[0], jax.random.PRNGKey(2))
    w0 = p0["body"]["0"]["wq"]["w"]
    w1 = p1["body"]["0"]["wq"]["w"]
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
    s0 = p0["body"]["0"]["wq"]["s_w"]
    s1 = p1["body"]["0"]["wq"]["s_w"]
    assert not np.allclose(np.asarray(s0), np.asarray(s1))


def test_indicator_monotonicity(demo):
    """Paper §3.3.2: scale value grows as bit-width shrinks (s(2b)>s(6b))."""
    cfg, _, _, ql, ind, *_ = demo
    frac_w = np.mean([ind[q.name]["w"][0] > ind[q.name]["w"][-1] for q in ql])
    frac_a = np.mean([ind[q.name]["a"][0] > ind[q.name]["a"][-1] for q in ql])
    assert frac_w >= 0.9
    assert frac_a >= 0.9


def test_search_respects_budget(demo):
    cfg, _, _, ql, ind, *_ = demo
    for level in (3, 4):
        budget = search.bitops_budget_for_uniform(ql, level)
        res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                                   bitops_budget=budget)
        assert res.bitops <= budget * (1 + 1e-9)
        avg_w, avg_a = res.policy.avg_bits()
        assert 2 <= avg_w <= 6 and 2 <= avg_a <= 6


def test_search_size_constraint(demo):
    cfg, _, _, ql, ind, *_ = demo
    size_budget = search.size_budget_for_rate(ql, fp_bits=32, rate=10.0)
    res = search.search_policy(ql, ind, cfg.bits, alpha=1.0,
                               size_budget_bytes=size_budget)
    assert res.size_bytes <= size_budget * (1 + 1e-9)


def test_reversed_assignment_mechanics(demo):
    """Table-6 ablation: `reverse=True` rank-mirrors the indicator table
    (sensitive layers perceived as insensitive) under the same budget."""
    cfg, _, _, ql, ind, *_ = demo
    # mirror anti-correlates the per-layer scores
    rev_ind = search.reverse_indicators(ql, ind)
    names = [q.name for q in ql]
    fwd_scores = np.asarray([ind[n]["w"].sum() + ind[n]["a"].sum()
                             for n in names])
    rev_scores = np.asarray([rev_ind[n]["w"].sum() + rev_ind[n]["a"].sum()
                             for n in names])
    ra = np.argsort(np.argsort(fwd_scores)).astype(float)
    rb = np.argsort(np.argsort(rev_scores)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    rho = (ra * rb).sum() / np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    assert rho < -0.99                       # perfectly anti-correlated

    budget = search.bitops_budget_for_uniform(ql, 4)
    rev = search.search_policy(ql, ind, cfg.bits, bitops_budget=budget,
                               reverse=True)
    assert rev.bitops <= budget * (1 + 1e-9)
    assert rev.policy.meta["kind"] == "ilp-reversed"


def test_policy_roundtrip(tmp_path, demo):
    cfg, _, _, ql, ind, *_ = demo
    budget = search.bitops_budget_for_uniform(ql, 3)
    res = search.search_policy(ql, ind, cfg.bits, bitops_budget=budget)
    p = tmp_path / "policy.json"
    res.policy.save(str(p))
    loaded = MPQPolicy.load(str(p))
    assert loaded.w_bits == res.policy.w_bits
    assert loaded.a_bits == res.policy.a_bits


def test_policy_execution_consistent(demo):
    """bits_from_policy must route exactly the policy's bits: executing a
    uniform-via-policy assignment == bits_uniform."""
    cfg, params, ctx, ql, _, batches, _ = demo
    uni_policy = MPQPolicy.uniform(ql, 4)
    bits_p = lm.bits_from_policy(cfg, uni_policy, ql)
    bits_u = lm.bits_uniform(cfg, list(cfg.bits).index(4))
    l1, _ = lm.loss_fn(params, cfg, batches[0], bits_p, ctx, NO_AXES,
                       remat=False)
    l2, _ = lm.loss_fn(params, cfg, batches[0], bits_u, ctx, NO_AXES,
                       remat=False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_hessian_baseline_plugs_in(demo):
    """HAWQ-style table must flow through the same search machinery."""
    from repro.core import hessian
    cfg, params, ctx, ql, _, batches, _ = demo
    table = hessian.hawq_sensitivities(params, cfg, batches[0],
                                       jax.random.PRNGKey(3), qlayers=ql,
                                       n_samples=2)
    assert set(table) == {q.name for q in ql}
    budget = search.bitops_budget_for_uniform(ql, 4)
    res = search.search_policy(ql, table, cfg.bits, alpha=1.0,
                               bitops_budget=budget)
    assert res.bitops <= budget * (1 + 1e-9)
