"""Hutchinson trace estimator sanity on a known quadratic."""
import jax
import jax.numpy as jnp
import numpy as np


def test_hutchinson_on_quadratic():
    """loss = 0.5 x^T A x  =>  H = A, Tr(H) known exactly. A is PSD so the
    trace is bounded away from 0 and a relative tolerance is meaningful."""
    rng = np.random.default_rng(0)
    n = 16
    A = rng.standard_normal((n, n))
    A = A @ A.T / n
    Aj = jnp.asarray(A, jnp.float32)

    def loss(x):
        return 0.5 * x @ Aj @ x

    grad = jax.grad(loss)
    key = jax.random.PRNGKey(0)
    est = 0.0
    n_samples = 400
    for i in range(n_samples):
        key, k = jax.random.split(key)
        v = jax.random.rademacher(k, (n,), jnp.float32)
        hv = jax.jvp(grad, (jnp.zeros(n),), (v,))[1]
        est += float(v @ hv) / n_samples
    np.testing.assert_allclose(est, np.trace(A), rtol=0.25)


def test_hawq_table_monotone_in_bits(rng):
    """Perturbation ||Q_b(W)-W||^2 must shrink as bits grow, so HAWQ
    sensitivities are monotone per layer."""
    from repro.configs import get_config
    from repro.core import hessian
    from repro.models import lm

    cfg = get_config("limpq-demo").scaled(n_layers=2, d_model=64, n_heads=2,
                                          n_kv_heads=2, d_ff=128, vocab=128)
    params = lm.init_params(rng, cfg)
    ql = lm.enumerate_qlayers(cfg)
    pert = hessian.quantization_perturbations(params, cfg, ql)
    for name, errs in pert.items():
        assert np.all(np.diff(errs) <= 1e-6), name   # decreasing with bits
