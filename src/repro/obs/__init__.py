"""repro.obs — observability for the quantized serving stack.

Three pieces, all zero-dependency (stdlib + the repo only):

* ``obs.metrics``   — a metrics registry (monotonic counters, gauges,
  fixed-bucket histograms, snapshot-to-dict). The engine, scheduler,
  session, dispatch and KV cache report through one registry instead of
  mutating ad-hoc stat fields.
* ``obs.trace``     — per-request lifecycle event traces
  (admit → prefill → first-token → decode ticks → complete/evict) with
  fenced ``time.perf_counter`` timestamps, exportable as JSONL or
  Chrome-trace/Perfetto JSON (``serve --trace-out``).
* ``obs.calibrate`` — replays measured per-phase engine timings against
  the ``dist.roofline`` step-cost model and emits a measured-vs-modeled
  table plus a device-table stanza the ``ChipSpec`` can be updated from
  (``benchmarks/roofline_calibration.py``).
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import TraceRecorder  # noqa: F401
