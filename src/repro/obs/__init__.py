"""repro.obs — observability for the quantized serving stack.

Six pieces, all zero-dependency (stdlib + numpy + the repo only):

* ``obs.metrics``   — a metrics registry (monotonic counters, gauges,
  fixed-bucket histograms, snapshot-to-dict). The engine, scheduler,
  session, dispatch and KV cache report through one registry instead of
  mutating ad-hoc stat fields.
* ``obs.trace``     — per-request lifecycle event traces
  (admit → prefix_hit → prefill → first-token → decode ticks →
  complete/evict) with fenced ``time.perf_counter`` timestamps,
  exportable as JSONL or Chrome-trace/Perfetto JSON
  (``serve --trace-out``).
* ``obs.calibrate`` — replays measured per-phase engine timings against
  the ``dist.roofline`` step-cost model and emits a measured-vs-modeled
  table plus a device-table stanza the ``ChipSpec`` can be updated from
  (``benchmarks/roofline_calibration.py``).
* ``obs.health``    — quantization health computed host-side from
  already-materialized artifacts: pack-time code saturation and scale
  utilization per site, KV-scale drift across decode ticks, per-route
  dispatch latency attribution, roofline drift. Never touches the
  jitted graph, so greedy-token identity is untouched.
* ``obs.export``    — Prometheus text exposition of a registry snapshot
  plus a periodic JSONL snapshot streamer
  (``serve --metrics-stream``).
* ``obs.monitor``   — threshold watchers over the registry raising
  structured ``Alert`` records into the trace and the engine stats
  (page-pool pressure, saturation ceiling, roofline drift).
"""
from repro.obs.export import (  # noqa: F401
    MetricsStreamer,
    parse_prometheus_text,
    prometheus_text,
    read_jsonl_snapshots,
    write_prometheus,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.monitor import (  # noqa: F401
    Alert,
    Monitor,
    Watcher,
    default_monitor,
)
from repro.obs.trace import TraceRecorder  # noqa: F401
