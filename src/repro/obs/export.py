"""Export surfaces for the metrics registry: Prometheus text + JSONL.

Two consumers, two formats, one source of truth (``MetricsRegistry``):

* ``prometheus_text`` renders the registry in the Prometheus text
  exposition format (``# HELP`` / ``# TYPE`` lines, ``_total`` suffix on
  counters, cumulative ``_bucket{le=...}`` series for histograms).
  Registry names use dots (``engine.decode_step_ms``); Prometheus wants
  ``[a-zA-Z_:][a-zA-Z0-9_:]*``, so names are sanitized through
  ``prom_name`` and prefixed (default ``repro``) to keep the scrape
  namespace clean. ``parse_prometheus_text`` is the inverse used by the
  line-format test: every exposition line must round-trip.

* ``MetricsStreamer`` appends periodic JSONL snapshots (one
  ``{"ts", "seq", "metrics"}`` object per line) for ``serve
  --metrics-stream``. It is pull-driven: the engine calls ``tick``
  once per scheduler iteration and the streamer decides whether the
  interval has elapsed. ``close`` force-emits a final snapshot so even
  a sub-interval smoke run yields >= 2 lines (first tick + close).

Everything here reads already-materialized host-side values — no jax,
no device sync, nothing on the hot path.
"""
from __future__ import annotations

import json
import re
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LINE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)$")
_LABEL_RE = re.compile(r'^(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>[^"]*)"$')


def prom_name(name: str, prefix: str = "repro") -> str:
    """Sanitize a dotted registry name into a Prometheus metric name."""
    san = _NAME_RE.sub("_", name)
    if prefix:
        san = f"{prefix}_{san}"
    if not re.match(r"^[a-zA-Z_:]", san):
        san = "_" + san
    return san


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def prometheus_text(registry: MetricsRegistry, prefix: str = "repro") -> str:
    """Render the whole registry in Prometheus text exposition format."""
    lines: List[str] = []
    for name in sorted(registry._metrics):
        m = registry._metrics[name]
        base = prom_name(name, prefix)
        if isinstance(m, Counter):
            full = base if base.endswith("_total") else base + "_total"
            if m.help:
                lines.append(f"# HELP {full} {m.help}")
            lines.append(f"# TYPE {full} counter")
            lines.append(f"{full} {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            if m.help:
                lines.append(f"# HELP {base} {m.help}")
            lines.append(f"# TYPE {base} gauge")
            lines.append(f"{base} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            if m.help:
                lines.append(f"# HELP {base} {m.help}")
            lines.append(f"# TYPE {base} histogram")
            cum = 0
            for edge, n in zip(m.buckets, m.counts):
                cum += n
                lines.append(f'{base}_bucket{{le="{_fmt(edge)}"}} {cum}')
            lines.append(f'{base}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{base}_sum {_fmt(m.sum)}")
            lines.append(f"{base}_count {m.count}")
        else:  # pragma: no cover - registry only holds the three kinds
            raise TypeError(f"unknown metric kind for {name!r}: {type(m)}")
    return "\n".join(lines) + "\n"


Sample = Tuple[str, Dict[str, str], float]


def parse_prometheus_text(text: str) -> List[Sample]:
    """Parse exposition text into (name, labels, value) samples.

    Raises ValueError on any line that is neither a comment nor a valid
    sample — this is the line-format check the tests gate on.
    """
    samples: List[Sample] = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE_RE.match(line)
        if m is None:
            raise ValueError(f"bad prometheus line {lineno}: {raw!r}")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            for part in m.group("labels").rstrip(",").split(","):
                lm = _LABEL_RE.match(part.strip())
                if lm is None:
                    raise ValueError(
                        f"bad prometheus label on line {lineno}: {part!r}")
                labels[lm.group("k")] = lm.group("v")
        v = m.group("value")
        value = float("inf") if v == "+Inf" else (
            float("-inf") if v == "-Inf" else float(v))
        samples.append((m.group("name"), labels, value))
    return samples


def samples_as_dict(samples: List[Sample]) -> Dict[str, Any]:
    """Fold samples into {name: value} / {name: {le: count}} for tests."""
    out: Dict[str, Any] = {}
    for name, labels, value in samples:
        if labels:
            out.setdefault(name, {})[tuple(sorted(labels.items()))] = value
        else:
            out[name] = value
    return out


def write_prometheus(registry: MetricsRegistry, path: str,
                     prefix: str = "repro") -> str:
    text = prometheus_text(registry, prefix=prefix)
    with open(path, "w") as f:
        f.write(text)
    return text


class MetricsStreamer:
    """Periodic JSONL snapshot writer for ``serve --metrics-stream``.

    ``tick(registry)`` emits at most one line per ``interval_s`` (the
    first tick always emits). ``close(registry)`` force-emits a final
    snapshot and flushes, so every run produces >= 2 snapshots: one at
    the first scheduler iteration, one at drain.
    """

    def __init__(self, path: str, interval_s: float = 0.5):
        if interval_s < 0:
            raise ValueError(f"interval_s must be >= 0, got {interval_s}")
        self.path = path
        self.interval_s = float(interval_s)
        self.seq = 0
        self._last_emit: Optional[float] = None
        self._f = open(path, "w")

    def _emit(self, registry: MetricsRegistry, now: float) -> None:
        rec = {"ts": now, "seq": self.seq, "metrics": registry.snapshot()}
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        self.seq += 1
        self._last_emit = now

    def tick(self, registry: MetricsRegistry,
             now: Optional[float] = None) -> bool:
        if self._f.closed:
            return False
        t = time.monotonic() if now is None else now
        if self._last_emit is not None and t - self._last_emit < self.interval_s:
            return False
        self._emit(registry, t)
        return True

    def close(self, registry: Optional[MetricsRegistry] = None,
              now: Optional[float] = None) -> None:
        if self._f.closed:
            return
        if registry is not None:
            self._emit(registry, time.monotonic() if now is None else now)
        self._f.close()


def read_jsonl_snapshots(path: str) -> List[Dict[str, Any]]:
    """Load and validate a --metrics-stream file (every line must be a
    snapshot object with ts/seq/metrics; seq must be contiguous)."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            for key in ("ts", "seq", "metrics"):
                if key not in obj:
                    raise ValueError(
                        f"{path}:{lineno}: snapshot missing {key!r}")
            if obj["seq"] != len(out):
                raise ValueError(
                    f"{path}:{lineno}: seq {obj['seq']} != {len(out)}")
            out.append(obj)
    return out
