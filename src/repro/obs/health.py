"""Quantization health: is the packed model actually healthy at serving?

Pure host-side numpy over already-materialized artifacts — the packed
weights, their trained scales, and the int8 KV cache's write-time
scales. Nothing here enters the jitted graph, so greedy-token identity
is untouched by construction; the engine simply *reads* what packing
and the KV write path already produced.

Signals (the ISSUE's signal plane):

* **code-saturation rate** — fraction of weight values whose grid image
  would round OUTSIDE ``[qmin, qmax]`` (i.e. the clip in
  ``quantize_to_grid`` engaged): ``mean(w/s > qmax + 0.5  or
  w/s < qmin - 0.5)``. A policy packed from its own calibration data
  (scale >= max|w|/qmax) has exactly zero saturation — the property the
  tests pin. Values landing exactly ON the grid edge are *not*
  saturated; that distinction is why this reads ``w`` and ``s`` rather
  than counting extreme codes.
* **scale utilization** — ``max|w| / (scale * qmax)`` per site: ~1.0
  means the trained scale tightly covers the weights; << 1 wastes grid
  resolution; > 1 means clipping (saturation above becomes nonzero).
* **KV-scale drift** — per-row write-time scales are write-once, so
  "drift across decode ticks" is the drift of the *population*: the
  relative change of the mean nonzero scale between consecutive
  samples. A stationary decode drifts ~0; a distribution shift in the
  keys/values shows up immediately.
* **per-route latency attribution** — the engine's perf_counter-fenced
  phase timings attributed to the dispatch route that actually ran
  (``dispatch.latency_ms.<family>.<route>`` histograms), so a route
  regression is visible per route, not just in the aggregate.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.quantizer import bit_range
from repro.obs.metrics import MetricsRegistry

SCALE_EPS = 1e-9  # keep in sync with runtime.packing.SCALE_EPS

# rate-style histograms (fractions in [0, 1] and small relative drifts)
RATE_BUCKETS: Tuple[float, ...] = (
    1e-6, 1e-5, 1e-4, 1e-3, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0)
# scale-utilization histogram: 1.0 is ideal, > 1 means clipping
UTIL_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1, 1.5, 2.0)


def site_health(w, w_bits: int, scale) -> Dict[str, float]:
    """Saturation + utilization for one packed site, from weight + scale.

    ``scale`` may be scalar or per-channel over the last dim (the same
    broadcast ``packing.quantize_to_grid`` applies).
    """
    w = np.asarray(w, np.float64)
    s = np.maximum(np.asarray(scale, np.float64), SCALE_EPS)
    if s.ndim == 1 and w.ndim >= 1 and s.shape[0] == w.shape[-1]:
        s = s.reshape((1,) * (w.ndim - 1) + (-1,))
    qmin, qmax = bit_range(int(w_bits), True)
    x = w / s
    saturated = np.logical_or(x > qmax + 0.5, x < qmin - 0.5)
    n = int(w.size)
    sat_rate = float(np.count_nonzero(saturated)) / n if n else 0.0
    util = float(np.max(np.abs(x))) / qmax if n else 0.0
    return {
        "saturation_rate": sat_rate,
        "scale_utilization": util,
        "n_values": n,
        "n_saturated": int(np.count_nonzero(saturated)),
        "w_bits": int(w_bits),
    }


def pack_summary(sites: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Aggregate per-site health into the bench/gate scalars."""
    if not sites:
        return {"saturation_rate_max": 0.0, "scale_utilization_p50": 0.0,
                "scale_utilization_min": 0.0, "sites": 0}
    sats = [h["saturation_rate"] for h in sites.values()]
    utils = sorted(h["scale_utilization"] for h in sites.values())
    return {
        "saturation_rate_max": max(sats),
        "scale_utilization_p50": utils[len(utils) // 2],
        "scale_utilization_min": utils[0],
        "sites": len(sites),
    }


def publish_pack_health(registry: MetricsRegistry,
                        sites: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Record per-site gauges + aggregate histograms into the registry.

    Names: ``quant.saturation_rate.<site>`` / ``quant.scale_utilization
    .<site>`` gauges, ``quant.saturation_rate`` / ``quant
    .scale_utilization`` histograms over sites, and the summary gauges
    ``quant.saturation_rate_max`` / ``quant.scale_utilization_p50`` the
    monitor and bench read.
    """
    h_sat = registry.histogram(
        "quant.saturation_rate", buckets=RATE_BUCKETS,
        help="per-site fraction of weight values clipped by the grid")
    h_util = registry.histogram(
        "quant.scale_utilization", buckets=UTIL_BUCKETS,
        help="per-site max|w| / (scale*qmax)")
    for name, h in sites.items():
        registry.gauge(f"quant.saturation_rate.{name}").set(
            h["saturation_rate"])
        registry.gauge(f"quant.scale_utilization.{name}").set(
            h["scale_utilization"])
        h_sat.observe(h["saturation_rate"])
        h_util.observe(h["scale_utilization"])
    summary = pack_summary(sites)
    registry.gauge(
        "quant.saturation_rate_max",
        help="worst per-site saturation rate (monitor ceiling input)",
    ).set(summary["saturation_rate_max"])
    registry.gauge("quant.scale_utilization_p50").set(
        summary["scale_utilization_p50"])
    registry.gauge("quant.scale_utilization_min").set(
        summary["scale_utilization_min"])
    return summary


# ---------------------------------------------------------------------------
# int8 KV write path: write-time scale population drift
# ---------------------------------------------------------------------------
def kv_scale_leaves(tree) -> List[np.ndarray]:
    """Materialize every quantized cache's (k_scale, v_scale) host-side.

    Walks plain containers; any node exposing ``k_scale``/``v_scale``
    (QuantKVCache, PagedKVCache — NamedTuples, so check before tuple
    recursion) contributes both arrays. Fp caches contribute nothing.
    """
    out: List[np.ndarray] = []

    def visit(x) -> None:
        if hasattr(x, "k_scale") and hasattr(x, "v_scale"):
            out.append(np.asarray(x.k_scale, np.float32))
            out.append(np.asarray(x.v_scale, np.float32))
            return
        if isinstance(x, (list, tuple)):
            for y in x:
                visit(y)
        elif isinstance(x, dict):
            for y in x.values():
                visit(y)

    visit(tree)
    return out


class KVScaleDrift:
    """Sampled drift of the KV write-time scale population.

    The engine calls ``update(state)`` every few decode ticks (host-side,
    after the step's device sync). Each call summarizes the nonzero
    scales (mean/max) and returns the relative change of the mean since
    the previous sample — the drift signal — or None on the first sample
    or an empty cache.
    """

    def __init__(self):
        self.prev_mean: Optional[float] = None
        self.last: Dict[str, float] = {}

    def update(self, tree) -> Optional[float]:
        leaves = kv_scale_leaves(tree)
        if not leaves:
            return None
        flat = np.concatenate([x.reshape(-1) for x in leaves])
        nz = flat[flat > 0.0]
        if nz.size == 0:
            return None
        mean = float(nz.mean())
        self.last = {"mean": mean, "max": float(nz.max()),
                     "rows": int(nz.size)}
        drift: Optional[float] = None
        if self.prev_mean is not None and self.prev_mean > 0.0:
            drift = abs(mean - self.prev_mean) / self.prev_mean
        self.prev_mean = mean
        return drift

    def publish(self, registry: MetricsRegistry,
                drift: Optional[float]) -> None:
        if not self.last:
            return
        registry.gauge("quant.kv_scale_mean").set(self.last["mean"])
        registry.gauge("quant.kv_scale_max").set(self.last["max"])
        if drift is not None:
            registry.histogram(
                "quant.kv_scale_drift", buckets=RATE_BUCKETS,
                help="relative change of the mean KV write scale "
                     "between samples").observe(drift)
            g = registry.gauge("quant.kv_scale_drift_max")
            g.set(max(g.value, drift))


# ---------------------------------------------------------------------------
# per-route dispatch latency attribution (host-side phase timings)
# ---------------------------------------------------------------------------
def attribute_latency(registry: MetricsRegistry, family: str, route: str,
                      seconds: float) -> None:
    """Attribute one fenced phase duration to the route that served it."""
    registry.histogram(
        f"dispatch.latency_ms.{family}.{route}",
        help=f"fenced {family} phase time attributed to route {route}",
    ).observe(seconds * 1e3)


def roofline_drift(rows: Sequence[Dict[str, Any]]) -> float:
    """Worst modeled-vs-measured factor from calibrate() rows:
    max over finite ratios of max(r, 1/r). 1.0 == perfect model."""
    worst = 1.0
    for row in rows:
        r = row.get("ratio")
        if r is None or not np.isfinite(r) or r <= 0:
            continue
        worst = max(worst, r, 1.0 / r)
    return float(worst)
