"""Roofline calibration: measured engine phase timings vs the step-cost
model.

``dist.roofline.decode_step_cost`` / ``suggest_prefill_chunk`` are the
scheduler's (and the ROADMAP's elastic-serving controller's) trusted
step-time oracle — but until something replays *measured* timings against
them, "trusted" is aspirational. This module closes that loop:

* :func:`calibrate` takes an ``EngineStats.as_dict()`` snapshot (whose
  timers are ``perf_counter``-fenced over the full device output tree)
  and the same workload shape the engine budgeted with, and returns a
  measured-vs-modeled row per phase (decode step, prefill token, TTFT)
  plus a **device-table stanza**: the effective HBM bandwidth and FLOP
  rate this host *actually delivered*, in ``ChipSpec`` field names, so
  ``dist.roofline.chip_from_table`` can build a calibrated chip.
* :func:`render_table` prints the rows as the fixed-width table the
  serve smoke and ``benchmarks/roofline_calibration.py`` emit.

The ratios are diagnostic, not gated — a CPU interpreter is orders of
magnitude off a TPU v5e envelope by design. What IS checked (bench
assert + serve smoke) is that every ratio is finite and positive: the
model and the measurement describe the same phases of the same run.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

from repro.dist import roofline


def _phase_rows(cfg, stats: Dict[str, Any], *, slots: int,
                cache_tokens: int, kv_bits: float, kv_attend: str,
                w_bits_total: Optional[float], avg_weight_bits: float,
                tp_size: int, chip: roofline.ChipSpec) -> List[Dict[str, Any]]:
    from repro.models import lm  # local import: lm imports dist.axes

    cost = roofline.decode_step_cost(
        cfg, slots, cache_tokens=cache_tokens, tp_size=tp_size,
        avg_weight_bits=avg_weight_bits, kv_bits=kv_bits,
        kv_attend=kv_attend, w_bits_total=w_bits_total, chip=chip)
    macs = sum(q.macs_per_token * q.n_mats for q in lm.enumerate_qlayers(cfg))
    per_token_s = 2.0 * macs / max(tp_size, 1) / chip.peak_flops

    rows: List[Dict[str, Any]] = []

    def row(phase: str, measured: float, modeled: float, note: str) -> None:
        ratio = measured / modeled if modeled else math.inf
        rows.append({"phase": phase, "measured_s": measured,
                     "modeled_s": modeled, "ratio": ratio, "note": note})

    decode_steps = max(int(stats.get("decode_steps", 0)), 1)
    row("decode_step", stats.get("t_decode_s", 0.0) / decode_steps,
        cost["step_s"],
        f"{cost['dominant']}-bound model, {cost['hbm_bytes']:.0f} B/step")

    prefill_tokens = max(int(stats.get("prefill_tokens", 0)), 1)
    row("prefill_token", stats.get("t_prefill_s", 0.0) / prefill_tokens,
        per_token_s, f"compute model, {2.0 * macs:.2e} flops/token")

    prefill_calls = max(int(stats.get("prefill_calls", 0)), 1)
    mean_prompt = prefill_tokens / prefill_calls
    ttft_p50_s = stats.get("ttft_p50_ms", 0.0) / 1e3
    row("ttft", ttft_p50_s, mean_prompt * per_token_s + cost["step_s"],
        f"p50 over {stats.get('admitted', 0)} requests, "
        f"mean prompt {mean_prompt:.1f} tok")
    return rows


def calibrate(cfg, stats: Dict[str, Any], *, slots: int, cache_tokens: int,
              kv_bits: float = 16.0, kv_attend: str = "fused",
              w_bits_total: Optional[float] = None,
              avg_weight_bits: float = 8.0, tp_size: int = 1,
              chip: roofline.ChipSpec = roofline.DEFAULT_CHIP
              ) -> Dict[str, Any]:
    """Measured-vs-modeled phase table + device-table stanza (module doc).

    ``stats`` is ``EngineStats.as_dict()`` from a *measured* run (warmed
    up: compile time in the timers would calibrate the jit cache, not the
    device). The keyword shape must match what the engine budgeted with —
    the same arguments it passed to ``suggest_prefill_chunk``.
    """
    rows = _phase_rows(cfg, stats, slots=slots, cache_tokens=cache_tokens,
                       kv_bits=kv_bits, kv_attend=kv_attend,
                       w_bits_total=w_bits_total,
                       avg_weight_bits=avg_weight_bits, tp_size=tp_size,
                       chip=chip)
    cost = roofline.decode_step_cost(
        cfg, slots, cache_tokens=cache_tokens, tp_size=tp_size,
        avg_weight_bits=avg_weight_bits, kv_bits=kv_bits,
        kv_attend=kv_attend, w_bits_total=w_bits_total, chip=chip)

    # effective device envelope this run delivered: the decode step moved
    # cost["hbm_bytes"] bytes in measured time (decode is memory-bound on
    # every chip the model knows), the prefill executed 2*macs flops per
    # token in measured time — both in ChipSpec field names so
    # roofline.chip_from_table can apply them directly
    from repro.models import lm
    macs = sum(q.macs_per_token * q.n_mats for q in lm.enumerate_qlayers(cfg))
    decode_steps = max(int(stats.get("decode_steps", 0)), 1)
    measured_step_s = stats.get("t_decode_s", 0.0) / decode_steps
    prefill_tokens = max(int(stats.get("prefill_tokens", 0)), 1)
    measured_prefill_s = stats.get("t_prefill_s", 0.0)
    table = {
        "name": f"{chip.name}-measured",
        "hbm_bytes_s": (cost["hbm_bytes"] / measured_step_s
                        if measured_step_s > 0 else 0.0),
        "peak_flops": (2.0 * macs * prefill_tokens / measured_prefill_s
                       if measured_prefill_s > 0 else 0.0),
        "source": "repro.obs.calibrate",
    }
    return {"chip": chip.name, "rows": rows, "device_table": table,
            "finite": all(math.isfinite(r["ratio"]) and r["ratio"] > 0
                          for r in rows)}


def render_table(rows: List[Dict[str, Any]]) -> str:
    """Fixed-width measured-vs-modeled table for logs."""
    lines = [f"  {'phase':<14} {'measured':>12} {'modeled':>12} "
             f"{'ratio':>10}  note"]
    for r in rows:
        lines.append(
            f"  {r['phase']:<14} {r['measured_s']:>10.3e} s "
            f"{r['modeled_s']:>10.3e} s {r['ratio']:>10.2f}  {r['note']}")
    return "\n".join(lines)
