"""Per-request lifecycle traces for the serving engine.

The engine records one event stream per serving epoch: every request
emits ``admit`` → ``prefill`` (span) → ``first_token`` → one ``token``
instant per decode tick → ``complete`` → ``evict``, and the engine adds
``decode_step`` spans for each jitted decode launch. Timestamps are
``time.perf_counter`` seconds relative to the recorder's epoch, stamped
only after the full device output tree is fenced
(``jax.block_until_ready``) — so a span's duration is wall time the
device actually spent, not dispatch latency.

Two interchangeable export formats (``serve --trace-out``):

* JSONL — one event per line (``to_jsonl``/``from_jsonl``), the
  greppable artifact format;
* Chrome trace / Perfetto — a ``{"traceEvents": [...]}`` JSON
  (``chrome``/``write_chrome``/``from_chrome``) loadable in
  ``chrome://tracing`` or https://ui.perfetto.dev. Requests render as
  named tracks (``req:<rid>``) alongside the engine track; the original
  event fields ride in ``args`` so the two formats round-trip
  losslessly.

``reconcile`` cross-checks a trace against an ``EngineStats.as_dict()``
snapshot — the serve smoke's proof that the trace and the counters
describe the same run (decode-span time within tolerance of
``t_decode_s``, token events == ``tokens_generated``, every admitted
request closed out in order).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Union

TRACE_SCHEMA_VERSION = 1

ENGINE_TRACK = "engine"

# per-request lifecycle vocabulary, in lifecycle order. ``prefix_hit`` is
# optional (paged layout only): it marks an admission that re-mapped shared
# prefix pages instead of prefilling them, carrying pages_reused / tokens /
# flops_saved — without it a shared-prefix admission is indistinguishable
# from a suspiciously fast prefill in the trace.
REQUEST_EVENTS = ("admit", "prefix_hit", "first_token", "token",
                  "complete", "evict")
# events that each carry exactly one emitted token
TOKEN_EVENTS = ("first_token", "token")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One trace event. ``phase`` follows the Chrome trace vocabulary we
    use: ``"X"`` = complete span (``ts``..``ts+dur``), ``"i"`` = instant.
    ``ts``/``dur`` are seconds relative to the recorder epoch."""

    name: str
    phase: str
    ts: float
    dur: float = 0.0
    track: str = ENGINE_TRACK
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def end(self) -> float:
        return self.ts + self.dur


def req_track(rid: int) -> str:
    return f"req:{rid}"


class TraceRecorder:
    """Append-only event recorder with a ``perf_counter`` epoch."""

    def __init__(self):
        self.events: List[TraceEvent] = []
        self._epoch = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._epoch

    def instant(self, name: str, track: str = ENGINE_TRACK,
                ts: Optional[float] = None, **args) -> TraceEvent:
        ev = TraceEvent(name, "i", self.now() if ts is None else ts,
                        0.0, track, args)
        self.events.append(ev)
        return ev

    def span(self, name: str, t0: float, t1: float,
             track: str = ENGINE_TRACK, **args) -> TraceEvent:
        if t1 < t0:
            raise ValueError(f"span {name!r}: end {t1} before start {t0}")
        ev = TraceEvent(name, "X", t0, t1 - t0, track, args)
        self.events.append(ev)
        return ev

    # -- JSONL ---------------------------------------------------------------
    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps({"schema": TRACE_SCHEMA_VERSION}) + "\n")
            for ev in self.events:
                f.write(json.dumps(dataclasses.asdict(ev), sort_keys=True)
                        + "\n")

    @classmethod
    def from_jsonl(cls, path: str) -> "TraceRecorder":
        rec = cls()
        with open(path) as f:
            header = json.loads(f.readline())
            if header.get("schema") != TRACE_SCHEMA_VERSION:
                raise ValueError(f"unknown trace schema {header!r}")
            for line in f:
                rec.events.append(TraceEvent(**json.loads(line)))
        return rec

    # -- Chrome trace / Perfetto --------------------------------------------
    def chrome(self) -> Dict[str, Any]:
        """Chrome-trace JSON object. ``ts``/``dur`` in microseconds per the
        format; one tid per track plus thread-name metadata so Perfetto
        labels the request lanes."""
        tids: Dict[str, int] = {ENGINE_TRACK: 0}
        events: List[Dict[str, Any]] = []
        for ev in self.events:
            tid = tids.setdefault(ev.track, len(tids))
            ce: Dict[str, Any] = {
                "name": ev.name, "ph": ev.phase, "pid": 0, "tid": tid,
                "ts": ev.ts * 1e6,
                "args": dict(ev.args, track=ev.track),
            }
            if ev.phase == "X":
                ce["dur"] = ev.dur * 1e6
            if ev.phase == "i":
                ce["s"] = "t"  # instant scope: thread
            events.append(ce)
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": track}} for track, tid in tids.items()]
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms",
                "metadata": {"schema": TRACE_SCHEMA_VERSION,
                             "source": "repro.obs.trace"}}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f, indent=1, sort_keys=True)

    @classmethod
    def from_chrome(cls, obj: Union[str, Dict[str, Any]]) -> "TraceRecorder":
        """Rebuild a recorder from ``chrome()`` output (path or dict) —
        the schema round-trip the tests gate."""
        if isinstance(obj, str):
            with open(obj) as f:
                obj = json.load(f)
        if not isinstance(obj, dict) or "traceEvents" not in obj:
            raise ValueError("not a Chrome trace: no traceEvents")
        rec = cls()
        for ce in obj["traceEvents"]:
            if ce.get("ph") == "M":
                continue
            args = dict(ce.get("args", {}))
            track = args.pop("track", ENGINE_TRACK)
            rec.events.append(TraceEvent(
                name=ce["name"], phase=ce["ph"], ts=ce["ts"] / 1e6,
                dur=ce.get("dur", 0.0) / 1e6, track=track, args=args))
        return rec

    def write(self, path: str) -> None:
        """Format by extension: ``.jsonl`` -> JSONL, else Chrome trace."""
        if path.endswith(".jsonl"):
            self.to_jsonl(path)
        else:
            self.write_chrome(path)


# ---------------------------------------------------------------------------
# analysis over a recorded event stream
# ---------------------------------------------------------------------------
def request_summaries(events: List[TraceEvent]) -> Dict[int, Dict[str, Any]]:
    """Per-request lifecycle view: timestamps of each stage, token count,
    TTFT and the inter-token gaps (milliseconds)."""
    out: Dict[int, Dict[str, Any]] = {}
    for ev in events:
        if not ev.track.startswith("req:"):
            continue
        rid = int(ev.track.split(":", 1)[1])
        r = out.setdefault(rid, {"events": [], "token_ts": []})
        r["events"].append(ev)
        if ev.name in TOKEN_EVENTS:
            r["token_ts"].append(ev.end())
        if ev.name in ("admit", "first_token", "complete", "evict"):
            r[ev.name] = ev.ts
    for rid, r in out.items():
        ts = sorted(r["token_ts"])
        r["tokens"] = len(ts)
        r["ttft_ms"] = ((r["first_token"] - r["admit"]) * 1e3
                        if "first_token" in r and "admit" in r else None)
        r["itl_ms"] = [(b - a) * 1e3 for a, b in zip(ts, ts[1:])]
    return out


def reconcile(rec: TraceRecorder, stats: Dict[str, Any],
              tol: float = 0.05) -> List[str]:
    """Cross-check a trace against an ``EngineStats.as_dict()`` snapshot.

    Returns a list of problems (empty = the trace and the counters agree):

    * sum of ``decode_step`` span durations within ``tol`` of
      ``t_decode_s`` (and prefill spans vs ``t_prefill_s``);
    * token events (``first_token`` + ``token``) == ``tokens_generated``;
    * every admitted request has a complete
      admit → first_token → tokens → complete chain with non-decreasing
      timestamps, and the request count matches ``completed``;
    * one ``spec_verify`` instant per speculative round, whose
      ``drafted``/``accepted`` args sum exactly to the engine's
      ``spec_draft_tokens``/``spec_accepted_tokens`` counters;
    * elastic traces: ``policy_swap`` events define swap epochs — every
      policy-stamped token must fall in its variant's epoch, every
      request stays within one variant, and the swap count and final
      epoch match ``policy_swaps`` / ``active_policy``.
    """
    problems: List[str] = []

    def close(measured: float, counted: float, label: str) -> None:
        ref = max(abs(counted), 1e-9)
        if abs(measured - counted) / ref > tol:
            problems.append(f"{label}: trace {measured:.6f} vs stats "
                            f"{counted:.6f} (tol {tol:.0%})")

    decode_spans = [e for e in rec.events if e.name == "decode_step"]
    close(sum(e.dur for e in decode_spans), stats.get("t_decode_s", 0.0),
          "sum(decode_step dur) != t_decode_s")
    if len(decode_spans) != stats.get("decode_steps", 0):
        problems.append(f"decode_step spans {len(decode_spans)} != "
                        f"decode_steps {stats.get('decode_steps')}")
    prefill_spans = [e for e in rec.events if e.name == "prefill"]
    close(sum(e.dur for e in prefill_spans), stats.get("t_prefill_s", 0.0),
          "sum(prefill dur) != t_prefill_s")

    # prefix-hit admissions are page-table remaps, NOT prefills: the
    # explicit prefix_hit events must account for exactly the tokens and
    # FLOPs the counters say were saved, and every admit that reports
    # reused prefix tokens must have one — otherwise the trace would
    # under-count what the paged path skipped.
    hits = [e for e in rec.events if e.name == "prefix_hit"]
    hit_tokens = sum(int(e.args.get("tokens", 0)) for e in hits)
    if hit_tokens != stats.get("prefix_hit_tokens", 0):
        problems.append(f"prefix_hit tokens {hit_tokens} != "
                        f"prefix_hit_tokens {stats.get('prefix_hit_tokens')}")
    close(sum(float(e.args.get("flops_saved", 0.0)) for e in hits),
          stats.get("prefill_flops_saved", 0.0),
          "sum(prefix_hit flops_saved) != prefill_flops_saved")
    hit_tracks = {e.track for e in hits}
    for e in rec.events:
        if (e.name == "admit" and e.args.get("prefix_hit_tokens", 0)
                and e.track not in hit_tracks):
            problems.append(f"{e.track}: admit reused "
                            f"{e.args['prefix_hit_tokens']} prefix tokens "
                            f"but has no prefix_hit event")

    # speculative rounds: every round emits one spec_verify instant; its
    # drafted/accepted args must sum exactly to the spec counters, so a
    # round that lost or double-counted acceptance bookkeeping cannot
    # reconcile (token identity alone would not catch the stats drifting)
    verifies = [e for e in rec.events if e.name == "spec_verify"]
    if len(verifies) != stats.get("spec_rounds", 0):
        problems.append(f"spec_verify instants {len(verifies)} != "
                        f"spec_rounds {stats.get('spec_rounds')}")
    drafted = sum(int(e.args.get("drafted", 0)) for e in verifies)
    if drafted != stats.get("spec_draft_tokens", 0):
        problems.append(f"sum(spec_verify drafted) {drafted} != "
                        f"spec_draft_tokens {stats.get('spec_draft_tokens')}")
    accepted = sum(int(e.args.get("accepted", 0)) for e in verifies)
    if accepted != stats.get("spec_accepted_tokens", 0):
        problems.append(
            f"sum(spec_verify accepted) {accepted} != "
            f"spec_accepted_tokens {stats.get('spec_accepted_tokens')}")

    # elastic swap epochs: policy_swap events partition the trace into
    # epochs, each serving ONE variant. Every policy-stamped token must
    # match the epoch active at its timestamp, every request must stay
    # inside a single variant (drain-then-swap admits nothing mid-swap),
    # the non-initial swap count must equal the policy_swaps counter, and
    # the last epoch must be the variant the stats say is active. Gated
    # on the events being present, so single-policy traces skip it —
    # reconcile no longer ASSUMES one policy per trace, it verifies it
    # per epoch.
    swaps = sorted((e for e in rec.events if e.name == "policy_swap"),
                   key=lambda e: e.ts)
    if swaps:
        real = [e for e in swaps if not e.args.get("initial")]
        if len(real) != stats.get("policy_swaps", 0):
            problems.append(f"policy_swap events {len(real)} != "
                            f"policy_swaps {stats.get('policy_swaps')}")
        if not swaps[0].args.get("initial"):
            problems.append("trace has policy_swap events but no initial "
                            "epoch marker (initial=true)")
        marks = [(e.ts, str(e.args.get("to", ""))) for e in swaps]
        active_stat = str(stats.get("active_policy", ""))
        if active_stat and marks[-1][1] != active_stat:
            problems.append(f"last swap epoch {marks[-1][1]!r} != stats "
                            f"active_policy {active_stat!r}")

        def epoch_at(ts: float) -> str:
            cur = marks[0][1]
            for t, pid in marks:
                if t <= ts:
                    cur = pid
                else:
                    break
            return cur

        variants_by_track: Dict[str, set] = {}
        for ev in rec.events:
            if ev.name in TOKEN_EVENTS and "policy" in ev.args:
                pid = str(ev.args["policy"])
                variants_by_track.setdefault(ev.track, set()).add(pid)
                expected = epoch_at(ev.ts)
                if pid != expected:
                    problems.append(
                        f"{ev.track}: {ev.name} stamped {pid!r} inside the "
                        f"{expected!r} swap epoch (ts {ev.ts:.6f})")
        for track, pids in sorted(variants_by_track.items()):
            if len(pids) > 1:
                problems.append(
                    f"{track}: tokens span policy variants {sorted(pids)} "
                    "— a request must drain under the variant that "
                    "admitted it")

    reqs = request_summaries(rec.events)
    tokens = sum(r["tokens"] for r in reqs.values())
    if tokens != stats.get("tokens_generated", 0):
        problems.append(f"token events {tokens} != tokens_generated "
                        f"{stats.get('tokens_generated')}")
    admits = [rid for rid, r in reqs.items() if "admit" in r]
    if len(admits) != stats.get("admitted", 0):
        problems.append(f"admit events {len(admits)} != admitted "
                        f"{stats.get('admitted')}")
    completes = [rid for rid, r in reqs.items() if "complete" in r]
    if len(completes) != stats.get("completed", 0):
        problems.append(f"complete events {len(completes)} != completed "
                        f"{stats.get('completed')}")
    for rid, r in reqs.items():
        for stage in ("first_token", "complete"):
            if stage not in r:
                problems.append(f"rid {rid}: no {stage} event")
        chain = [r[k] for k in ("admit", "first_token", "complete")
                 if k in r]
        if any(b < a for a, b in zip(chain, chain[1:])):
            problems.append(f"rid {rid}: lifecycle timestamps decrease")
        toks = r["token_ts"]
        if toks != sorted(toks):
            problems.append(f"rid {rid}: token timestamps decrease")
    return problems


def validate_chrome(obj: Dict[str, Any]) -> List[str]:
    """Minimal structural validity of a Chrome-trace dict."""
    problems: List[str] = []
    evs = obj.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["no traceEvents list"]
    for i, ce in enumerate(evs):
        if not isinstance(ce, dict) or "ph" not in ce or "name" not in ce:
            problems.append(f"event {i}: missing ph/name")
            continue
        if ce["ph"] in ("X", "i") and ce.get("ts", -1.0) < 0:
            problems.append(f"event {i} ({ce['name']}): negative/missing ts")
        if ce["ph"] == "X" and ce.get("dur", -1.0) < 0:
            problems.append(f"event {i} ({ce['name']}): negative/missing dur")
    return problems
