"""Zero-dependency metrics registry for the serving stack.

Three metric kinds, one registry:

* ``Counter``   — monotonic (``inc`` rejects negative deltas). Counts
  events (decode steps, admitted requests) and accumulates durations
  (``engine.t_decode_s``).
* ``Gauge``     — a point-in-time value (slot occupancy, queue depth,
  packed/cache bytes, prefill shapes compiled).
* ``Histogram`` — fixed upper-bound buckets plus an overflow bucket,
  with running count/sum/min/max. ``percentile`` interpolates linearly
  inside the winning bucket (edges clamped to the observed min/max, so
  a single-sample histogram reports that exact sample).

``MetricsRegistry`` is get-or-create by name: the instrumented call sites
(``launch.engine``, ``launch.scheduler``, ``runtime.dispatch``, ...)
never need to know whether a metric exists yet, and ``snapshot()``
renders the whole registry to one JSON-able dict for ``serve
--metrics-out`` and the bench artifacts. Registries are cheap; the
engine makes a fresh one per ``reset()`` so counters stay monotonic
within a serving epoch while old snapshots stay frozen.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, Optional, Tuple

# log-ish spaced latency buckets in milliseconds: 10 us .. 60 s covers a
# CPU-interpreted smoke decode step and a TPU decode step on one scale
DEFAULT_MS_BUCKETS: Tuple[float, ...] = (
    0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
    100.0, 200.0, 500.0, 1000.0, 2000.0, 5000.0, 10000.0, 30000.0, 60000.0,
)


class Counter:
    """Monotonic counter (float-valued, so it can accumulate seconds)."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name!r} is monotonic: inc({n}) rejected")
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value; ``set`` may move in either direction."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``buckets`` are ascending finite upper bounds; one overflow bucket
    (+inf) is implicit. ``observe`` is O(buckets) with no allocation, so
    the engine can call it per decode step without showing up in the
    step time it is measuring.
    """

    def __init__(self, name: str, buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                 help: str = ""):
        bs = tuple(float(b) for b in buckets)
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(f"histogram {name!r} needs ascending buckets")
        if not all(math.isfinite(b) for b in bs):
            raise ValueError(f"histogram {name!r}: buckets must be finite "
                             "(the overflow bucket is implicit)")
        self.name = name
        self.help = help
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)  # last = overflow
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self._sum += v
        self._count += 1
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) from the bucket counts.

        Linear interpolation inside the winning bucket, with the bucket
        edges clamped to the observed min/max — so an empty histogram
        reports 0.0, a single sample reports itself exactly, and the
        overflow bucket reports the observed max.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"percentile wants q in [0,1], got {q}")
        if self._count == 0:
            return 0.0
        rank = q * self._count
        cum = 0.0
        for i, n in enumerate(self.counts):
            if n == 0:
                continue
            lo = self.buckets[i - 1] if i > 0 else self._min
            hi = self.buckets[i] if i < len(self.buckets) else self._max
            lo = max(lo, self._min)
            hi = min(hi, self._max)
            if rank <= cum + n:
                frac = (rank - cum) / n
                return lo + (hi - lo) * max(0.0, min(1.0, frac))
            cum += n
        return self._max

    def as_dict(self) -> Dict[str, Any]:
        return {
            "count": self._count,
            "sum": self._sum,
            "min": self._min if self._count else 0.0,
            "max": self._max if self._count else 0.0,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "buckets": {("+inf" if i == len(self.buckets)
                         else repr(self.buckets[i])): n
                        for i, n in enumerate(self.counts) if n},
        }


class MetricsRegistry:
    """Name -> metric store with get-or-create accessors (module doc)."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, kind, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name, **kw)
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_MS_BUCKETS,
                  help: str = "") -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name, buckets, help=help)
        elif not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not Histogram")
        return m

    def value(self, name: str, default: float = 0.0) -> float:
        """Scalar read of a counter/gauge (0.0 when never registered)."""
        m = self._metrics.get(name)
        return m.value if m is not None and hasattr(m, "value") else default

    def get(self, name: str) -> Optional[Any]:
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Any]:
        """The whole registry as one JSON-able dict: scalars for
        counters/gauges, the bucket/percentile dict for histograms."""
        out: Dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            out[name] = m.as_dict() if isinstance(m, Histogram) else m.value
        return out

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)
