"""Threshold watchers over the metrics registry -> structured alerts.

A ``Watcher`` names one registry metric, a comparison, and a threshold.
``Monitor.check`` evaluates every watcher against the current registry
values and, on a False -> True transition (edge-triggered, so a
persistently bad value alerts once until it clears), raises a structured
``Alert``: appended to ``Monitor.alerts``, counted in the registry
(``alerts.fired`` plus ``alerts.fired.<name>``), and — when a
``TraceRecorder`` is attached — emitted as an ``alert`` instant event on
the engine track so Perfetto shows *when* the threshold tripped relative
to the request lifecycle.

Comparisons are inclusive (``>=`` / ``<=``): a value exactly at the
threshold fires. A watcher whose metric has never been registered is
skipped (not fired) — the page-pool watcher must not trip before the
first admission publishes the gauge.

Stock watchers match the ISSUE's signal plane:

* ``pool_pressure_watcher``   — paged-KV ``engine.kv_pool_free_pages``
  drops to/below one slot's worst-case page need.
* ``saturation_watcher``      — pack-time ``quant.saturation_rate_max``
  reaches the ceiling (trained scales clipping at serving time).
* ``roofline_drift_watcher``  — ``roofline.drift_max`` (worst
  modeled-vs-measured phase ratio, as max(r, 1/r)) exceeds the factor.

Everything is host-side python over already-recorded values; nothing
here touches the jitted graph.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

ALERTS_FIRED = "alerts.fired"


@dataclass(frozen=True)
class Alert:
    """One threshold trip: which watcher, what it saw, when."""

    name: str
    metric: str
    op: str
    threshold: float
    value: float
    ts: float
    severity: str = "warning"
    message: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "metric": self.metric, "op": self.op,
            "threshold": self.threshold, "value": self.value, "ts": self.ts,
            "severity": self.severity, "message": self.message,
        }


_OPS = {
    ">=": lambda v, t: v >= t,
    "<=": lambda v, t: v <= t,
}


@dataclass
class Watcher:
    """One inclusive threshold over one registry metric."""

    name: str
    metric: str
    op: str
    threshold: float
    severity: str = "warning"
    message: str = ""
    firing: bool = field(default=False, init=False)

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"watcher {self.name!r}: op must be one of "
                             f"{sorted(_OPS)}, got {self.op!r}")

    def evaluate(self, registry: MetricsRegistry) -> Optional[float]:
        """Current metric value when the condition holds, else None.
        Unregistered metrics never fire."""
        if self.metric not in registry:
            return None
        v = registry.value(self.metric)
        return v if _OPS[self.op](v, self.threshold) else None


class Monitor:
    """Edge-triggered watcher set; records alerts into registry + trace."""

    def __init__(self, watchers: Optional[List[Watcher]] = None):
        self.watchers: List[Watcher] = list(watchers or [])
        self.alerts: List[Alert] = []

    def add(self, watcher: Watcher) -> "Monitor":
        self.watchers.append(watcher)
        return self

    def check(self, registry: MetricsRegistry, trace=None,
              now: Optional[float] = None) -> List[Alert]:
        """Evaluate all watchers; return (and record) newly-fired alerts."""
        fired: List[Alert] = []
        for w in self.watchers:
            v = w.evaluate(registry)
            if v is None:
                w.firing = False
                continue
            if w.firing:  # still in violation, already alerted
                continue
            w.firing = True
            ts = (trace.now() if trace is not None and now is None
                  else (now if now is not None else 0.0))
            alert = Alert(name=w.name, metric=w.metric, op=w.op,
                          threshold=w.threshold, value=v, ts=ts,
                          severity=w.severity, message=w.message)
            fired.append(alert)
            self.alerts.append(alert)
            registry.counter(
                ALERTS_FIRED, help="threshold alerts raised").inc()
            registry.counter(f"{ALERTS_FIRED}.{w.name}").inc()
            if trace is not None:
                # "name"/"ts" collide with instant()'s own params
                args = alert.as_dict()
                args["watcher"] = args.pop("name")
                args.pop("ts")
                trace.instant("alert", ts=ts, **args)
        return fired

    @property
    def fired_count(self) -> int:
        return len(self.alerts)

    def as_dicts(self) -> List[Dict[str, Any]]:
        return [a.as_dict() for a in self.alerts]


def pool_pressure_watcher(min_free_pages: float,
                          metric: str = "engine.kv_pool_available_pages"
                          ) -> Watcher:
    """Fires when obtainable pages (free + LRU-evictable; the same number
    the scheduler's deferral check uses) drop to/below the floor. Watching
    raw ``free_count`` instead would trip whenever the prefix registry is
    merely full even though an admission could evict its way through —
    pass ``metric="engine.kv_pool_free_pages"`` to watch that anyway."""
    return Watcher(
        name="pool_pressure", metric=metric,
        op="<=", threshold=float(min_free_pages), severity="warning",
        message="paged-KV obtainable pages below one slot's worst-case "
                "need — admissions are deferring")


def saturation_watcher(ceiling: float = 0.25) -> Watcher:
    return Watcher(
        name="saturation_ceiling", metric="quant.saturation_rate_max",
        op=">=", threshold=float(ceiling), severity="critical",
        message="a packed layer clips above the saturation ceiling — "
                "trained scales do not cover the served weights")


def roofline_drift_watcher(max_factor: float = 8.0) -> Watcher:
    return Watcher(
        name="roofline_drift", metric="roofline.drift_max",
        op=">=", threshold=float(max_factor), severity="warning",
        message="modeled-vs-measured step cost drifted past the factor "
                "the elastic controller can trust")


def default_monitor(*, pool_min_free: Optional[float] = None,
                    saturation_ceiling: float = 0.25,
                    roofline_max_factor: float = 8.0) -> Monitor:
    """The stock watcher set (pool watcher only when a floor is given)."""
    mon = Monitor([saturation_watcher(saturation_ceiling),
                   roofline_drift_watcher(roofline_max_factor)])
    if pool_min_free is not None:
        mon.add(pool_pressure_watcher(pool_min_free))
    return mon
