"""QAT finetune / pretrain step factory.

After the ILP search, the model is finetuned with the searched policy's
*static* bit assignment active (paper §4.1: 90 epochs, cosine LR, SGD).
The same factory also produces the full-precision and uniform-bit baseline
steps — one code path for every experiment row.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ModelConfig
from repro.dist.axes import NO_AXES, MeshAxes
from repro.models import lm
from repro.models.quant_layers import QuantContext


def make_train_step(cfg: ModelConfig, ctx: QuantContext,
                    optimizer: optim.Optimizer, bits,
                    axes: MeshAxes = NO_AXES, *,
                    remat: bool = True) -> Callable:
    """step(params, opt_state, batch) -> (params, opt_state, metrics).

    `bits` is a static bit-assignment pytree (or None for full precision) —
    closure-captured so the ILP policy is baked into the compiled step.
    """

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, cfg, batch, bits, ctx, axes,
                                      remat)
        gnorm = optim.global_norm(grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return step


def make_eval_step(cfg: ModelConfig, ctx: QuantContext, bits,
                   axes: MeshAxes = NO_AXES) -> Callable:
    def step(params, batch):
        loss, metrics = lm.loss_fn(params, cfg, batch, bits, ctx, axes,
                                   remat=False)
        return metrics
    return step


def evaluate(params, cfg: ModelConfig, ctx: QuantContext, bits, batches,
             axes: MeshAxes = NO_AXES, jit: bool = True) -> dict:
    step = make_eval_step(cfg, ctx, bits, axes)
    if jit:
        step = jax.jit(step)
    total, n = None, 0
    for b in batches:
        m = step(params, b)
        total = m if total is None else jax.tree.map(jnp.add, total, m)
        n += 1
    return {k: float(v) / n for k, v in jax.device_get(total).items()}
