"""Fault-tolerant checkpointing.

Design goals at 1000+ nodes (DESIGN.md §4):

* **Atomicity** — arrays are written to ``<dir>/tmp.<step>`` and the
  directory is ``os.rename``d to ``step_<n>`` only after an fsync'd DONE
  marker: a reader can never observe a torn checkpoint after a mid-write
  node failure.
* **Async** — a writer thread snapshots device arrays to host
  (``jax.device_get`` at call time, so the train loop's donated buffers are
  safe) and performs I/O off the critical path; ``wait()`` joins before
  exit or before starting a save of the same step.
* **Keep-N GC** — old steps are garbage-collected after a successful save.
* **Elastic restore** — arrays are stored *unsharded* (host-gathered); the
  restore path places them onto ANY mesh via
  ``jax.device_put(x, NamedSharding(new_mesh, spec))``, so a job can
  restart on a different device count (elastic scaling / failed-pod
  exclusion) without a repartitioning tool.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree, *, meta: Optional[dict] = None,
             blocking: bool = False):
        """Snapshot now, write asynchronously (unless blocking)."""
        self.wait()
        flat = _flatten(tree)           # device->host BEFORE returning
        meta = dict(meta or {})
        meta["step"] = int(step)

        def _write():
            try:
                tmp = os.path.join(self.dir, f"tmp.{step}")
                final = os.path.join(self.dir, f"step_{step:010d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)    # atomic publish
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, *,
                sharding_fn: Optional[Callable[[str], Any]] = None):
        """Restore onto the current topology. `sharding_fn(path) -> Sharding`
        enables elastic re-placement onto any mesh; None keeps host arrays
        committed by jnp.asarray (single-device)."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(template, flat)
        if sharding_fn is None:
            return jax.tree.map(lambda a: jax.numpy.asarray(a), tree)

        def place(kp, leaf):
            key = "/".join(
                str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                for k in kp)
            return jax.device_put(leaf, sharding_fn(key))

        return jax.tree_util.tree_map_with_path(place, tree)

    def meta(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:010d}", "meta.json")
        with open(path) as f:
            return json.load(f)


# ---------------------------------------------------------------------------
# serving bundles: params + searched policy in one atomic checkpoint
# ---------------------------------------------------------------------------
def save_serving_bundle(directory: str, step: int, params,
                        policy, *, extra_meta: Optional[dict] = None,
                        solve_report: Optional[Any] = None,
                        keep_n: int = 3) -> None:
    """Checkpoint trained params together with the searched ``MPQPolicy``
    (stored in the step's meta.json), so the serving runtime can restore a
    deployable (params, policy) pair from one atomic artifact.

    ``solve_report`` (a ``core.ilp.SolveReport``, or its ``to_json()``
    string) rides along as ``meta["solve_report"]`` — the ILP audit trail
    ``serve --explain-policy`` renders. When omitted, a report already
    embedded in ``policy.meta["solve_report"]`` by ``search_policy`` is
    promoted into the bundle meta so explainability survives the bundle
    round trip either way."""
    meta = dict(extra_meta or {})
    meta["mpq_policy"] = policy.to_json()
    if solve_report is None:
        solve_report = getattr(policy, "meta", {}).get("solve_report")
    if solve_report is not None:
        meta["solve_report"] = (solve_report if isinstance(solve_report, str)
                                else solve_report.to_json())
    mgr = CheckpointManager(directory, keep_n=keep_n)
    mgr.save(step, params, meta=meta, blocking=True)


def _bundle_policy_meta(directory: str, step: Optional[int]):
    from repro.core.policy import MPQPolicy

    mgr = CheckpointManager(directory)
    if step is None:
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory!r}")
    meta = mgr.meta(step)
    if "mpq_policy" not in meta:
        raise KeyError(
            f"checkpoint step {step} in {directory!r} has no 'mpq_policy' "
            "meta entry — not a serving bundle")
    return mgr, step, MPQPolicy.from_json(meta["mpq_policy"]), meta


def peek_serving_policy(directory: str, *, step: Optional[int] = None):
    """Load just the ``MPQPolicy`` from a serving bundle (meta.json only,
    no array I/O) — lets deployment code validate a bundle against its
    model config *before* paying, or crashing inside, the param restore."""
    return _bundle_policy_meta(directory, step)[2]


def load_serving_bundle(directory: str, template, *, step: Optional[int] = None,
                        sharding_fn: Optional[Callable[[str], Any]] = None,
                        validate: Optional[Callable[[Any], Any]] = None):
    """Restore ``(params, policy, meta)`` saved by ``save_serving_bundle``.
    ``step=None`` loads the latest step. ``validate(policy)`` runs BEFORE
    the array restore, so a stale/foreign bundle fails on the policy
    message path instead of a cryptic missing-array error (and the meta is
    read only once — no separate ``peek_serving_policy`` round trip)."""
    mgr, step, policy, meta = _bundle_policy_meta(directory, step)
    if validate is not None:
        validate(policy)
    params = mgr.restore(step, template, sharding_fn=sharding_fn)
    return params, policy, meta


class StepWatchdog:
    """Straggler mitigation hook: tracks step wall-times and flags outliers
    (a slow host in a real fleet). The train loop consults `suspect` to log
    and, in a real deployment, to trigger hot-spare swap; the deterministic
    skip-to-step data pipeline makes the swap stateless."""

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: List[float] = []
        self.flags = 0

    def observe(self, dt: float) -> bool:
        hist = self.times[-self.window:]
        slow = bool(hist) and len(hist) >= 8 and \
            dt > self.threshold * float(np.median(hist))
        self.times.append(dt)
        if slow:
            self.flags += 1
        return slow
