"""Deterministic synthetic data pipeline.

No dataset ships in the container (DESIGN.md §8), so the pipeline generates
seeded synthetic batches with *learnable structure* (a QAT loss that cannot
go below entropy of noise would make every indicator identical):

* token streams: Zipf unigram base + a first-order Markov "grammar" derived
  from a seeded random transition table + motif copying. CE starts near
  ln(vocab) and drops as the model learns the transitions.
* audio frames: smoothed Gaussian features; labels are a fixed random
  projection argmax of the features — a deterministic learnable mapping.
* vision stub: seeded patch embeddings.

Every sample is generated *state-free* from (seed, step, global_index):
skip-to-any-step is O(1) (straggler/elastic restart needs no replay), and
hosts materialize only their own slice of the global batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import FRONTEND_DIMS


def _rs(*key_ints) -> np.random.Generator:
    # Philox wants a 2- or 4-element key; fold arbitrary ints into 2 words.
    k0 = k1 = np.uint64(0x9E3779B97F4A7C15)
    for i, k in enumerate(key_ints):
        w = np.uint64(k % (2 ** 63))
        if i % 2 == 0:
            k0 = np.uint64((int(k0) * 6364136223846793005 + int(w)) % 2 ** 64)
        else:
            k1 = np.uint64((int(k1) * 1442695040888963407 + int(w)) % 2 ** 64)
    return np.random.Generator(np.random.Philox(key=np.asarray([k0, k1])))


@dataclass(frozen=True)
class DataConfig:
    seed: int = 17
    zipf_a: float = 1.3
    markov_weight: float = 0.7     # prob of following the "grammar"
    n_states: int = 64             # grammar order (transition table rows)


class SyntheticLM:
    """Deterministic synthetic corpus for one ModelConfig."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.dcfg = dcfg
        V = cfg.vocab
        g = _rs(dcfg.seed, 0xC0FFEE)
        # Zipf base distribution over the vocab
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = ranks ** (-dcfg.zipf_a)
        self.base_p = p / p.sum()
        # seeded Markov grammar: state = token % n_states
        self.trans = g.integers(0, V, size=(dcfg.n_states, 8))
        # audio label projection
        if cfg.frontend == "audio_stub":
            self.audio_proj = g.standard_normal(
                (FRONTEND_DIMS["audio_stub"], min(V, 504))).astype(np.float32)

    # -- samples ------------------------------------------------------------
    def _tokens(self, step: int, gidx: int, S: int) -> np.ndarray:
        g = _rs(self.dcfg.seed, step, gidx)
        V = self.cfg.vocab
        base = g.choice(V, size=S + 1, p=self.base_p)
        out = np.empty(S + 1, np.int64)
        out[0] = base[0]
        follow = g.random(S + 1) < self.dcfg.markov_weight
        pick = g.integers(0, self.trans.shape[1], size=S + 1)
        for t in range(1, S + 1):
            if follow[t]:
                out[t] = self.trans[out[t - 1] % self.dcfg.n_states, pick[t]]
            else:
                out[t] = base[t]
        return out[:S].astype(np.int32)

    def _audio(self, step: int, gidx: int, S: int):
        g = _rs(self.dcfg.seed, step, gidx, 0xA0D10)
        F = FRONTEND_DIMS["audio_stub"]
        x = g.standard_normal((S + 4, F)).astype(np.float32)
        x = 0.5 * (x[:S] + x[2:S + 2] + x[4:S + 4])    # temporal smoothing
        labels = (x @ self.audio_proj).argmax(-1).astype(np.int32)
        return x, labels

    def _img(self, step: int, gidx: int):
        g = _rs(self.dcfg.seed, step, gidx, 0x1A6E)
        return g.standard_normal(
            (self.cfg.n_image_tokens, FRONTEND_DIMS["vision_stub"])
        ).astype(np.float32)

    # -- batches ------------------------------------------------------------
    def batch(self, step: int, batch_size: int, seq_len: int, *,
              host_id: int = 0, n_hosts: int = 1) -> Dict[str, np.ndarray]:
        assert batch_size % n_hosts == 0, (batch_size, n_hosts)
        per = batch_size // n_hosts
        gidx = range(host_id * per, (host_id + 1) * per)
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            pairs = [self._audio(step, i, seq_len) for i in gidx]
            return {"feats": np.stack([p[0] for p in pairs]),
                    "labels": np.stack([p[1] for p in pairs])}
        out = {"tokens": np.stack([self._tokens(step, i, seq_len)
                                   for i in gidx])}
        if cfg.family == "vlm":
            out["img"] = np.stack([self._img(step, i) for i in gidx])
        return out

    def batches(self, n_steps: int, batch_size: int, seq_len: int,
                start_step: int = 0, **kw) -> Iterator[Dict[str, np.ndarray]]:
        for s in range(start_step, start_step + n_steps):
            yield self.batch(s, batch_size, seq_len, **kw)
