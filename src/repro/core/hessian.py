"""HAWQ-style Hessian-trace sensitivity baseline (Dong et al., HAWQ-v2).

The paper argues this family of criteria is *biased*: the trace is computed
on the full-precision network, blind to the quantizer. We implement it
faithfully as the comparison baseline (benchmarks/hessian_baseline.py):

  sensitivity_l(b) = (Tr(H_l) / numel_l) * ||Q_b(W_l) - W_l||^2

with Tr(H_l) estimated by Hutchinson: E_v[v^T H v], v ~ Rademacher,
restricted per layer. The resulting per-layer per-bit table plugs into the
same MCKP solver as our learned indicators, so the two criteria are compared
under identical search machinery.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qspec import QLayer
from repro.core.quantizer import bit_range, fake_quant, init_scale_from_stats
from repro.dist.axes import NO_AXES, MeshAxes
from repro.models import lm
from repro.models.quant_layers import fp_context


def _weight_leaf(params, q: QLayer):
    seg, idx = q.segment.split(".")
    node = params[seg][idx]
    for k in q.path:
        node = node[k]
    return node["w"]


def hutchinson_traces(params, cfg: ModelConfig, batch,
                      qlayers: Sequence[QLayer], rng, *,
                      n_samples: int = 4,
                      axes: MeshAxes = NO_AXES) -> Dict[str, float]:
    """Per-QLayer Hessian-trace estimates of the FULL-PRECISION loss."""
    ctx = fp_context(compute_dtype=jnp.float32)

    def loss(p):
        return lm.loss_fn(p, cfg, batch, None, ctx, axes, remat=False)[0]

    grad_fn = jax.grad(loss)

    def hvp(v):
        return jax.jvp(grad_fn, (params,), (v,))[1]

    # restrict probes to QLayer weight leaves; zeros elsewhere
    names = {q.name: q for q in qlayers}
    traces = {name: 0.0 for name in names}
    for s in range(n_samples):
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, len(qlayers))
        v = jax.tree.map(jnp.zeros_like, params)
        v = {k: v[k] for k in v}
        # build a full-tree Rademacher probe on weight leaves only
        probe = jax.tree.map(jnp.zeros_like, params)
        for key, q in zip(keys, qlayers):
            w = _weight_leaf(params, q)
            r = jax.random.rademacher(key, w.shape, w.dtype)
            seg, idx = q.segment.split(".")
            node = probe[seg][idx]
            for kk in q.path[:-1]:
                node = node[kk]
            node[q.path[-1]]["w"] = r            # dicts are mutable pytrees
        hv = hvp(probe)
        for q in qlayers:
            w_probe = _weight_leaf(probe, q)
            w_hv = _weight_leaf(hv, q)
            contrib = float(jnp.sum(w_probe.astype(jnp.float32)
                                    * w_hv.astype(jnp.float32)))
            if q.segment.startswith("body."):
                # probes hit all units at once; attribute uniformly
                contrib /= max(1, w_probe.shape[0])
            traces[q.name] += contrib / n_samples
    return traces


def quantization_perturbations(params, cfg: ModelConfig,
                               qlayers: Sequence[QLayer]) -> Dict[str, np.ndarray]:
    """||Q_b(W) - W||^2 per QLayer per bit option (statistics-init scales)."""
    out = {}
    for q in qlayers:
        w = _weight_leaf(params, q).astype(jnp.float32)
        if q.segment.startswith("body."):
            w = w[q.unit]
        errs = []
        for b in cfg.bits:
            qmin, qmax = bit_range(int(b), True)
            s = init_scale_from_stats(w, qmax)
            qw = fake_quant(w, s, qmin, qmax)
            errs.append(float(jnp.sum(jnp.square(qw - w))))
        out[q.name] = np.asarray(errs, np.float64)
    return out


def hawq_sensitivities(params, cfg: ModelConfig, batch, rng, *,
                       qlayers: Optional[Sequence[QLayer]] = None,
                       n_samples: int = 4,
                       axes: MeshAxes = NO_AXES):
    """HAWQ-v2 style values table: name -> (n_bits,) sensitivity. Plug into
    repro.core.search.search_policy via the `indicators` argument with
    alpha=0 semantics (weights-only criterion, as HAWQ defines it)."""
    qlayers = qlayers if qlayers is not None else lm.enumerate_qlayers(cfg)
    traces = hutchinson_traces(params, cfg, batch, qlayers, rng,
                               n_samples=n_samples, axes=axes)
    perturb = quantization_perturbations(params, cfg, qlayers)
    table = {}
    for q in qlayers:
        numel = max(1, q.w_params)
        sens = max(traces[q.name], 0.0) / numel * perturb[q.name]
        # shape it like learned indicators: weights-only criterion, so the
        # activation half is zero (HAWQ does not rank activations).
        table[q.name] = {"w": sens, "a": np.zeros_like(sens)}
    return table
