"""The paper's primary contribution (LIMPQ, Tang et al. 2022):

  quantizer   — LSQ fake-quant + per-bit indicator banks (Eq. 1, §3.3)
  importance  — one-shot joint indicator training (§3.4)
  qspec       — QLayer: the unit of mixed-precision search + BitOps/size
  ilp         — MCKP solvers (exact DP + Lagrangian + bruteforce checks)
  search      — Eq. 3: indicators -> ILP -> MPQPolicy (+ Table-6 reversal)
  policy      — the searched per-layer (b_w, b_a) artifact (serializable)
  hessian     — HAWQ-style Hessian-trace criterion (comparison baseline)
"""
