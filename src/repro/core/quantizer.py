"""LSQ-style uniform quantizer with learnable step-size scale factors.

This is Eq. (1) of the paper:

    v_q = round(clip(v / s, min_b, max_b)) * s

with the LSQ straight-through gradients (Esser et al., ICLR'20): the round is
an STE, and d v_q / d s is `round(v/s) - v/s` inside the clip range and
`min_b` / `max_b` outside — obtained here *compositionally* from two STE
primitives (``round_ste`` on top of ``clip``), which yields exactly the LSQ
vjp (see tests/test_quantizer.py::test_lsq_scale_gradient).

The paper's central object — the *importance indicator* — is the learned
scale `s` itself, kept **per bit-width** in an ``IndicatorBank`` so that one
joint QAT run learns all `2 * L * n` indicators at once (paper §3.4).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def round_ste(x: Array) -> Array:
    """round() with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def grad_scale(x: Array, scale) -> Array:
    """Identity in value; gradient multiplied by `scale` (LSQ trick)."""
    return x * scale + jax.lax.stop_gradient(x - x * scale)


def bit_range(b, signed: bool):
    """(qmin, qmax) for bit-width `b`. Works on python ints and traced arrays."""
    if signed:
        return -(2 ** (b - 1)), 2 ** (b - 1) - 1
    return 0 if not isinstance(b, jnp.ndarray) else jnp.zeros_like(b), 2 ** b - 1


def fake_quant(v: Array, s: Array, qmin, qmax, *, grad_scale_factor=None) -> Array:
    """Quantize-dequantize `v` with scale `s` (Eq. 1) and LSQ gradients.

    `qmin`/`qmax` may be python scalars or traced scalars (dynamic bit-width
    during joint importance training). `s` is a per-tensor scalar.
    """
    s = jnp.maximum(jnp.asarray(s, v.dtype), jnp.asarray(1e-9, v.dtype))
    if grad_scale_factor is not None:
        s = grad_scale(s, jnp.asarray(grad_scale_factor, v.dtype))
    vs = v / s
    vbar = jnp.clip(vs, qmin, qmax)
    return round_ste(vbar) * s


def lsq_grad_scale_factor(numel: int, qmax) -> Array:
    """LSQ gradient normalizer g = 1 / sqrt(numel * qmax). `numel` goes in
    as python float — giant activation tensors overflow int32 otherwise."""
    return 1.0 / jnp.sqrt(jnp.maximum(
        float(numel) * jnp.asarray(qmax, jnp.float32), 1.0))


def init_scale_from_stats(v: Array, qmax) -> Array:
    """LSQ statistics init: s0 = 2*E|v| / sqrt(qmax) (paper §3.3.2 keeps it)."""
    return 2.0 * jnp.mean(jnp.abs(v.astype(jnp.float32))) / jnp.sqrt(
        jnp.asarray(qmax, jnp.float32)
    )


def init_scale_same(b) -> Array:
    """Paper's alternative same-value init: s_b = 0.1 / b (§3.3.2)."""
    return 0.1 / jnp.asarray(b, jnp.float32)


class BitTables(NamedTuple):
    """Static per-bit (qmin, qmax, grad-scale-vs-qmax) lookup tables so a
    *traced* bit index can select its range with a gather."""
    bits: Array     # (n,) int32
    qmin: Array     # (n,) float32
    qmax: Array     # (n,) float32

    @staticmethod
    def make(bits: Sequence[int], signed: bool) -> "BitTables":
        qmins, qmaxs = [], []
        for b in bits:
            lo, hi = bit_range(int(b), signed)
            qmins.append(float(lo))
            qmaxs.append(float(hi))
        return BitTables(
            bits=jnp.asarray(bits, jnp.int32),
            qmin=jnp.asarray(qmins, jnp.float32),
            qmax=jnp.asarray(qmaxs, jnp.float32),
        )


def fake_quant_indexed(
    v: Array,
    scale_bank: Array,     # (n_bits,) learnable indicator bank for this tensor
    bit_idx,               # scalar int (python or traced): index into the bank
    tables: BitTables,
    numel: int,
) -> Array:
    """Fake-quant `v` at the bank entry `bit_idx`.

    This is the joint-training workhorse: uniform-bit passes feed the same
    `bit_idx` to every layer, the random pass feeds per-layer indices, and
    policy execution feeds the ILP-chosen static index. Only the selected
    bank entry receives gradient (gather has scatter-add transpose).

    `scale_bank` may carry leading stacked dims, e.g. (E, n) for MoE expert
    stacks — the selected scale then broadcasts per-expert against `v`.
    """
    s = jnp.take(scale_bank, bit_idx, axis=-1)
    if s.ndim:                       # (E,) -> (E, 1, ..., 1) to broadcast
        s = s.reshape(s.shape + (1,) * (v.ndim - s.ndim))
    qmin = jnp.take(tables.qmin, bit_idx).astype(v.dtype)
    qmax = jnp.take(tables.qmax, bit_idx).astype(v.dtype)
    g = lsq_grad_scale_factor(numel, jnp.take(tables.qmax, bit_idx))
    return fake_quant(v, s, qmin, qmax, grad_scale_factor=g)


def quantization_error(v: Array, s: Array, qmin, qmax) -> Array:
    """||Q(v) - v||^2 — used by the HAWQ-style baseline's sensitivity metric."""
    q = fake_quant(v, s, qmin, qmax)
    d = (q - v).astype(jnp.float32)
    return jnp.sum(d * d)
