"""MPQPolicy: the searched per-layer (b_w, b_a) assignment.

The policy is the artifact Eq. 3 produces. It serializes to JSON (deployable
per device, paper §4.3's `z`-device scenario) and converts into the stacked
per-segment bit-index arrays the scanned model consumes.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core import qspec
from repro.core.qspec import QLayer


@dataclass
class MPQPolicy:
    w_bits: Dict[str, int]
    a_bits: Dict[str, int]
    meta: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self):
        if set(self.w_bits) != set(self.a_bits):
            raise ValueError("w_bits / a_bits must cover identical layers")

    # -- constructors ------------------------------------------------------
    @staticmethod
    def uniform(qlayers: Sequence[QLayer], bw: int, ba: int | None = None) -> "MPQPolicy":
        ba = bw if ba is None else ba
        return MPQPolicy({q.name: bw for q in qlayers},
                         {q.name: ba for q in qlayers},
                         meta={"kind": "uniform", "bw": bw, "ba": ba})

    @staticmethod
    def from_choice(qlayers: Sequence[QLayer], choice: np.ndarray,
                    bits: Sequence[int], meta=None) -> "MPQPolicy":
        """Decode an MCKP choice column (index into the (bw, ba) product)."""
        n = len(bits)
        w, a = {}, {}
        for q, c in zip(qlayers, choice):
            i, j = divmod(int(c), n)
            w[q.name] = int(bits[i])
            a[q.name] = int(bits[j])
        return MPQPolicy(w, a, meta=dict(meta or {}))

    # -- accounting --------------------------------------------------------
    def bitops(self, qlayers: Sequence[QLayer], n_tokens: int) -> float:
        return qspec.total_bitops(qlayers, self.w_bits, self.a_bits, n_tokens)

    def size_bytes(self, qlayers: Sequence[QLayer],
                   per_shard: int = 1) -> float:
        """Weight-storage bytes of this policy; ``per_shard=tp`` states the
        same accounting per tensor-parallel shard, so an ILP memory budget
        (or the serve smoke's per-chip gate) can be phrased against one
        device's HBM instead of the replicated total."""
        total = qspec.total_size_bytes(qlayers, self.w_bits)
        return total / max(int(per_shard), 1)

    def avg_bits(self) -> Tuple[float, float]:
        return (float(np.mean(list(self.w_bits.values()))),
                float(np.mean(list(self.a_bits.values()))))

    # -- model-facing view -------------------------------------------------
    def bit_index_arrays(self, qlayers: Sequence[QLayer],
                         bits: Sequence[int]) -> Dict[Tuple[str, Tuple[str, ...]], Dict[str, np.ndarray]]:
        """Per stacked-tensor arrays of bank indices, ordered by unit."""
        lut = {int(b): i for i, b in enumerate(bits)}
        out = {}
        for key, group in qspec.group_by_segment(qlayers).items():
            out[key] = {
                "w": np.asarray([lut[self.w_bits[q.name]] for q in group], np.int32),
                "a": np.asarray([lut[self.a_bits[q.name]] for q in group], np.int32),
            }
        return out

    # -- deployment-time validation ----------------------------------------
    def validate(self, qlayers: Sequence[QLayer],
                 bits: Sequence[int] | None = None,
                 family: str | None = None) -> "MPQPolicy":
        """Check this policy covers exactly the model's QLayers (and, when
        ``bits`` is given, only searched bit-widths). A stale policy file —
        renamed layers, different depth, foreign arch — fails loudly here
        instead of silently mis-dispatching in the serving runtime.

        ``family`` is the served indicator-bank fingerprint
        (``runtime.session.bank_fingerprint``): a policy stamped with
        ``meta["indicator_family"]`` from a *different* training fails,
        because its importances — and hence its bit assignment — were
        learned against scales the served checkpoint does not have. An
        unstamped policy passes for back-compat with pre-bank files."""
        names = {q.name for q in qlayers}
        covered = set(self.w_bits) & set(self.a_bits)
        unknown = sorted((set(self.w_bits) | set(self.a_bits)) - names)
        missing = sorted(names - covered)
        problems = []
        if unknown:
            problems.append(f"unknown layer names {unknown[:5]}"
                            + (f" (+{len(unknown) - 5} more)"
                               if len(unknown) > 5 else ""))
        if missing:
            problems.append(f"missing layer names {missing[:5]}"
                            + (f" (+{len(missing) - 5} more)"
                               if len(missing) > 5 else ""))
        if bits is not None:
            allowed = {int(b) for b in bits}
            bad = sorted({b for b in list(self.w_bits.values())
                          + list(self.a_bits.values())
                          if int(b) not in allowed})
            if bad:
                problems.append(f"bit-widths {bad} outside searched set "
                                f"{sorted(allowed)}")
        if family is not None:
            stamp = self.meta.get("indicator_family")
            if stamp is not None and str(stamp) != str(family):
                problems.append(
                    f"indicator-bank family {str(stamp)!r} != the served "
                    f"checkpoint's fingerprint {str(family)!r} (searched "
                    "from a different training)")
        if problems:
            raise ValueError(
                "MPQPolicy does not match this model's layer table: "
                + "; ".join(problems)
                + ". Was the policy searched for a different arch/config?")
        return self

    # -- serialization -----------------------------------------------------
    SCHEMA_VERSION = 1

    def to_json(self) -> str:
        return json.dumps({"schema": self.SCHEMA_VERSION,
                           "w_bits": self.w_bits, "a_bits": self.a_bits,
                           "meta": self.meta}, indent=2, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "MPQPolicy":
        d = json.loads(s)
        schema = int(d.get("schema", 0))   # 0 = pre-versioning files
        if schema > MPQPolicy.SCHEMA_VERSION:
            raise ValueError(
                f"MPQPolicy schema {schema} is newer than this build "
                f"supports ({MPQPolicy.SCHEMA_VERSION}); refusing to guess "
                "at its layout")
        return MPQPolicy(dict(d["w_bits"]), dict(d["a_bits"]), d.get("meta", {}))

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "MPQPolicy":
        with open(path) as f:
            return MPQPolicy.from_json(f.read())
