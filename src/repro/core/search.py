"""End-to-end MPQ search (paper §3.5): indicators -> ILP -> MPQPolicy.

The objective per layer l and choice (i, j) is  s_a[j] + alpha * s_w[i]
(Eq. 3). Costs are BitOps (Eq. 3b) and/or weight-storage bits (Table 3's
compression-rate constraint).

`reverse=True` implements the Table-6 ablation (sensitive layers get FEWER
bits) by rank-mirroring the indicator table across layers: the most
sensitive layer receives the least-sensitive layer's indicators and vice
versa, then the SAME ILP runs. (Negating the objective would instead
collapse to all-min-bits and under-use the budget — not the paper's
"reversed assignment" at the same BitOps level.)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import ilp, qspec
from repro.core.policy import MPQPolicy
from repro.core.qspec import QLayer

Indicators = Dict[str, Dict[str, np.ndarray]]  # name -> {"w": (n,), "a": (n,)}


@dataclass
class SearchResult:
    policy: MPQPolicy
    objective: float
    bitops: float
    size_bytes: float
    elapsed_s: float
    solver: str
    optimal: bool
    report: Optional[ilp.SolveReport] = None  # the ILP audit trail


def reverse_indicators(qlayers: Sequence[QLayer],
                       indicators: Indicators) -> Indicators:
    """Rank-mirror the indicator table across layers (Table-6 'Ours-R')."""
    names = [q.name for q in qlayers]
    score = {n: float(np.sum(indicators[n]["w"]) + np.sum(indicators[n]["a"]))
             for n in names}
    order = sorted(names, key=lambda n: score[n])
    mirror = {order[i]: order[len(order) - 1 - i] for i in range(len(order))}
    return {n: indicators[mirror[n]] for n in names}


def build_mckp(qlayers: Sequence[QLayer], indicators: Indicators,
               bits: Sequence[int], alpha: float, n_tokens: int,
               reverse: bool = False
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dense (L, n*n) value/bitops/sizebits arrays; choice c = i*n + j."""
    if reverse:
        indicators = reverse_indicators(qlayers, indicators)
    n = len(bits)
    L = len(qlayers)
    values = np.zeros((L, n * n), np.float64)
    cost_ops = np.zeros((L, n * n), np.float64)
    cost_size = np.zeros((L, n * n), np.float64)
    for l, q in enumerate(qlayers):
        s_w = np.asarray(indicators[q.name]["w"], np.float64)
        s_a = np.asarray(indicators[q.name]["a"], np.float64)
        for i, bw in enumerate(bits):
            for j, ba in enumerate(bits):
                c = i * n + j
                values[l, c] = s_a[j] + alpha * s_w[i]
                cost_ops[l, c] = qspec.bitops(q, int(bw), int(ba), n_tokens)
                cost_size[l, c] = qspec.model_bits(q, int(bw))
    return values, cost_ops, cost_size


def search_policy(
    qlayers: Sequence[QLayer],
    indicators: Indicators,
    bits: Sequence[int],
    *,
    alpha: float = 1.0,
    n_tokens: int = 1,
    bitops_budget: Optional[float] = None,
    size_budget_bytes: Optional[float] = None,
    method: str = "dp",
    reverse: bool = False,
) -> SearchResult:
    if bitops_budget is None and size_budget_bytes is None:
        raise ValueError("need at least one constraint (Eq. 3b)")
    values, cost_ops, cost_size = build_mckp(
        qlayers, indicators, bits, alpha, n_tokens, reverse=reverse)

    t0 = time.perf_counter()
    if bitops_budget is not None and size_budget_bytes is not None:
        sol = ilp.solve_mckp_dual(values, cost_ops, bitops_budget,
                                  cost_size, size_budget_bytes * 8.0)
    elif bitops_budget is not None:
        sol = ilp.solve_mckp(values, cost_ops, bitops_budget, method=method)
    else:
        sol = ilp.solve_mckp(values, cost_size, size_budget_bytes * 8.0,
                             method=method)
    elapsed = time.perf_counter() - t0

    report = ilp.build_solve_report(
        [q.name for q in qlayers], [int(b) for b in bits], sol, values,
        {"bitops": cost_ops, "size_bits": cost_size},
        {"bitops": bitops_budget,
         "size_bits": (size_budget_bytes * 8.0
                       if size_budget_bytes is not None else None)},
        elapsed_s=elapsed,
        meta={
            "kind": "ilp-reversed" if reverse else "ilp",
            "alpha": alpha,
            "n_tokens": n_tokens,
        },
    )
    policy = MPQPolicy.from_choice(
        qlayers, sol.choice, bits,
        meta={
            "kind": "ilp-reversed" if reverse else "ilp",
            "alpha": alpha,
            "bitops_budget": bitops_budget,
            "size_budget_bytes": size_budget_bytes,
            "solver": sol.method,
            "elapsed_s": elapsed,
            "solve_report": report.to_json(),
        },
    )
    return SearchResult(
        policy=policy,
        objective=float(abs(sol.value)),
        bitops=policy.bitops(qlayers, n_tokens),
        size_bytes=policy.size_bytes(qlayers),
        elapsed_s=elapsed,
        solver=sol.method,
        optimal=sol.optimal,
        report=report,
    )


def bitops_budget_for_uniform(qlayers: Sequence[QLayer], bits: int,
                              n_tokens: int = 1) -> float:
    """Budget equal to a uniform `bits`-bit network — the paper's
    '3-bit level' / '4-bit level' constraint definition."""
    u = MPQPolicy.uniform(qlayers, bits)
    return u.bitops(qlayers, n_tokens)


def size_budget_for_rate(qlayers: Sequence[QLayer], fp_bits: int,
                         rate: float) -> float:
    """Size budget from a compression rate (Table 3: 12.2x over fp32)."""
    fp_bytes = sum(q.w_params for q in qlayers) * fp_bits / 8.0
    return fp_bytes / rate
