"""Joint one-shot importance-indicator training (paper §3.4).

At every step the atomic update runs `n` forward/backward passes — the whole
network uniformly at bit option k — plus ONE pass at a random per-layer bit
assignment (the "communication" pass, one-shot-NAS style). The n+1 gradients
are aggregated and applied in a single optimizer update, so all
`M = 2 * L * n` indicators are learned in one QAT run instead of M runs.

Paper finding (§3.4 last paragraph): freezing the backbone weights and
training *only* the indicators yields near-identical indicators; both modes
are exposed (``freeze_backbone``).

``extract_indicators`` then reads the learned banks out of the param tree in
QLayer order, producing exactly what ``repro.core.search.search_policy``
(Eq. 3) consumes.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import ModelConfig
from repro.core.qspec import QLayer
from repro.dist.axes import NO_AXES, MeshAxes
from repro.models import lm
from repro.models.quant_layers import QuantContext

Indicators = Dict[str, Dict[str, np.ndarray]]


def importance_optimizer(lr: float = 0.01, momentum: float = 0.9,
                         freeze_backbone: bool = True,
                         clip_norm: Optional[float] = 1.0) -> optim.Optimizer:
    """Paper §4.1: SGD, lr=0.01. With freeze_backbone only the scale banks
    (the indicators) receive updates."""
    base = optim.sgd(lr, momentum=momentum, clip_norm=clip_norm)
    if freeze_backbone:
        return optim.masked(base, optim.indicator_only_mask)
    return base


def make_importance_step(cfg: ModelConfig, ctx: QuantContext,
                         optimizer: optim.Optimizer,
                         axes: MeshAxes = NO_AXES, *,
                         include_random_pass: bool = True,
                         remat: bool = True) -> Callable:
    """Returns jit-able step(params, opt_state, batch, rng) ->
    (params, opt_state, metrics). One call = the paper's atomic operation."""
    n = cfg.n_bits

    def loss_of(params, batch, bits):
        return lm.loss_fn(params, cfg, batch, bits, ctx, axes, remat=remat)[0]

    def step(params, opt_state, batch, rng):
        grads_sum = None
        losses = []
        for k in range(n):                         # uniform-bit passes
            l, g = jax.value_and_grad(loss_of)(params, batch,
                                               lm.bits_uniform(cfg, k))
            losses.append(l)
            grads_sum = g if grads_sum is None else \
                jax.tree.map(jnp.add, grads_sum, g)
        if include_random_pass:                    # communication pass
            l_r, g = jax.value_and_grad(loss_of)(
                params, batch, lm.bits_random(cfg, rng))
            grads_sum = jax.tree.map(jnp.add, grads_sum, g)
        else:
            l_r = jnp.zeros(())
        # aggregate the n+1 gradients into one atomic update (§3.4):
        # backbone weights receive signal from every pass -> average over
        # all of them. A bank ENTRY is selected by its own uniform pass
        # plus at most the random pass, so the banks are normalized by
        # that upper bound (2) instead — a deliberately conservative
        # fixed constant, not a per-entry average: a flat 1/(n+1) would
        # dilute the indicator gradients ~(n+1)/2-fold relative to their
        # lr, while the exact expectation (1 + 1/n) over-amplifies the
        # entries the random pass did not actually select
        n_passes = n + (1 if include_random_pass else 0)
        bank_passes = 2 if include_random_pass else 1
        grads = jax.tree_util.tree_map_with_path(
            lambda path, g: g / (bank_passes
                                 if optim.indicator_only_mask(path, g)
                                 else n_passes),
            grads_sum)

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {"loss_uniform": jnp.stack(losses), "loss_random": l_r}
        return params, opt_state, metrics

    return step


def train_importance(params, cfg: ModelConfig, ctx: QuantContext,
                     batches, *, lr: float = 0.01,
                     freeze_backbone: bool = True,
                     axes: MeshAxes = NO_AXES, remat: bool = False,
                     jit: bool = True):
    """Convenience loop: run the joint scheme over `batches` (an iterable).
    Returns (params, history)."""
    opt = importance_optimizer(lr, freeze_backbone=freeze_backbone)
    step = make_importance_step(cfg, ctx, opt, axes, remat=remat)
    if jit:
        step = jax.jit(step)
    opt_state = opt.init(params)
    rng = jax.random.PRNGKey(1234)
    history = []
    for batch in batches:
        rng, sub = jax.random.split(rng)
        params, opt_state, m = step(params, opt_state, batch, sub)
        history.append(jax.device_get(m))
    return params, history


# ---------------------------------------------------------------------------
# indicator extraction
# ---------------------------------------------------------------------------
def _qparam_node(params, segment: str, path):
    seg, idx = segment.split(".")
    node = params[seg][idx]
    for k in path:
        node = node[k]
    return node


def extract_indicators(params, cfg: ModelConfig,
                       qlayers: Optional[Sequence[QLayer]] = None) -> Indicators:
    """Read the learned (n_bits,) banks per QLayer. Body banks are stacked
    (repeats, ..., n); MoE expert stacks are averaged over the expert dim —
    one QLayer spans the whole stacked tensor."""
    qlayers = qlayers if qlayers is not None else lm.enumerate_qlayers(cfg)
    out: Indicators = {}
    for q in qlayers:
        node = _qparam_node(params, q.segment, q.path)
        s_w = np.asarray(jax.device_get(node["s_w"]), np.float64)
        s_a = np.asarray(jax.device_get(node["s_a"]), np.float64)
        if q.segment.startswith("body."):
            s_w, s_a = s_w[q.unit], s_a[q.unit]
        while s_w.ndim > 1:            # MoE expert dim
            s_w = s_w.mean(axis=0)
        while s_a.ndim > 1:
            s_a = s_a.mean(axis=0)
        out[q.name] = {"w": np.abs(s_w), "a": np.abs(s_a)}
    return out


def indicators_summary(ind: Indicators, bits) -> str:
    lines = ["layer".ljust(28) + "  " + "  ".join(f"w@{b}b" for b in bits)
             + "  |  " + "  ".join(f"a@{b}b" for b in bits)]
    for name, d in ind.items():
        lines.append(name.ljust(28) + "  "
                     + "  ".join(f"{v:.4f}" for v in d["w"])
                     + "  |  " + "  ".join(f"{v:.4f}" for v in d["a"]))
    return "\n".join(lines)
