"""QLayer: the unit of mixed-precision search.

A QLayer is one quantized einsum in the network — the LM analog of the
paper's per-conv-layer quantizer. It carries everything the ILP needs
(activated MACs/token for BitOps, weight param count for model size) and
everything the model needs to route indicator banks (segment/unit/path).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple


@dataclass(frozen=True)
class QLayer:
    name: str                  # globally unique, e.g. "blocks.3.attn.wq"
    segment: str               # param segment (scan stack) this lives in
    unit: int                  # index within the segment's stacked dim
    path: Tuple[str, ...]      # param path inside one unit, e.g. ("attn", "wq")
    in_dim: int
    out_dim: int
    n_mats: int                # stacked matrices in the tensor (MoE experts)
    macs_per_token: float      # *activated* MACs per token (top-k for MoE)
    w_params: int              # total weight elements (all mats)
    kind: str                  # attn | mlp | moe | rec | rwkv | cross


def bitops(q: QLayer, bw: int, ba: int, n_tokens: int) -> float:
    """Paper's BitOps(l) = MACs(l) * b_w * b_a (Eq. 3b)."""
    return q.macs_per_token * n_tokens * bw * ba


def model_bits(q: QLayer, bw: int) -> float:
    """Weight-storage bits for the size/compression-rate constraint."""
    return q.w_params * bw


def total_bitops(qlayers: Sequence[QLayer], w_bits: Dict[str, int],
                 a_bits: Dict[str, int], n_tokens: int) -> float:
    return sum(bitops(q, w_bits[q.name], a_bits[q.name], n_tokens) for q in qlayers)


def total_size_bytes(qlayers: Sequence[QLayer], w_bits: Dict[str, int]) -> float:
    return sum(model_bits(q, w_bits[q.name]) for q in qlayers) / 8.0


def fp_bitops(qlayers: Sequence[QLayer], n_tokens: int, fp_bits: int = 32) -> float:
    return sum(bitops(q, fp_bits, fp_bits, n_tokens) for q in qlayers)


def group_by_segment(qlayers: Sequence[QLayer]) -> Dict[Tuple[str, Tuple[str, ...]], List[QLayer]]:
    """Group QLayers by (segment, path) — one group per stacked param tensor,
    ordered by unit index. Used to build per-segment bit-index arrays."""
    groups: Dict[Tuple[str, Tuple[str, ...]], List[QLayer]] = {}
    for q in qlayers:
        groups.setdefault((q.segment, q.path), []).append(q)
    for g in groups.values():
        g.sort(key=lambda q: q.unit)
    return groups
