"""Multiple-Choice Knapsack (the paper's Eq. 3 ILP) — in-repo solvers.

The paper solves Eq. 3 with PuLP; PuLP is not available offline, so we ship
three solvers with cross-checked semantics:

  * ``solve_bruteforce`` — exponential, tests only.
  * ``solve_dp``         — exact on a ceil-rounded integer cost grid
                           (admissible: rounding costs *up* keeps every
                           returned solution feasible for the true budget).
  * ``solve_lagrangian`` — bisection on the dual multiplier + greedy repair;
                           returns a certified duality gap.

All solvers MINIMIZE sum of per-layer choice values subject to
sum of per-layer choice costs <= budget, picking exactly one choice per layer
(Eq. 3a/3b/3c). Inputs are dense (L, C) float64 arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
import numpy as np


@dataclass
class MCKPSolution:
    choice: np.ndarray          # (L,) int — chosen column per layer
    value: float                # achieved objective
    cost: float                 # achieved total cost
    budget: float
    method: str
    optimal: bool               # True when the method certifies optimality
    gap: float = 0.0            # duality gap for lagrangian (abs value units)

    @property
    def feasible(self) -> bool:
        return self.cost <= self.budget * (1 + 1e-12)


class InfeasibleError(ValueError):
    pass


def _validate(values: np.ndarray, costs: np.ndarray, budget: float):
    values = np.asarray(values, np.float64)
    costs = np.asarray(costs, np.float64)
    if values.shape != costs.shape or values.ndim != 2:
        raise ValueError(f"values/costs must be (L, C); got {values.shape} vs {costs.shape}")
    if np.any(costs < 0):
        raise ValueError("negative costs unsupported")
    min_cost = costs.min(axis=1).sum()
    if min_cost > budget:
        raise InfeasibleError(
            f"budget {budget:.3e} below minimum achievable cost {min_cost:.3e}")
    return values, costs


def solve_bruteforce(values, costs, budget: float) -> MCKPSolution:
    values, costs = _validate(values, costs, budget)
    L, C = values.shape
    if C ** L > 2_000_000:
        raise ValueError("bruteforce only for tiny instances")
    best_v, best_choice = np.inf, None
    idx = np.zeros(L, dtype=int)
    while True:
        c = costs[np.arange(L), idx].sum()
        if c <= budget:
            v = values[np.arange(L), idx].sum()
            if v < best_v:
                best_v, best_choice = v, idx.copy()
        # odometer increment
        pos = L - 1
        while pos >= 0:
            idx[pos] += 1
            if idx[pos] < C:
                break
            idx[pos] = 0
            pos -= 1
        if pos < 0:
            break
    if best_choice is None:
        raise InfeasibleError("no feasible assignment")
    cost = costs[np.arange(L), best_choice].sum()
    return MCKPSolution(best_choice, float(best_v), float(cost), budget,
                        "bruteforce", optimal=True)


def _greedy_improve(values: np.ndarray, costs: np.ndarray, budget: float,
                    choice: np.ndarray) -> np.ndarray:
    """Single-layer swaps that reduce value while staying within the TRUE
    budget. Recovers solutions the ceil-rounded DP grid excludes at tight
    budgets and polishes the Lagrangian primal."""
    L = values.shape[0]
    rows = np.arange(L)
    choice = choice.copy()
    improved = True
    while improved:
        improved = False
        cur_cost = costs[rows, choice].sum()
        for l in range(L):
            c0 = choice[l]
            slack = budget - (cur_cost - costs[l, c0])
            cand = np.where(costs[l] <= slack, values[l], np.inf)
            c1 = int(np.argmin(cand))
            if cand[c1] < values[l, c0] - 1e-15:
                choice[l] = c1
                cur_cost = cur_cost - costs[l, c0] + costs[l, c1]
                improved = True
    return choice


def solve_dp(values, costs, budget: float, bins: int = 8192) -> MCKPSolution:
    """Exact DP on a ceil-rounded cost grid + greedy true-budget polish.

    Cost unit = budget / bins. Each choice cost is rounded UP to grid units so
    any solution the DP accepts is feasible for the real budget; optimality is
    exact on the rounded instance (gap vanishes as bins grows — tests compare
    against bruteforce). The greedy pass then reclaims budget the ceil
    rounding left on the table (tight integral instances).
    """
    values, costs = _validate(values, costs, budget)
    L, C = values.shape
    unit = budget / bins if budget > 0 else 1.0
    icost = np.ceil(costs / unit - 1e-12).astype(np.int64)  # (L, C)
    icost = np.clip(icost, 0, bins + 1)

    NEG = np.inf
    dp = np.full(bins + 1, NEG)
    dp[0] = 0.0
    # dp[b] = min value over layer-prefixes whose rounded cost is EXACTLY b;
    # the final answer is argmin over all b <= bins (i.e. cost <= budget).
    back = np.zeros((L, bins + 1), dtype=np.int8 if C < 127 else np.int16)
    for l in range(L):
        new_dp = np.full(bins + 1, NEG)
        new_back = np.zeros(bins + 1, dtype=back.dtype)
        for c in range(C):
            ic, v = int(icost[l, c]), values[l, c]
            if ic > bins:
                continue
            cand = np.full(bins + 1, NEG)
            cand[ic:] = dp[: bins + 1 - ic] + v
            better = cand < new_dp
            new_dp = np.where(better, cand, new_dp)
            new_back = np.where(better, c, new_back)
        dp = new_dp
        back[l] = new_back
        if not np.isfinite(dp).any():
            raise InfeasibleError("DP infeasible at layer %d" % l)

    # best terminal state
    b = int(np.argmin(dp))
    if not np.isfinite(dp[b]):
        raise InfeasibleError("no feasible assignment")
    choice = np.zeros(L, dtype=int)
    for l in range(L - 1, -1, -1):
        c = int(back[l, b])
        choice[l] = c
        b -= int(icost[l, c])
    choice = _greedy_improve(values, costs, budget, choice)
    cost = costs[np.arange(L), choice].sum()
    value = values[np.arange(L), choice].sum()
    return MCKPSolution(choice, float(value), float(cost), budget, "dp",
                        optimal=True)


def solve_lagrangian(values, costs, budget: float, iters: int = 64) -> MCKPSolution:
    """Bisection on lambda for min_x sum(v + lam*c) with greedy repair.

    Fast (O(L*C*iters)) and near-optimal; returns the certified gap between
    the best primal found and the Lagrangian dual bound.
    """
    values, costs = _validate(values, costs, budget)
    L = values.shape[0]
    rows = np.arange(L)

    def primal(lam: float):
        choice = np.argmin(values + lam * costs, axis=1)
        return choice, costs[rows, choice].sum(), values[rows, choice].sum()

    lo, hi = 0.0, 1.0
    # grow hi until feasible
    choice_hi, cost_hi, _ = primal(hi)
    guard = 0
    while cost_hi > budget and guard < 128:
        hi *= 4.0
        choice_hi, cost_hi, _ = primal(hi)
        guard += 1
    if cost_hi > budget:
        raise InfeasibleError("lagrangian could not reach feasibility")

    best_choice, best_cost, best_val = choice_hi, cost_hi, values[rows, choice_hi].sum()
    dual_bound = -np.inf
    for _ in range(iters):
        lam = 0.5 * (lo + hi)
        choice, cost, val = primal(lam)
        dual_bound = max(dual_bound, val + lam * (cost - budget))
        if cost <= budget:
            hi = lam
            if val < best_val:
                best_choice, best_cost, best_val = choice, cost, val
        else:
            lo = lam

    best_choice = _greedy_improve(values, costs, budget, best_choice)
    best_cost = costs[rows, best_choice].sum()
    best_val = values[rows, best_choice].sum()
    gap = float(best_val - dual_bound)
    return MCKPSolution(best_choice, float(best_val), float(best_cost), budget,
                        "lagrangian", optimal=gap <= 1e-9, gap=max(gap, 0.0))


def solve_mckp(values, costs, budget: float, method: str = "auto",
               bins: int = 8192) -> MCKPSolution:
    if method == "auto":
        method = "dp"
    if method == "bruteforce":
        return solve_bruteforce(values, costs, budget)
    if method == "dp":
        return solve_dp(values, costs, budget, bins=bins)
    if method == "lagrangian":
        return solve_lagrangian(values, costs, budget)
    raise ValueError(f"unknown method {method!r}")


def solve_mckp_dual(values, costs_a, budget_a: float, costs_b,
                    budget_b: float, outer_iters: int = 40,
                    bins: int = 8192) -> MCKPSolution:
    """Two simultaneous budgets (paper Table 3: BitOps AND compression rate).

    Lagrangian-relax constraint B into the objective, bisect its multiplier,
    and solve the remaining single-constraint MCKP exactly with the DP.
    """
    values = np.asarray(values, np.float64)
    costs_a = np.asarray(costs_a, np.float64)
    costs_b = np.asarray(costs_b, np.float64)
    L = values.shape[0]
    rows = np.arange(L)

    def inner(mu: float) -> MCKPSolution:
        return solve_dp(values + mu * costs_b, costs_a, budget_a, bins=bins)

    sol = inner(0.0)
    if costs_b[rows, sol.choice].sum() <= budget_b:
        sol.method = "dual(mu=0)"
        return sol
    lo, mu = 0.0, 1.0
    sol_hi = inner(mu)
    guard = 0
    while costs_b[rows, sol_hi.choice].sum() > budget_b and guard < 60:
        mu *= 4.0
        sol_hi = inner(mu)
        guard += 1
    if costs_b[rows, sol_hi.choice].sum() > budget_b:
        raise InfeasibleError("dual-budget instance infeasible")
    hi = mu
    best = sol_hi
    for _ in range(outer_iters):
        mid = 0.5 * (lo + hi)
        s = inner(mid)
        if costs_b[rows, s.choice].sum() <= budget_b:
            hi = mid
            if values[rows, s.choice].sum() <= values[rows, best.choice].sum():
                best = s
        else:
            lo = mid
    choice = best.choice
    return MCKPSolution(
        choice,
        float(values[rows, choice].sum()),
        float(costs_a[rows, choice].sum()),
        budget_a,
        "dual",
        optimal=False,
    )
