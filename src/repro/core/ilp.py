"""Multiple-Choice Knapsack (the paper's Eq. 3 ILP) — in-repo solvers.

The paper solves Eq. 3 with PuLP; PuLP is not available offline, so we ship
three solvers with cross-checked semantics:

  * ``solve_bruteforce`` — exponential, tests only.
  * ``solve_dp``         — exact on a ceil-rounded integer cost grid
                           (admissible: rounding costs *up* keeps every
                           returned solution feasible for the true budget).
  * ``solve_lagrangian`` — bisection on the dual multiplier + greedy repair;
                           returns a certified duality gap.

All solvers MINIMIZE sum of per-layer choice values subject to
sum of per-layer choice costs <= budget, picking exactly one choice per layer
(Eq. 3a/3b/3c). Inputs are dense (L, C) float64 arrays.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


@dataclass
class MCKPSolution:
    choice: np.ndarray          # (L,) int — chosen column per layer
    value: float                # achieved objective
    cost: float                 # achieved total cost
    budget: float
    method: str
    optimal: bool               # True when the method certifies optimality
    gap: float = 0.0            # duality gap for lagrangian (abs value units)

    @property
    def feasible(self) -> bool:
        return self.cost <= self.budget * (1 + 1e-12)


class InfeasibleError(ValueError):
    pass


def _validate(values: np.ndarray, costs: np.ndarray, budget: float):
    values = np.asarray(values, np.float64)
    costs = np.asarray(costs, np.float64)
    if values.shape != costs.shape or values.ndim != 2:
        raise ValueError(f"values/costs must be (L, C); got {values.shape} vs {costs.shape}")
    if np.any(costs < 0):
        raise ValueError("negative costs unsupported")
    min_cost = costs.min(axis=1).sum()
    if min_cost > budget:
        raise InfeasibleError(
            f"budget {budget:.3e} below minimum achievable cost {min_cost:.3e}")
    return values, costs


def solve_bruteforce(values, costs, budget: float) -> MCKPSolution:
    values, costs = _validate(values, costs, budget)
    L, C = values.shape
    if C ** L > 2_000_000:
        raise ValueError("bruteforce only for tiny instances")
    best_v, best_choice = np.inf, None
    idx = np.zeros(L, dtype=int)
    while True:
        c = costs[np.arange(L), idx].sum()
        if c <= budget:
            v = values[np.arange(L), idx].sum()
            if v < best_v:
                best_v, best_choice = v, idx.copy()
        # odometer increment
        pos = L - 1
        while pos >= 0:
            idx[pos] += 1
            if idx[pos] < C:
                break
            idx[pos] = 0
            pos -= 1
        if pos < 0:
            break
    if best_choice is None:
        raise InfeasibleError("no feasible assignment")
    cost = costs[np.arange(L), best_choice].sum()
    return MCKPSolution(best_choice, float(best_v), float(cost), budget,
                        "bruteforce", optimal=True)


def _greedy_improve(values: np.ndarray, costs: np.ndarray, budget: float,
                    choice: np.ndarray) -> np.ndarray:
    """Single-layer swaps that reduce value while staying within the TRUE
    budget. Recovers solutions the ceil-rounded DP grid excludes at tight
    budgets and polishes the Lagrangian primal."""
    L = values.shape[0]
    rows = np.arange(L)
    choice = choice.copy()
    improved = True
    while improved:
        improved = False
        cur_cost = costs[rows, choice].sum()
        for l in range(L):
            c0 = choice[l]
            slack = budget - (cur_cost - costs[l, c0])
            cand = np.where(costs[l] <= slack, values[l], np.inf)
            c1 = int(np.argmin(cand))
            if cand[c1] < values[l, c0] - 1e-15:
                choice[l] = c1
                cur_cost = cur_cost - costs[l, c0] + costs[l, c1]
                improved = True
    return choice


def solve_dp(values, costs, budget: float, bins: int = 8192) -> MCKPSolution:
    """Exact DP on a ceil-rounded cost grid + greedy true-budget polish.

    Cost unit = budget / bins. Each choice cost is rounded UP to grid units so
    any solution the DP accepts is feasible for the real budget; optimality is
    exact on the rounded instance (gap vanishes as bins grows — tests compare
    against bruteforce). The greedy pass then reclaims budget the ceil
    rounding left on the table (tight integral instances).
    """
    values, costs = _validate(values, costs, budget)
    L, C = values.shape
    unit = budget / bins if budget > 0 else 1.0
    icost = np.ceil(costs / unit - 1e-12).astype(np.int64)  # (L, C)
    icost = np.clip(icost, 0, bins + 1)

    NEG = np.inf
    dp = np.full(bins + 1, NEG)
    dp[0] = 0.0
    # dp[b] = min value over layer-prefixes whose rounded cost is EXACTLY b;
    # the final answer is argmin over all b <= bins (i.e. cost <= budget).
    back = np.zeros((L, bins + 1), dtype=np.int8 if C < 127 else np.int16)
    for l in range(L):
        new_dp = np.full(bins + 1, NEG)
        new_back = np.zeros(bins + 1, dtype=back.dtype)
        for c in range(C):
            ic, v = int(icost[l, c]), values[l, c]
            if ic > bins:
                continue
            cand = np.full(bins + 1, NEG)
            cand[ic:] = dp[: bins + 1 - ic] + v
            better = cand < new_dp
            new_dp = np.where(better, cand, new_dp)
            new_back = np.where(better, c, new_back)
        dp = new_dp
        back[l] = new_back
        if not np.isfinite(dp).any():
            raise InfeasibleError("DP infeasible at layer %d" % l)

    # best terminal state
    b = int(np.argmin(dp))
    if not np.isfinite(dp[b]):
        raise InfeasibleError("no feasible assignment")
    choice = np.zeros(L, dtype=int)
    for l in range(L - 1, -1, -1):
        c = int(back[l, b])
        choice[l] = c
        b -= int(icost[l, c])
    choice = _greedy_improve(values, costs, budget, choice)
    cost = costs[np.arange(L), choice].sum()
    value = values[np.arange(L), choice].sum()
    return MCKPSolution(choice, float(value), float(cost), budget, "dp",
                        optimal=True)


def solve_lagrangian(values, costs, budget: float, iters: int = 64) -> MCKPSolution:
    """Bisection on lambda for min_x sum(v + lam*c) with greedy repair.

    Fast (O(L*C*iters)) and near-optimal; returns the certified gap between
    the best primal found and the Lagrangian dual bound.
    """
    values, costs = _validate(values, costs, budget)
    L = values.shape[0]
    rows = np.arange(L)

    def primal(lam: float):
        choice = np.argmin(values + lam * costs, axis=1)
        return choice, costs[rows, choice].sum(), values[rows, choice].sum()

    lo, hi = 0.0, 1.0
    # grow hi until feasible
    choice_hi, cost_hi, _ = primal(hi)
    guard = 0
    while cost_hi > budget and guard < 128:
        hi *= 4.0
        choice_hi, cost_hi, _ = primal(hi)
        guard += 1
    if cost_hi > budget:
        raise InfeasibleError("lagrangian could not reach feasibility")

    best_choice, best_cost, best_val = choice_hi, cost_hi, values[rows, choice_hi].sum()
    dual_bound = -np.inf
    for _ in range(iters):
        lam = 0.5 * (lo + hi)
        choice, cost, val = primal(lam)
        dual_bound = max(dual_bound, val + lam * (cost - budget))
        if cost <= budget:
            hi = lam
            if val < best_val:
                best_choice, best_cost, best_val = choice, cost, val
        else:
            lo = lam

    best_choice = _greedy_improve(values, costs, budget, best_choice)
    best_cost = costs[rows, best_choice].sum()
    best_val = values[rows, best_choice].sum()
    gap = float(best_val - dual_bound)
    return MCKPSolution(best_choice, float(best_val), float(best_cost), budget,
                        "lagrangian", optimal=gap <= 1e-9, gap=max(gap, 0.0))


def solve_mckp(values, costs, budget: float, method: str = "auto",
               bins: int = 8192) -> MCKPSolution:
    if method == "auto":
        method = "dp"
    if method == "bruteforce":
        return solve_bruteforce(values, costs, budget)
    if method == "dp":
        return solve_dp(values, costs, budget, bins=bins)
    if method == "lagrangian":
        return solve_lagrangian(values, costs, budget)
    raise ValueError(f"unknown method {method!r}")


def solve_mckp_dual(values, costs_a, budget_a: float, costs_b,
                    budget_b: float, outer_iters: int = 40,
                    bins: int = 8192) -> MCKPSolution:
    """Two simultaneous budgets (paper Table 3: BitOps AND compression rate).

    Lagrangian-relax constraint B into the objective, bisect its multiplier,
    and solve the remaining single-constraint MCKP exactly with the DP.
    """
    values = np.asarray(values, np.float64)
    costs_a = np.asarray(costs_a, np.float64)
    costs_b = np.asarray(costs_b, np.float64)
    L = values.shape[0]
    rows = np.arange(L)

    def inner(mu: float) -> MCKPSolution:
        return solve_dp(values + mu * costs_b, costs_a, budget_a, bins=bins)

    sol = inner(0.0)
    if costs_b[rows, sol.choice].sum() <= budget_b:
        sol.method = "dual(mu=0)"
        return sol
    lo, mu = 0.0, 1.0
    sol_hi = inner(mu)
    guard = 0
    while costs_b[rows, sol_hi.choice].sum() > budget_b and guard < 60:
        mu *= 4.0
        sol_hi = inner(mu)
        guard += 1
    if costs_b[rows, sol_hi.choice].sum() > budget_b:
        raise InfeasibleError("dual-budget instance infeasible")
    hi = mu
    best = sol_hi
    for _ in range(outer_iters):
        mid = 0.5 * (lo + hi)
        s = inner(mid)
        if costs_b[rows, s.choice].sum() <= budget_b:
            hi = mid
            if values[rows, s.choice].sum() <= values[rows, best.choice].sum():
                best = s
        else:
            lo = mid
    choice = best.choice
    return MCKPSolution(
        choice,
        float(values[rows, choice].sum()),
        float(costs_a[rows, choice].sum()),
        budget_a,
        "dual",
        optimal=False,
    )

# ---------------------------------------------------------------------------
# SolveReport: the ILP audit trail
# ---------------------------------------------------------------------------
SOLVE_REPORT_SCHEMA = 1


@dataclass
class SolveReport:
    """Structured audit of one MCKP solve: *why* each layer got its bits.

    Everything the serving side needs to explain (and re-verify) a
    policy: the candidate grid, per-layer chosen bits, the objective
    decomposed per layer (``importance``), and every constraint with its
    used cost and slack. Round-trips to JSON (``to_json``/``from_json``)
    so ``checkpoint`` can embed it in the serving bundle and ``serve
    --explain-policy`` can render it back as a table.

    Replaying the audit is cheap and exact: ``chosen_w``/``chosen_a``
    rebuilt into an ``MPQPolicy`` must validate against the qlayers, and
    ``policy.size_bytes * 8`` must equal the ``size_bits`` constraint's
    ``used`` — the property the tests pin.
    """

    layers: List[str]                    # per-layer site names
    bits: List[int]                      # searched candidate widths
    chosen_w: List[int]                  # chosen weight bits per layer
    chosen_a: List[int]                  # chosen activation bits per layer
    importance: List[float]              # per-layer chosen objective term
    candidate_values: List[List[float]]  # (L, n*n) objective grid
    candidate_costs: Dict[str, List[List[float]]]  # name -> (L, n*n)
    constraints: List[Dict[str, Any]]    # name/budget/used/slack/binding
    objective: float
    solver: str
    optimal: bool
    elapsed_s: float
    meta: Dict[str, Any] = field(default_factory=dict)
    schema: int = SOLVE_REPORT_SCHEMA

    # -- accessors ---------------------------------------------------------
    @property
    def binding(self) -> str:
        """Name of the binding (smallest relative slack) constraint."""
        for c in self.constraints:
            if c.get("binding"):
                return str(c["name"])
        return "none"

    def constraint(self, name: str) -> Dict[str, Any]:
        for c in self.constraints:
            if c["name"] == name:
                return c
        raise KeyError(f"no constraint {name!r} in report")

    def policy_bits(self) -> Dict[str, Dict[str, int]]:
        """{"w_bits": {...}, "a_bits": {...}} keyed by layer name."""
        return {
            "w_bits": dict(zip(self.layers, self.chosen_w)),
            "a_bits": dict(zip(self.layers, self.chosen_a)),
        }

    # -- json round-trip ---------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "schema": self.schema,
            "layers": list(self.layers),
            "bits": [int(b) for b in self.bits],
            "chosen_w": [int(b) for b in self.chosen_w],
            "chosen_a": [int(b) for b in self.chosen_a],
            "importance": [float(v) for v in self.importance],
            "candidate_values": [[float(v) for v in row]
                                 for row in self.candidate_values],
            "candidate_costs": {k: [[float(v) for v in row] for row in m]
                                for k, m in self.candidate_costs.items()},
            "constraints": [dict(c) for c in self.constraints],
            "objective": float(self.objective),
            "solver": self.solver,
            "optimal": bool(self.optimal),
            "elapsed_s": float(self.elapsed_s),
            "meta": dict(self.meta),
        }

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "SolveReport":
        schema = int(obj.get("schema", 0))
        if schema > SOLVE_REPORT_SCHEMA:
            raise ValueError(
                f"SolveReport schema {schema} is newer than supported "
                f"{SOLVE_REPORT_SCHEMA}")
        return cls(
            layers=list(obj["layers"]),
            bits=[int(b) for b in obj["bits"]],
            chosen_w=[int(b) for b in obj["chosen_w"]],
            chosen_a=[int(b) for b in obj["chosen_a"]],
            importance=[float(v) for v in obj["importance"]],
            candidate_values=[list(map(float, r))
                              for r in obj["candidate_values"]],
            candidate_costs={k: [list(map(float, r)) for r in m]
                             for k, m in obj["candidate_costs"].items()},
            constraints=[dict(c) for c in obj["constraints"]],
            objective=float(obj["objective"]),
            solver=str(obj["solver"]),
            optimal=bool(obj["optimal"]),
            elapsed_s=float(obj["elapsed_s"]),
            meta=dict(obj.get("meta", {})),
            schema=schema,
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "SolveReport":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- human rendering ---------------------------------------------------
    def render_table(self) -> str:
        """The ``serve --explain-policy`` table: one row per layer plus
        the constraint footer naming the binding budget."""
        size = self.candidate_costs.get("size_bits")
        ops = self.candidate_costs.get("bitops")
        n = len(self.bits)
        header = (f"{'layer':<28} {'w':>2} {'a':>2} {'importance':>12} "
                  f"{'kbytes':>10} {'bitops':>12}")
        lines = [header, "-" * len(header)]
        for l, name in enumerate(self.layers):
            i = self.bits.index(self.chosen_w[l])
            j = self.bits.index(self.chosen_a[l])
            c = i * n + j
            kb = size[l][c] / 8.0 / 1024.0 if size else float("nan")
            bo = ops[l][c] if ops else float("nan")
            lines.append(f"{name:<28} {self.chosen_w[l]:>2} "
                         f"{self.chosen_a[l]:>2} {self.importance[l]:>12.5g} "
                         f"{kb:>10.2f} {bo:>12.4g}")
        lines.append("")
        lines.append(f"objective {self.objective:.6g}  solver {self.solver}"
                     f"{' (optimal)' if self.optimal else ''}  "
                     f"elapsed {self.elapsed_s * 1e3:.1f} ms")
        for c in self.constraints:
            mark = "  <- binding" if c.get("binding") else ""
            if c["budget"] is None:
                lines.append(f"constraint {c['name']:<10} budget -         "
                             f"used {c['used']:.4g}  (tracked, unconstrained)")
            else:
                lines.append(
                    f"constraint {c['name']:<10} budget {c['budget']:.4g}  "
                    f"used {c['used']:.4g}  slack {c['slack']:.4g} "
                    f"({100.0 * c['slack_frac']:.1f}%){mark}")
        return "\n".join(lines)


def _constraint_rows(used_by_name: Dict[str, float],
                     budget_by_name: Dict[str, Optional[float]]
                     ) -> List[Dict[str, Any]]:
    """Constraint dicts with slack; the smallest relative slack among
    constraints that HAVE a budget is marked binding."""
    rows: List[Dict[str, Any]] = []
    for name, used in used_by_name.items():
        budget = budget_by_name.get(name)
        if budget is None:
            rows.append({"name": name, "budget": None, "used": float(used),
                         "slack": None, "slack_frac": 0.0, "binding": False})
            continue
        slack = float(budget) - float(used)
        frac = slack / budget if budget else 0.0
        rows.append({"name": name, "budget": float(budget),
                     "used": float(used), "slack": slack,
                     "slack_frac": frac, "binding": False})
    budgeted = [r for r in rows if r["budget"] is not None]
    if budgeted:
        min(budgeted, key=lambda r: r["slack_frac"])["binding"] = True
    return rows


def build_solve_report(
    layers: Sequence[str],
    bits: Sequence[int],
    sol: MCKPSolution,
    values: np.ndarray,
    cost_matrices: Dict[str, np.ndarray],
    budgets: Dict[str, Optional[float]],
    *,
    elapsed_s: float = 0.0,
    meta: Optional[Dict[str, Any]] = None,
) -> SolveReport:
    """Compose the audit from a solved instance (search.py's call site).

    ``cost_matrices`` are the dense (L, C) cost grids keyed by constraint
    name; ``budgets`` maps the same names to their budget (None for a
    cost that was tracked but not constrained).
    """
    values = np.asarray(values, np.float64)
    L = len(layers)
    n = len(bits)
    rows = np.arange(L)
    choice = np.asarray(sol.choice, int)
    iw, ja = np.divmod(choice, n)
    used = {name: float(np.asarray(m, np.float64)[rows, choice].sum())
            for name, m in cost_matrices.items()}
    return SolveReport(
        layers=[str(s) for s in layers],
        bits=[int(b) for b in bits],
        chosen_w=[int(bits[i]) for i in iw],
        chosen_a=[int(bits[j]) for j in ja],
        importance=[float(v) for v in values[rows, choice]],
        candidate_values=values.tolist(),
        candidate_costs={k: np.asarray(m, np.float64).tolist()
                         for k, m in cost_matrices.items()},
        constraints=_constraint_rows(used, budgets),
        objective=float(values[rows, choice].sum()),
        solver=sol.method,
        optimal=bool(sol.optimal),
        elapsed_s=float(elapsed_s),
        meta=dict(meta or {}),
    )


def describe_policy_report(qlayers, policy, bits: Sequence[int],
                           n_tokens: int = 1,
                           meta: Optional[Dict[str, Any]] = None
                           ) -> SolveReport:
    """Post-hoc audit for a policy that was NOT produced by a solve here
    (the demo stand-in, a hand-written policy). Cost grids are the real
    qspec accounting; importance is unknown (zeros); budgets are set to
    the used costs, so slack is exactly 0 and the size constraint reads
    as binding. ``meta.kind == "describe"`` marks the provenance.
    """
    from repro.core import qspec  # local import: keep ilp dependency-light

    bits = [int(b) for b in bits]
    n = len(bits)
    L = len(qlayers)
    values = np.zeros((L, n * n), np.float64)
    cost_ops = np.zeros((L, n * n), np.float64)
    cost_size = np.zeros((L, n * n), np.float64)
    choice = np.zeros(L, int)
    for l, q in enumerate(qlayers):
        for i, bw in enumerate(bits):
            for j, ba in enumerate(bits):
                cost_ops[l, i * n + j] = qspec.bitops(q, bw, ba, n_tokens)
                cost_size[l, i * n + j] = qspec.model_bits(q, bw)
        choice[l] = (bits.index(policy.w_bits[q.name]) * n
                     + bits.index(policy.a_bits[q.name]))
    rows = np.arange(L)
    sol = MCKPSolution(choice, 0.0, float(cost_size[rows, choice].sum()),
                       float(cost_size[rows, choice].sum()),
                       method="describe", optimal=False)
    budgets = {"bitops": None,
               "size_bits": float(cost_size[rows, choice].sum())}
    m = {"kind": "describe"}
    m.update(meta or {})
    return build_solve_report(
        [q.name for q in qlayers], bits, sol, values,
        {"bitops": cost_ops, "size_bits": cost_size}, budgets, meta=m)
