"""Per-layer kernel dispatch for packed mixed-precision matmuls.

A ``PackedLinear`` carries its searched bit-widths as static metadata, so
every call site resolves — at trace time — which execution route serves it:

* ``pallas-w4``   — int4 weights in the ``nib4`` layout feed
  ``kernels.quant_matmul.quant_matmul_w4`` directly: the packed bytes are
  the kernel operand and nibbles unpack in the VMEM prologue (HBM never
  sees unpacked codes).
* ``pallas-int8`` — any searched width ≤ 8 lands on a subset of the int8
  grid: codes unpack via XLA, activations quantize on the fly, and the
  matmul runs int8 x int8 -> int32 on the MXU
  (``kernels.quant_matmul.quant_matmul``).
* ``dequant-fp``  — exact fallback for everything the kernels can't tile
  (stacked MoE expert einsums, row-parallel ``(N,K)`` weight orientation,
  per-channel scales, odd contraction dims): dequantize the codes and run
  the same fp einsum as the fake-quant training graph. This route is
  *bit-exact* with that graph — it is the default off-TPU and what the
  serve smoke's token-identity gate runs on.

The Pallas routes are int32-exact per the kernel contract but not bitwise
equal to an fp einsum, so ``resolve`` only picks them on a TPU backend;
``force_impl`` overrides for interpret-mode equivalence tests.

Tensor parallelism (``axes_scope``): column-parallel layers need nothing —
codes, scale and the matmul all split on the output dim, every channel's
full-K contraction stays on one shard, and the result is bitwise equal to
the single-device einsum. Row-parallel layers (``...k,kn->...n`` with K
sharded, or the transposed ``...e,ed->...d`` orientation) are where the
megatron eqn splits the *contraction*:

    y = sum_s  x_s @ dequant(codes_s)        (s = shard)

each shard dequantizes its K-slab and computes a partial product, and the
cross-shard partial-sum reduce happens in fp. That split is
order-independent — hence still exact — whenever the per-shard partial is
accumulated in integers (the int8/int4 MXU kernel routes: int32 partials,
fp only at the final scale), so on the kernel routes the eqn split is the
execution plan. The fp fallback cannot use it and stay bitwise: fp MACs
reassociate under the split (measured: ~5e-5 per matmul, which the next
layer's quantization grid amplifies into full code-step jumps). So in the
``dequant-fp`` route each shard still dequantizes only its own slab, but
the slabs (and the activation) are then constrained replicated — SPMD
all-gathers them and the full-K einsum runs unsplit, reproducing the
single-device op chain bit-for-bit. Packed HBM storage stays sharded
either way; only the fp route's wire traffic pays for its exactness.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import fake_quant, lsq_grad_scale_factor
from repro.runtime.packing import PackedLinear

Array = jax.Array

_AXES: List = [None]
_METRICS: List = [None]


# ---------------------------------------------------------------------------
# route table — one registry + one force mechanism for every routed op
# ---------------------------------------------------------------------------
class RouteTable:
    """Per-op route registry with one forcing mechanism.

    Each routed *op* (packed matmuls, int8 decode attention, the engine's
    KV layout) registers its legal route names here; ``force_route(op,
    name)`` pins one for a scope (the single seam behind the legacy
    ``force_impl`` / ``force_decode_attn`` context managers), ``validate``
    is what CLI flags (``serve --decode-attn`` / ``--kv-layout``) and
    engine config checks call, and ``resolve``/``resolve_decode_attn``
    consult the forced entry first. Forcing is a stack (scopes nest), and
    ``None`` restores auto-resolution.
    """

    def __init__(self, ops: Dict[str, tuple]):
        self.ops = {op: tuple(routes) for op, routes in ops.items()}
        self._forced: Dict[str, List[Optional[str]]] = {
            op: [None] for op in self.ops}

    def routes(self, op: str) -> tuple:
        if op not in self.ops:
            raise ValueError(f"unknown routed op {op!r}: {tuple(self.ops)}")
        return self.ops[op]

    def validate(self, op: str, name: str) -> str:
        routes = self.routes(op)
        if name not in routes:
            raise ValueError(f"unknown {op} route {name!r}: {routes}")
        return name

    def forced(self, op: str) -> Optional[str]:
        return self._forced[op][-1]

    @contextlib.contextmanager
    def force_route(self, op: str, name: Optional[str]):
        """Pin op ``op`` to route ``name`` for the scope (None = auto)."""
        if name is not None:
            self.validate(op, name)
        stack = self._forced[op]
        stack.append(name)
        try:
            yield
        finally:
            stack.pop()


ROUTES = RouteTable({
    "matmul": ("dequant-fp", "pallas-int8", "pallas-w4"),
    "decode_attn": ("fused", "fused-interpret", "dequant-fp"),
    "kv_layout": ("ring", "paged"),
    # how decode tokens are produced: plain target decode, or
    # self-speculative (the low-bit draft policy proposes, the searched
    # target policy verifies — launch/engine._spec_round)
    "spec": ("off", "self"),
    # which policy serves: one immutable policy per process, or a
    # pre-packed variant bank whose active member the admission-time ILP
    # re-solve hot-swaps between batches (launch/elastic.py)
    "elastic": ("off", "bank"),
})


def force_route(op: str, name: Optional[str]):
    """Module-level alias for ``ROUTES.force_route`` (the one force API)."""
    return ROUTES.force_route(op, name)


def force_impl(name: Optional[str]):
    """Pin every packed-matmul dispatch to ``name`` (tests; None restores
    auto). Legacy delegate for ``force_route("matmul", name)``."""
    return ROUTES.force_route("matmul", name)


@contextlib.contextmanager
def axes_scope(axes):
    """Bind the serving session's ``MeshAxes`` for the duration of one
    traced forward, so the dequant-fp route can pin its row-parallel
    gather (module docstring) without threading ``axes`` through every
    layer call site. No-op scope under ``NO_AXES``."""
    _AXES.append(axes if (axes is not None and axes.enabled) else None)
    try:
        yield
    finally:
        _AXES.pop()


@contextlib.contextmanager
def metrics_scope(registry):
    """Bind a ``repro.obs.metrics.MetricsRegistry`` for the duration of one
    traced forward, so dispatch can count which route each packed matmul
    (``dispatch.route.<impl>``) and the int8 decode attention
    (``dispatch.decode_attn.<route>``) resolved to. Counts are per *trace*
    (one compile), like ``act_reuse_scope`` hits — the jitted graph
    dispatches once, not per executed step. No-op scope under ``None``."""
    _METRICS.append(registry)
    try:
        yield
    finally:
        _METRICS.pop()


def _count_route(family: str, route: str) -> None:
    reg = _METRICS[-1]
    if reg is not None:
        reg.counter(f"dispatch.{family}.{route}").inc()


def dominant_route(registry, family: str = "route") -> str:
    """Most-counted ``dispatch.<family>.*`` impl in a registry ("fp" when
    nothing was counted). Route counts are per trace; the engine uses this
    to attribute its measured phase latencies to the impl that actually
    serves the compiled graph (``obs.health.attribute_latency``)."""
    prefix = f"dispatch.{family}."
    best, best_count = "fp", 0.0
    for name in getattr(registry, "_metrics", {}):
        if name.startswith(prefix):
            v = registry.value(name)
            if v > best_count:
                best, best_count = name[len(prefix):], v
    return best


def _w_contracted_dims(eqn: str):
    """Indices of the weight dims the einsum contracts away."""
    try:
        lhs, out = eqn.split("->")
        xs, ws = lhs.split(",")
    except ValueError:
        return frozenset()
    return frozenset(i for i, c in enumerate(ws) if c in xs and c not in out)


# ---------------------------------------------------------------------------
# decode-attention routing (int8 KV cache)
# ---------------------------------------------------------------------------
# Decode attention over a ``QuantKVCache`` resolves one of three routes —
# the matmul registry's sibling for the serving hot path:
#
# * ``fused``           — the Pallas kernel attends directly on the int8
#   codes + f32 scales (kernels.quant_attention): decode-attention HBM
#   traffic is code-sized. TPU backends only.
# * ``fused-interpret`` — the same kernel program through the Pallas
#   interpreter: CI's proof that the fused route is greedy-token-identical
#   to the dequant reference without TPU hardware.
# * ``dequant-fp``      — dequantize the whole cache and run the fp masked
#   softmax (models.attention). Exact reference; default off-TPU.
#
# Like matmul routes, resolution happens at trace time; the engine also
# resolves once at build for its roofline accounting, so a force scope
# must wrap engine construction AND its first run.
DECODE_ATTN_ROUTES = ROUTES.routes("decode_attn")


def force_decode_attn(name: Optional[str]):
    """Pin the int8 decode-attention route (tests/CLI; None restores auto).
    Legacy delegate for ``force_route("decode_attn", name)``."""
    return ROUTES.force_route("decode_attn", name)


def resolve_decode_attn(backend: Optional[str] = None) -> str:
    """Route for decode attention over an int8 KV cache (see above)."""
    route = ROUTES.forced("decode_attn")
    if route is None:
        backend = backend or jax.default_backend()
        route = "fused" if backend == "tpu" else "dequant-fp"
    _count_route("decode_attn", route)
    return route


# ---------------------------------------------------------------------------
# activation-code reuse (one quantize per site for wq/wk/wv-style fans)
# ---------------------------------------------------------------------------
_SCOPE: List[Optional[dict]] = [None]


@contextlib.contextmanager
def act_reuse_scope():
    """Memoize quantized activations for the duration of one traced
    forward pass.

    Projections that consume the *same* hidden state with bit-identical
    quantization parameters — wq/wk/wv on a site's normed residual, an MoE
    stack's wi/wg on the gathered tokens — otherwise each quantize that
    activation again. Inside this scope, ``act_fake_quant``/``act_codes``
    cache by ``(input identity, PackedLinear.a_group)``: the session
    assigns matching ``a_group`` tags at pack time only to layers whose
    (a_bits, a_signed, trained bank scale values) are equal, so a cache
    hit returns the exact array the miss would have computed and token
    identity with the per-layer-quantizing reference graph is preserved.

    Yields a dict whose ``"hits"`` counts elided quantize ops. The count
    is per *trace* (one compile), not per executed step — it measures ops
    removed from the jitted graph (surfaced as
    ``EngineStats.act_quant_reused``).
    """
    scope = {"cache": {}, "hits": 0}
    _SCOPE.append(scope)
    try:
        yield scope
    finally:
        _SCOPE.pop()


def _reuse_lookup(x: Array, pl: PackedLinear, tag: str):
    """(cache_key, hit_or_None). The cached entry keeps a reference to the
    input array so an id() recycled by the allocator can never alias."""
    scope = _SCOPE[-1]
    if scope is None or not pl.a_group:
        return None, None
    key = (id(x), pl.a_group, tag)
    entry = scope["cache"].get(key)
    if entry is not None and entry[0] is x:
        scope["hits"] += 1
        return key, entry[1]
    return key, None


def _reuse_store(key, x: Array, value):
    if key is not None:
        _SCOPE[-1]["cache"][key] = (x, value)


# ---------------------------------------------------------------------------
# activation quantization (bit-exact with quant_layers._maybe_quant_a)
# ---------------------------------------------------------------------------
def _act_scale(x: Array, pl: PackedLinear) -> Array:
    """The bank scale aligned to the activation — trailing-ones broadcast
    for per-expert banks, exactly ``fake_quant_indexed``'s reshape."""
    s = pl.s_a
    if s.ndim:
        s = s.reshape(s.shape + (1,) * (x.ndim - s.ndim))
    return s


def act_fake_quant(x: Array, pl: PackedLinear, ctx) -> Array:
    """LSQ fake-quant of activations at the layer's searched a_bits, using
    the trained bank scale — the identical op chain (scale floor, LSQ grad
    wrapper, clip bounds, per-expert broadcast) as the training graph, for
    bitwise parity."""
    if not (ctx.enabled and ctx.quantize_acts):
        return x
    key, hit = _reuse_lookup(x, pl, "fake")
    if hit is not None:
        return hit
    qmin, qmax = pl.a_range
    g = lsq_grad_scale_factor(x.size, qmax)
    out = fake_quant(x, _act_scale(x, pl), qmin, qmax, grad_scale_factor=g)
    _reuse_store(key, x, out)
    return out


def act_codes(x: Array, pl: PackedLinear, ctx):
    """Integer activation codes + scale for the int8 kernel routes
    (per-tensor scale only — kernel-eligible layers are never stacked)."""
    key, hit = _reuse_lookup(x, pl, "codes")
    if hit is not None:
        return hit
    qmin, qmax = pl.a_range
    s = jnp.maximum(pl.s_a.reshape(()), 1e-9)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), qmin, qmax)
    out = (q.astype(jnp.int8), s)
    _reuse_store(key, x, out)
    return out


# ---------------------------------------------------------------------------
# eqn analysis
# ---------------------------------------------------------------------------
def _kernel_form(eqn: str) -> bool:
    """True for ``...k,kn->...n`` einsums — weight is (K, N) with the
    contraction on the activation's last dim (the only orientation the
    Pallas kernels tile)."""
    try:
        lhs, out = eqn.split("->")
        xs, ws = lhs.split(",")
    except ValueError:
        return False
    return (len(ws) == 2 and xs[-1] == ws[0] and out[-1] == ws[1]
            and ws[1] not in xs)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------
def _replicate(mesh, a: Array) -> Array:
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        a, NamedSharding(mesh, P(*([None] * a.ndim))))


def _impl_dequant_fp(eqn: str, x: Array, pl: PackedLinear, ctx) -> Array:
    xq = act_fake_quant(x, pl, ctx).astype(ctx.compute_dtype)
    axes = _AXES[-1]
    if axes is not None and pl.shard_count > 1:
        # Gather the *packed* codes — the cheapest form on the wire, and
        # the only per-step tp traffic this route adds — then unpack,
        # dequant and contract replicated. Row-parallel weights REQUIRE
        # the gather so the fp full-K contraction does not split (module
        # docstring); the rest take it too because the sub-byte unpack is
        # reshape/slice-heavy and a replicated stream keeps the op chain
        # identical to the single-device graph op for op. HBM storage
        # between steps stays sharded regardless — this trades wire for
        # bitwise exactness, which is the fallback's contract; the MXU
        # kernel routes keep shard-local slabs and the int32-exact
        # partial-sum split.
        import dataclasses
        codes, scale = jax.lax.optimization_barrier(
            (_replicate(axes.mesh, pl.codes),
             _replicate(axes.mesh, pl.scale)))
        pl = dataclasses.replace(pl, codes=codes, scale=scale)
        if pl.shard_dim in _w_contracted_dims(eqn):
            xq = _replicate(axes.mesh, xq)
        # the barriers bracket the unpack chain so the SPMD partitioner
        # cannot re-fuse it across the gather boundary — left free, the
        # 0.4.37 CPU partitioner re-tiles the packed-stream reshapes and
        # produces wrong slabs (only when the chain stays internal to a
        # larger jit; any materialization hides it)
        w = jax.lax.optimization_barrier(pl.dequant(ctx.compute_dtype))
        return jnp.einsum(eqn, xq, w)
    return jnp.einsum(eqn, xq, pl.dequant(ctx.compute_dtype))


def _scalar_scale(pl: PackedLinear) -> Array:
    return pl.scale.reshape(-1)[0]


def _kernel_call(eqn, x, pl, ctx, matmul):
    xq, s_x = act_codes(x, pl, ctx)
    m2 = xq.reshape(-1, xq.shape[-1])
    out = matmul(m2, s_x)
    return out.reshape(x.shape[:-1] + (out.shape[-1],)).astype(
        ctx.compute_dtype)


def _impl_pallas_int8(eqn: str, x: Array, pl: PackedLinear, ctx) -> Array:
    from repro.kernels import ops
    w_codes = pl.unpack()
    return _kernel_call(
        eqn, x, pl, ctx,
        lambda m2, s_x: ops.quant_matmul(m2, w_codes, s_x,
                                         _scalar_scale(pl)))


def _impl_pallas_w4(eqn: str, x: Array, pl: PackedLinear, ctx) -> Array:
    from repro.kernels import ops
    return _kernel_call(
        eqn, x, pl, ctx,
        lambda m2, s_x: ops.quant_matmul_w4(m2, pl.codes, s_x,
                                            _scalar_scale(pl),
                                            k=pl.shape[-2]))


REGISTRY: Dict[str, Callable] = {
    "dequant-fp": _impl_dequant_fp,
    "pallas-int8": _impl_pallas_int8,
    "pallas-w4": _impl_pallas_w4,
}


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------
def kernel_eligible(eqn: str, pl: PackedLinear) -> Optional[str]:
    """The Pallas route this (eqn, layer) pair could take, else None."""
    if len(pl.shape) != 2 or not _kernel_form(eqn):
        return None
    if pl.per_channel:  # kernel epilogue takes a per-tensor scale (for now)
        return None
    if not pl.a_signed and pl.a_bits > 7:
        return None  # unsigned 8-bit grid (qmax 255) overflows int8 codes
    if (pl.layout == "nib4" and pl.shape[-2] % 2 == 0
            and not pl.sharded_layout()):
        # the w4 kernel consumes the PLAIN nib4 byte stream; a per-shard
        # re-broken layout (odd per-shard rows) must go through unpack
        return "pallas-w4"
    if pl.w_bits <= 8:
        return "pallas-int8"
    return None


def resolve(eqn: str, pl: PackedLinear, backend: Optional[str] = None) -> str:
    """Pick the execution route for one packed matmul (see module doc)."""
    forced = ROUTES.forced("matmul")
    if forced is not None:
        return forced
    backend = backend or jax.default_backend()
    if backend != "tpu":
        return "dequant-fp"
    return kernel_eligible(eqn, pl) or "dequant-fp"


def packed_qeinsum(eqn: str, x: Array, pl: PackedLinear, ctx,
                   impl: Optional[str] = None) -> Array:
    """Quantized einsum over a packed weight — the serving-time counterpart
    of ``quant_layers.qeinsum`` (which routes here when it sees a
    ``PackedLinear`` instead of a fake-quant param dict)."""
    impl = impl or resolve(eqn, pl)
    _count_route("route", impl)
    return REGISTRY[impl](eqn, x, pl, ctx)
