"""Per-layer kernel dispatch for packed mixed-precision matmuls.

A ``PackedLinear`` carries its searched bit-widths as static metadata, so
every call site resolves — at trace time — which execution route serves it:

* ``pallas-w4``   — int4 weights in the ``nib4`` layout feed
  ``kernels.quant_matmul.quant_matmul_w4`` directly: the packed bytes are
  the kernel operand and nibbles unpack in the VMEM prologue (HBM never
  sees unpacked codes).
* ``pallas-int8`` — any searched width ≤ 8 lands on a subset of the int8
  grid: codes unpack via XLA, activations quantize on the fly, and the
  matmul runs int8 x int8 -> int32 on the MXU
  (``kernels.quant_matmul.quant_matmul``).
* ``dequant-fp``  — exact fallback for everything the kernels can't tile
  (stacked MoE expert einsums, row-parallel ``(N,K)`` weight orientation,
  per-channel scales, odd contraction dims): dequantize the codes and run
  the same fp einsum as the fake-quant training graph. This route is
  *bit-exact* with that graph — it is the default off-TPU and what the
  serve smoke's token-identity gate runs on.

The Pallas routes are int32-exact per the kernel contract but not bitwise
equal to an fp einsum, so ``resolve`` only picks them on a TPU backend;
``force_impl`` overrides for interpret-mode equivalence tests.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import fake_quant, lsq_grad_scale_factor
from repro.runtime.packing import PackedLinear

Array = jax.Array

_FORCE: List[Optional[str]] = [None]


@contextlib.contextmanager
def force_impl(name: Optional[str]):
    """Pin every dispatch to ``name`` (tests; None restores auto)."""
    _FORCE.append(name)
    try:
        yield
    finally:
        _FORCE.pop()


# ---------------------------------------------------------------------------
# activation quantization (bit-exact with quant_layers._maybe_quant_a)
# ---------------------------------------------------------------------------
def _act_scale(x: Array, pl: PackedLinear) -> Array:
    """The bank scale aligned to the activation — trailing-ones broadcast
    for per-expert banks, exactly ``fake_quant_indexed``'s reshape."""
    s = pl.s_a
    if s.ndim:
        s = s.reshape(s.shape + (1,) * (x.ndim - s.ndim))
    return s


def act_fake_quant(x: Array, pl: PackedLinear, ctx) -> Array:
    """LSQ fake-quant of activations at the layer's searched a_bits, using
    the trained bank scale — the identical op chain (scale floor, LSQ grad
    wrapper, clip bounds, per-expert broadcast) as the training graph, for
    bitwise parity."""
    if not (ctx.enabled and ctx.quantize_acts):
        return x
    qmin, qmax = pl.a_range
    g = lsq_grad_scale_factor(x.size, qmax)
    return fake_quant(x, _act_scale(x, pl), qmin, qmax, grad_scale_factor=g)


def act_codes(x: Array, pl: PackedLinear, ctx):
    """Integer activation codes + scale for the int8 kernel routes
    (per-tensor scale only — kernel-eligible layers are never stacked)."""
    qmin, qmax = pl.a_range
    s = jnp.maximum(pl.s_a.reshape(()), 1e-9)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), qmin, qmax)
    return q.astype(jnp.int8), s


# ---------------------------------------------------------------------------
# eqn analysis
# ---------------------------------------------------------------------------
def _kernel_form(eqn: str) -> bool:
    """True for ``...k,kn->...n`` einsums — weight is (K, N) with the
    contraction on the activation's last dim (the only orientation the
    Pallas kernels tile)."""
    try:
        lhs, out = eqn.split("->")
        xs, ws = lhs.split(",")
    except ValueError:
        return False
    return (len(ws) == 2 and xs[-1] == ws[0] and out[-1] == ws[1]
            and ws[1] not in xs)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------
def _impl_dequant_fp(eqn: str, x: Array, pl: PackedLinear, ctx) -> Array:
    xq = act_fake_quant(x, pl, ctx).astype(ctx.compute_dtype)
    w = pl.dequant(ctx.compute_dtype)
    return jnp.einsum(eqn, xq, w)


def _scalar_scale(pl: PackedLinear) -> Array:
    return pl.scale.reshape(-1)[0]


def _kernel_call(eqn, x, pl, ctx, matmul):
    xq, s_x = act_codes(x, pl, ctx)
    m2 = xq.reshape(-1, xq.shape[-1])
    out = matmul(m2, s_x)
    return out.reshape(x.shape[:-1] + (out.shape[-1],)).astype(
        ctx.compute_dtype)


def _impl_pallas_int8(eqn: str, x: Array, pl: PackedLinear, ctx) -> Array:
    from repro.kernels import ops
    w_codes = pl.unpack()
    return _kernel_call(
        eqn, x, pl, ctx,
        lambda m2, s_x: ops.quant_matmul(m2, w_codes, s_x,
                                         _scalar_scale(pl)))


def _impl_pallas_w4(eqn: str, x: Array, pl: PackedLinear, ctx) -> Array:
    from repro.kernels import ops
    return _kernel_call(
        eqn, x, pl, ctx,
        lambda m2, s_x: ops.quant_matmul_w4(m2, pl.codes, s_x,
                                            _scalar_scale(pl),
                                            k=pl.shape[-2]))


REGISTRY: Dict[str, Callable] = {
    "dequant-fp": _impl_dequant_fp,
    "pallas-int8": _impl_pallas_int8,
    "pallas-w4": _impl_pallas_w4,
}


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------
def kernel_eligible(eqn: str, pl: PackedLinear) -> Optional[str]:
    """The Pallas route this (eqn, layer) pair could take, else None."""
    if len(pl.shape) != 2 or not _kernel_form(eqn):
        return None
    if pl.per_channel:  # kernel epilogue takes a per-tensor scale (for now)
        return None
    if not pl.a_signed and pl.a_bits > 7:
        return None  # unsigned 8-bit grid (qmax 255) overflows int8 codes
    if pl.layout == "nib4" and pl.shape[-2] % 2 == 0:
        return "pallas-w4"
    if pl.w_bits <= 8:
        return "pallas-int8"
    return None


def resolve(eqn: str, pl: PackedLinear, backend: Optional[str] = None) -> str:
    """Pick the execution route for one packed matmul (see module doc)."""
    if _FORCE[-1] is not None:
        return _FORCE[-1]
    backend = backend or jax.default_backend()
    if backend != "tpu":
        return "dequant-fp"
    return kernel_eligible(eqn, pl) or "dequant-fp"


def packed_qeinsum(eqn: str, x: Array, pl: PackedLinear, ctx,
                   impl: Optional[str] = None) -> Array:
    """Quantized einsum over a packed weight — the serving-time counterpart
    of ``quant_layers.qeinsum`` (which routes here when it sees a
    ``PackedLinear`` instead of a fake-quant param dict)."""
    impl = impl or resolve(eqn, pl)
    return REGISTRY[impl](eqn, x, pl, ctx)
