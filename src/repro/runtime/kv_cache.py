"""KV-cache layouts behind one protocol: fp / int8 ring buffers and the
pooled int8 paged layout, plus the host-side page allocator.

One cache protocol (:class:`KVCache`): every decode-time cache leaf —
:class:`FpKVCache` (fp ring), :class:`QuantKVCache` (int8 ring) and
:class:`PagedKVCache` (int8 pages + slot page table) — implements
``append / gather / evict / inventory``, and :class:`KVCacheLayout` is the
one factory (``alloc``) call sites build caches through.  The legacy names
(``attention.init_kv_cache`` / ``build_prefill_cache`` / ``ring_write`` /
``cache_per_slot`` / ``init_quant_kv_cache``) remain as thin delegates.

Int8 quantization: decode-time KV rows are quantized at *write* time with
a per-head symmetric scale ``s = max|x| / 127`` (shape ``(..., Sc, KV)``),
so dequantization is exact per row and independent of when later rows
arrive.  Numerics contract: ``dequantize(*quantize_rows(x)) ==
fake_quant_kv(x)`` exactly — the serving engine with int8 slots is
token-identical to a reference engine that stores ``fake_quant_kv`` values
in an fp cache (``QuantContext.kv_quant = "fake"``).

Paged layout = ring + block indirection: slot ``b``'s position space
``[0, P * page_size)`` divides into ``P`` fixed-size pages; token ``t``
lands in physical page ``page_table[b, t // page_size]`` at in-page row
``t % page_size``.  ``gather()`` therefore reproduces the dense per-slot
ring view bit-for-bit (same codes, same scales, same positions), which is
how the paged engine stays greedy-token-identical to the ring engine.
Pages are pooled across slots by the host-side :class:`PagePool`
(free-list + refcounts): requests sharing a page-aligned prompt prefix map
the *same* physical pages (copy-on-write refcounts), so prefill of a
cached prefix becomes a page-table update instead of compute.

Accounting: ``inventory()`` itemizes every resident buffer — codes,
scales, the int32 ``pos`` rows, and for the paged layout the page table
plus the pool's free-list/refcount arrays (``table`` / ``meta`` parts) —
so the roofline-vs-inventory reconciliation gate stays honest under
paging (the PR 5 pos-buffer lesson, extended).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, NamedTuple, Optional, Protocol, Sequence, \
    Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

KV_QMAX = 127.0          # symmetric int8 grid (−127..127; −128 unused)
KV_SCALE_EPS = 1e-8


# ---------------------------------------------------------------------------
# int8 row quantization (write-time scales)
# ---------------------------------------------------------------------------
def quantize_rows(x: Array) -> Tuple[Array, Array]:
    """Quantize ``(..., hd)`` rows onto the symmetric int8 grid with one
    scale per leading index (per token-row, per head)."""
    x32 = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1) / KV_QMAX, KV_SCALE_EPS)
    q = jnp.clip(jnp.round(x32 / s[..., None]), -KV_QMAX, KV_QMAX)
    return q.astype(jnp.int8), s


def dequantize(q: Array, s: Array, dtype=jnp.float32) -> Array:
    """Exact inverse map of :func:`quantize_rows` codes -> values."""
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def fake_quant_kv(x: Array) -> Array:
    """Value-level int8 KV quantization (quantize-dequantize in fp) — the
    reference graph's view of what an int8 slot stores."""
    q, s = quantize_rows(x)
    return dequantize(q, s, x.dtype)


def _nbytes(*arrs: Array) -> int:
    import numpy as np
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in arrs)


def _ring_append(cache, rows: Dict[str, Array], pos: Array):
    """The single write sequence shared by both ring quadrants (shared /
    per-slot positions).  The slot is ``mod(max(pos, 0), cap)``: a negative
    sentinel position (an inactive engine slot riding along in the decode
    batch) clamps to slot 0 and stamps ``pos = -1`` there — never valid to
    attend — instead of wrapping to ``cap - 1`` and clobbering the ring's
    tail codes/scales."""
    cap = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    slot = jnp.mod(jnp.maximum(pos, 0), cap)

    def row_update(c, n, s):
        return jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)

    if cache.pos.ndim == 2:                        # per-slot: pos (B, Sc)
        upd = {f: jax.vmap(row_update)(getattr(cache, f), r, slot)
               for f, r in rows.items()}
        upd["pos"] = jax.vmap(row_update)(cache.pos, pos[:, None], slot)
    else:                                          # shared: pos (Sc,)
        upd = {f: jax.lax.dynamic_update_slice_in_dim(getattr(cache, f), r,
                                                      slot, axis=1)
               for f, r in rows.items()}
        upd["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, pos[None], slot, axis=0)
    return cache._replace(**upd)


def _ring_append_batch(cache, rows: Dict[str, Array], pos: Array):
    """Batched multi-row variant of :func:`_ring_append` for the per-slot
    layout (speculative verify): ``rows`` values are ``(B, S, ...)`` token
    rows landing at absolute positions ``pos (B, S)``.  Same slot rule as
    the single-row path — ``mod(max(pos, 0), cap)`` — so a sentinel slot
    (all ``pos = -1``) funnels its S writes onto ring index 0 with
    ``pos = -1`` stamped there (all S rows carry the same sentinel, so the
    duplicate-index scatter is value-unambiguous for ``pos``; the codes
    there are never attendable)."""
    cap = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    slot = jnp.mod(jnp.maximum(pos, 0), cap)
    scatter = jax.vmap(lambda c, r, s: c.at[s].set(r))
    upd = {f: scatter(getattr(cache, f), r, slot) for f, r in rows.items()}
    upd["pos"] = scatter(cache.pos, pos, slot)
    return cache._replace(**upd)


def _ring_rollback(cache, cut: Array):
    """Invalidate per-slot ring rows at positions ``>= cut`` (``cut (B,)``)
    by value — the speculative-decode rejection rewind.  Works on the pos
    stamps alone, so it is independent of physical ring indices, leaves
    codes/scales resident (matching :func:`_evict_pos` semantics: a -1
    position is never valid to attend), and is a no-op for sentinel slots
    (``pos`` already -1 everywhere)."""
    cut = jnp.asarray(cut, jnp.int32)
    mask = (cache.pos >= 0) & (cache.pos >= cut[:, None])
    return cache._replace(pos=jnp.where(mask, -1, cache.pos))


def _evict_pos(cache, slot):
    """Invalidate one slot's rows by stamping its ``pos`` to -1 (codes and
    scales stay resident; a -1 position is never valid to attend)."""
    axis = cache.pos.ndim - 2  # slot axis: 0 plain, 1 body-stacked
    empty_shape = list(cache.pos.shape)
    empty_shape[axis] = 1
    empty = jnp.full(empty_shape, -1, jnp.int32)
    pos = jax.lax.dynamic_update_slice_in_dim(cache.pos, empty, slot,
                                              axis=axis)
    return cache._replace(pos=pos)


# ---------------------------------------------------------------------------
# cache leaves
# ---------------------------------------------------------------------------
class FpKVCache(NamedTuple):
    """Decode-time fp ring buffer (exported as ``attention.KVCache``).

    Two position layouts share this container:

    * shared  — ``pos (Sc,)``: every batch row sits at the same absolute
      position (the fixed-batch serving path).
    * per-slot — ``pos (B, Sc)``: each batch row is an independent serving
      *slot* with its own position/length (the continuous-batching engine).
      ``decode_attention`` dispatches on ``pos.ndim``.
    """
    k: Array      # (B, Sc, KV, hd) — ring buffer when Sc < full context
    v: Array
    pos: Array    # (Sc,) or (B, Sc) int32 absolute position, -1 = empty

    def append(self, k_new: Array, v_new: Array, pos) -> "FpKVCache":
        return _ring_append(self, {"k": k_new, "v": v_new}, pos)

    def append_batch(self, k_new: Array, v_new: Array,
                     pos: Array) -> "FpKVCache":
        """Speculative verify: S rows per slot, ``k_new (B, S, KV, hd)``
        at per-slot absolute positions ``pos (B, S)``."""
        return _ring_append_batch(self, {"k": k_new, "v": v_new}, pos)

    def gather(self) -> "FpKVCache":
        return self            # already the dense per-slot view

    def evict(self, slot) -> "FpKVCache":
        return _evict_pos(self, slot)

    def rollback(self, cut: Array) -> "FpKVCache":
        """Invalidate rows at positions >= ``cut (B,)`` (per-slot only)."""
        return _ring_rollback(self, cut)

    def inventory(self) -> Dict[str, int]:
        return {"codes": _nbytes(self.k, self.v),
                "pos": _nbytes(self.pos)}


class QuantKVCache(NamedTuple):
    """Int8 decode-time ring buffer (see module docstring).

    Position layouts match :class:`FpKVCache`: shared ``pos (Sc,)`` or
    per-slot ``pos (B, Sc)`` for the continuous-batching engine.
    """

    k: Array          # (B, Sc, KV, hd) int8 codes (body-stacked: (R, B, ...))
    v: Array          # (B, Sc, KV, hd) int8 codes
    k_scale: Array    # (B, Sc, KV) f32 per-row per-head write-time scale
    v_scale: Array    # (B, Sc, KV) f32
    pos: Array        # (Sc,) or (B, Sc) int32 absolute position, -1 = empty

    def append(self, k_new: Array, v_new: Array, pos) -> "QuantKVCache":
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        return _ring_append(self, {"k": kq, "v": vq,
                                   "k_scale": ks, "v_scale": vs}, pos)

    def append_batch(self, k_new: Array, v_new: Array,
                     pos: Array) -> "QuantKVCache":
        """Speculative verify: quantize-and-write S rows per slot at once
        (``k_new (B, S, KV, hd)``, ``pos (B, S)``).  ``quantize_rows``
        reduces over ``hd`` only, so the batched codes/scales are bitwise
        the single-row :meth:`append`'s."""
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        return _ring_append_batch(self, {"k": kq, "v": vq,
                                         "k_scale": ks, "v_scale": vs}, pos)

    def gather(self) -> "QuantKVCache":
        return self            # already the dense per-slot view

    def evict(self, slot) -> "QuantKVCache":
        return _evict_pos(self, slot)

    def rollback(self, cut: Array) -> "QuantKVCache":
        """Invalidate rows at positions >= ``cut (B,)`` (per-slot only)."""
        return _ring_rollback(self, cut)

    def inventory(self) -> Dict[str, int]:
        return {"codes": _nbytes(self.k, self.v),
                "scales": _nbytes(self.k_scale, self.v_scale),
                "pos": _nbytes(self.pos)}


class PagedKVCache(NamedTuple):
    """Pooled int8 KV pages + per-slot page table (the paged layout).

    A single physical page-id space backs every slot: page ``p`` holds
    ``page_size`` consecutive token rows of whichever slot mapped it.
    ``page_table[b, j] = p`` maps slot ``b``'s j-th logical block onto
    physical page ``p`` (-1 = unmapped).  Slot ``b``'s token at absolute
    position ``t`` lives at ``(page_table[b, t // page_size],
    t % page_size)`` — the linear layout the ring buffer uses for
    non-wrapping (full-attention, validated-capacity) serving, so
    :meth:`gather` reproduces the dense ring view bit-for-bit.

    Writes to a sentinel position (``pos < 0`` — an inactive engine slot)
    or through an unmapped table entry are *dropped* (out-of-bounds
    scatter), unlike the ring's clamp-to-slot-0; an evicted slot's output
    is discarded either way, so live-slot numerics are unaffected.

    The host-side :class:`PagePool` owns the free-list / refcounts; its
    page ids are shared across every layer's ``PagedKVCache`` (the tables
    are kept in lockstep), while each layer stores its own page contents.
    """

    k: Array           # (n_pages, page_size, KV, hd) int8 codes
    v: Array           # (n_pages, page_size, KV, hd) int8 codes
    k_scale: Array     # (n_pages, page_size, KV) f32 write-time scales
    v_scale: Array     # (n_pages, page_size, KV) f32
    pos: Array         # (n_pages, page_size) int32 absolute pos, -1 = empty
    page_table: Array  # (B, pages_per_slot) int32 physical page, -1 unmapped

    # Shapes are written for the plain (unstacked) layout; a body-stacked
    # site (scan over repeated layers) carries one extra leading layer axis
    # on every field — the decode/append paths always see the unstacked
    # per-layer leaf (lax.scan unstacks), while the engine-level ops below
    # (map_slot / evict / free_pages / insert_slot) handle both.
    @property
    def stacked(self) -> bool:
        return self.k.ndim == 5

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]

    @property
    def n_pages(self) -> int:
        return self.k.shape[-4]

    @property
    def pages_per_slot(self) -> int:
        return self.page_table.shape[-1]

    @property
    def capacity(self) -> int:
        return self.pages_per_slot * self.page_size

    def _target(self, pos: Array, table_rows: Array):
        """(page_id, in-page row) for absolute positions; OOB-drop sentinel
        ``n_pages`` for sentinel/unmapped/overflow positions."""
        ps, cap = self.page_size, self.capacity
        safe = jnp.clip(pos, 0, cap - 1)
        blk, row = safe // ps, safe % ps
        pid = jnp.take_along_axis(table_rows, blk, axis=-1) \
            if table_rows.ndim == pos.ndim else table_rows[blk]
        ok = (pos >= 0) & (pos < cap) & (pid >= 0)
        return jnp.where(ok, pid, self.n_pages), row

    def append(self, k_new: Array, v_new: Array, pos) -> "PagedKVCache":
        """One decode token per slot: ``k_new (B, 1, KV, hd)``, per-slot
        position vector ``pos (B,)``."""
        pos = jnp.asarray(pos, jnp.int32)
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        pid, row = self._target(pos[:, None], self.page_table)
        pid, row = pid[:, 0], row[:, 0]
        return self._replace(
            k=self.k.at[pid, row].set(kq[:, 0], mode="drop"),
            v=self.v.at[pid, row].set(vq[:, 0], mode="drop"),
            k_scale=self.k_scale.at[pid, row].set(ks[:, 0], mode="drop"),
            v_scale=self.v_scale.at[pid, row].set(vs[:, 0], mode="drop"),
            pos=self.pos.at[pid, row].set(pos, mode="drop"))

    def append_batch(self, k_new: Array, v_new: Array,
                     pos: Array) -> "PagedKVCache":
        """Speculative verify: S rows per slot at once — ``k_new (B, S,
        KV, hd)`` rows land at per-slot absolute positions ``pos (B, S)``
        (sentinel / out-of-capacity / unmapped rows drop, as in
        :meth:`append`)."""
        pos = jnp.asarray(pos, jnp.int32)
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        pid, row = self._target(pos, self.page_table)
        return self._replace(
            k=self.k.at[pid, row].set(kq, mode="drop"),
            v=self.v.at[pid, row].set(vq, mode="drop"),
            k_scale=self.k_scale.at[pid, row].set(ks, mode="drop"),
            v_scale=self.v_scale.at[pid, row].set(vs, mode="drop"),
            pos=self.pos.at[pid, row].set(pos, mode="drop"))

    def rollback(self, cut: Array) -> "PagedKVCache":
        """Invalidate each slot's rows at positions >= ``cut (B,)`` — the
        speculative-decode rejection rewind.  Clears the ``pos`` stamps of
        the slot-private tail pages holding rejected draft rows (codes and
        scales stay resident, matching :meth:`free_pages` semantics).
        Safe under copy-on-write sharing by construction: rollback cuts
        land strictly past the prompt, and only *full* prompt pages are
        ever registered/shared, so every touched row lives in a fresh
        refcount-1 page — the property tests gate this."""
        cut = jnp.asarray(cut, jnp.int32)
        tbl = self.page_table[0] if self.stacked else self.page_table
        t = jnp.arange(self.capacity, dtype=jnp.int32)
        positions = jnp.broadcast_to(t[None], tbl.shape[:1] + t.shape)
        pid, row = self._target(positions, tbl)
        pid = jnp.where(positions >= cut[:, None], pid, self.n_pages)
        if self.stacked:
            return self._replace(
                pos=self.pos.at[:, pid, row].set(-1, mode="drop"))
        return self._replace(
            pos=self.pos.at[pid, row].set(-1, mode="drop"))

    def append_rows(self, k_new: Array, v_new: Array, q_pos: Array,
                    slot) -> "PagedKVCache":
        """Chunked (multi-token) append for one slot: ``k_new (1, C, KV,
        hd)`` rows land at absolute positions ``q_pos (C,)`` (-1 pads are
        dropped).  This is the prefill-as-page-writes path that kills the
        prompt-bucketing recompile workaround."""
        q_pos = jnp.asarray(q_pos, jnp.int32)
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        tbl = jax.lax.dynamic_slice_in_dim(self.page_table, slot, 1,
                                           axis=0)[0]
        pid, row = self._target(q_pos, tbl)
        return self._replace(
            k=self.k.at[pid, row].set(kq[0], mode="drop"),
            v=self.v.at[pid, row].set(vq[0], mode="drop"),
            k_scale=self.k_scale.at[pid, row].set(ks[0], mode="drop"),
            v_scale=self.v_scale.at[pid, row].set(vs[0], mode="drop"),
            pos=self.pos.at[pid, row].set(q_pos, mode="drop"))

    def _gather_rows(self, tbl: Array) -> QuantKVCache:
        safe = jnp.clip(tbl, 0)
        mapped = tbl >= 0
        lead = tbl.shape[:-1]
        flat = lead + (tbl.shape[-1] * self.page_size,)

        def g(pages):
            return pages[safe].reshape(flat + pages.shape[2:])

        pos = jnp.where(mapped[..., None], self.pos[safe], -1).reshape(flat)
        return QuantKVCache(g(self.k), g(self.v), g(self.k_scale),
                            g(self.v_scale), pos)

    def gather(self) -> QuantKVCache:
        """Dense per-slot ring view ``(B, P * page_size, ...)`` — bit-for-
        bit the ring layout's arrays (unmapped blocks carry ``pos = -1``,
        never valid to attend)."""
        return self._gather_rows(self.page_table)

    def gather_slot(self, slot) -> QuantKVCache:
        """Dense ``(1, P * page_size, ...)`` view of one slot."""
        tbl = jax.lax.dynamic_slice_in_dim(self.page_table, slot, 1, axis=0)
        return self._gather_rows(tbl)

    def _set_table_row(self, slot, row: Array) -> "PagedKVCache":
        row = jnp.asarray(row, jnp.int32)
        if self.stacked:
            R = self.page_table.shape[0]
            upd = jnp.broadcast_to(row[None, None],
                                   (R, 1, self.pages_per_slot))
            table = jax.lax.dynamic_update_slice(self.page_table, upd,
                                                 (0, slot, 0))
        else:
            table = jax.lax.dynamic_update_slice_in_dim(
                self.page_table, row[None], slot, axis=0)
        return self._replace(page_table=table)

    def map_slot(self, slot, table_row: Array) -> "PagedKVCache":
        """Point slot ``slot``'s page list at ``table_row (P,)`` (-1 =
        unmapped) — the page-table update that replaces prefix prefill."""
        return self._set_table_row(slot, table_row)

    def evict(self, slot) -> "PagedKVCache":
        """Unmap one slot (table row -> -1).  Freeing the physical pages —
        and clearing their ``pos`` rows once the last sharer leaves — is
        the :class:`PagePool`'s (host) call, via :meth:`free_pages`."""
        return self._set_table_row(
            slot, jnp.full((self.pages_per_slot,), -1, jnp.int32))

    def free_pages(self, page_ids: Array) -> "PagedKVCache":
        """Clear ``pos`` of freed pages to -1 (sentinel-padded ids >=
        ``n_pages`` are dropped).  Load-bearing: a stale ``pos`` row in a
        recycled page would be wrongly attendable by its next occupant."""
        ids = jnp.asarray(page_ids, jnp.int32)
        safe = jnp.where(ids < 0, self.n_pages, ids)
        if self.stacked:
            return self._replace(
                pos=self.pos.at[:, safe].set(-1, mode="drop"))
        return self._replace(
            pos=self.pos.at[safe].set(-1, mode="drop"))

    def insert_slot(self, row: QuantKVCache, slot, table_row: Array,
                    scatter_ids: Array) -> "PagedKVCache":
        """Miss-path admission: write a densely-prefilled per-slot row
        (``row.k (1, Sc, KV, hd)``; body-stacked ``(R, 1, Sc, ...)``) into
        this slot's pages wholesale and point the table at them.
        ``table_row (P,)`` is the slot's page list (-1 = unmapped) and
        ``scatter_ids (P,)`` equals it with unmapped entries replaced by
        the out-of-bounds sentinel ``n_pages`` (those page writes drop).
        Rows past ``Sc`` pad with ``pos = -1`` (never attendable)."""
        ps, P = self.page_size, self.pages_per_slot
        sids = jnp.asarray(scatter_ids, jnp.int32)
        Sc = row.k.shape[-3]
        pad = P * ps - Sc
        assert pad >= 0, (Sc, P, ps)

        batch_axis = 1 if self.stacked else 0

        def pages_of(a, fill=0):
            # (1, Sc, trailing...) -> (P, ps, trailing...); stacked rows
            # ((R, 1, Sc, ...)) keep their leading layer axis
            a = jnp.squeeze(a, axis=batch_axis)
            pad_w = [(0, 0)] * a.ndim
            pad_w[batch_axis] = (0, pad)
            a = jnp.pad(a, pad_w, constant_values=fill)
            lead = a.shape[:1] if self.stacked else ()
            return a.reshape(lead + (P, ps)
                             + a.shape[batch_axis + 1:])

        k_p = pages_of(row.k)
        v_p = pages_of(row.v)
        ks_p = pages_of(row.k_scale)
        vs_p = pages_of(row.v_scale)
        pos_p = pages_of(row.pos, fill=-1)
        if self.stacked:
            new = self._replace(
                k=self.k.at[:, sids].set(k_p, mode="drop"),
                v=self.v.at[:, sids].set(v_p, mode="drop"),
                k_scale=self.k_scale.at[:, sids].set(ks_p, mode="drop"),
                v_scale=self.v_scale.at[:, sids].set(vs_p, mode="drop"),
                pos=self.pos.at[:, sids].set(pos_p, mode="drop"))
        else:
            new = self._replace(
                k=self.k.at[sids].set(k_p, mode="drop"),
                v=self.v.at[sids].set(v_p, mode="drop"),
                k_scale=self.k_scale.at[sids].set(ks_p, mode="drop"),
                v_scale=self.v_scale.at[sids].set(vs_p, mode="drop"),
                pos=self.pos.at[sids].set(pos_p, mode="drop"))
        return new._set_table_row(slot, table_row)

    def copy_page(self, src, dst) -> "PagedKVCache":
        """Device-side page copy for a copy-on-write fork: duplicate page
        ``src``'s contents into ``dst`` (the shared original is never
        mutated)."""
        axis = 1 if self.stacked else 0

        def cp(a):
            row = jax.lax.dynamic_slice_in_dim(a, src, 1, axis=axis)
            return jax.lax.dynamic_update_slice_in_dim(a, row, dst,
                                                       axis=axis)
        return self._replace(k=cp(self.k), v=cp(self.v),
                             k_scale=cp(self.k_scale),
                             v_scale=cp(self.v_scale), pos=cp(self.pos))

    def inventory(self) -> Dict[str, int]:
        """Codes / scales / pos of every pooled page, the slot page table,
        and the pool's free-list + refcount arrays (``meta``; one int32
        each per page — see :meth:`PagePool.meta_bytes`).  The pool is
        shared across layers, so :func:`tree_inventory` counts ``meta``
        once per state tree."""
        return {"codes": _nbytes(self.k, self.v),
                "scales": _nbytes(self.k_scale, self.v_scale),
                "pos": _nbytes(self.pos),
                "table": _nbytes(self.page_table),
                "meta": 2 * self.n_pages * 4}


# Every decode-time cache container; engine/state plumbing that only needs
# `.pos`/`.page_table` and the slot axis treats them uniformly through it.
CACHE_TYPES = (FpKVCache, QuantKVCache, PagedKVCache)
QUANT_CACHE_TYPES = (QuantKVCache, PagedKVCache)


class KVCache(Protocol):
    """The one cache protocol every layout implements (see module doc).

    ``append`` writes decode rows (quantizing at write time for int8
    layouts), ``gather`` returns the dense per-slot view attention
    consumes, ``evict`` invalidates one slot, ``inventory`` itemizes
    resident HBM bytes.  Allocation goes through
    :meth:`KVCacheLayout.alloc`.
    """

    def append(self, k_new: Array, v_new: Array, pos): ...
    def append_batch(self, k_new: Array, v_new: Array, pos: Array): ...
    def gather(self): ...
    def evict(self, slot): ...
    def rollback(self, cut: Array): ...
    def inventory(self) -> Dict[str, int]: ...


# ---------------------------------------------------------------------------
# layout factory
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVCacheLayout:
    """How a decode state's KV is laid out — the single ``alloc`` factory
    behind ``attention.init_kv_cache`` / ``lm.init_site_state`` / the
    engine's ``EngineConfig.kv_layout``.

    ``kind="ring"`` pre-carves a fixed-capacity buffer per slot (fp or
    int8 per ``quant``); ``kind="paged"`` pools ``n_pages`` fixed-size
    int8 pages across slots behind a page table (requires
    ``quant="int8"``).
    """

    kind: str = "ring"       # "ring" | "paged"
    quant: str = "none"      # "none" | "fake" | "int8"
    page_size: int = 8       # tokens per page (paged)
    n_pages: int = 0         # pool size; 0 = (batch + 1) * pages_per_slot

    def __post_init__(self):
        if self.kind not in ("ring", "paged"):
            raise ValueError(f"unknown kv layout {self.kind!r}")
        if self.kind == "paged" and self.quant != "int8":
            raise ValueError(
                f"paged KV requires quant='int8', got {self.quant!r}")

    def pages_per_slot(self, capacity: int) -> int:
        return -(-capacity // self.page_size)

    def pool_pages(self, batch: int, capacity: int) -> int:
        return self.n_pages or (batch + 1) * self.pages_per_slot(capacity)

    def alloc(self, batch: int, capacity: int, kv_heads: int, head_dim: int,
              *, dtype=jnp.bfloat16, per_slot: bool = False):
        if self.kind == "paged":
            if not per_slot:
                raise ValueError("paged KV is a per-slot (engine) layout")
            return init_paged_kv_cache(
                self.pool_pages(batch, capacity), self.page_size, kv_heads,
                head_dim, batch, self.pages_per_slot(capacity))
        if self.quant == "int8":
            return init_quant_kv_cache(batch, capacity, kv_heads, head_dim,
                                       per_slot=per_slot)
        pos_shape = (batch, capacity) if per_slot else (capacity,)
        return FpKVCache(
            k=jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
            v=jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
            pos=jnp.full(pos_shape, -1, jnp.int32),
        )


def init_quant_kv_cache(batch: int, capacity: int, kv_heads: int, hd: int,
                        per_slot: bool = False) -> QuantKVCache:
    pos_shape = (batch, capacity) if per_slot else (capacity,)
    return QuantKVCache(
        k=jnp.zeros((batch, capacity, kv_heads, hd), jnp.int8),
        v=jnp.zeros((batch, capacity, kv_heads, hd), jnp.int8),
        k_scale=jnp.zeros((batch, capacity, kv_heads), jnp.float32),
        v_scale=jnp.zeros((batch, capacity, kv_heads), jnp.float32),
        pos=jnp.full(pos_shape, -1, jnp.int32),
    )


def init_paged_kv_cache(n_pages: int, page_size: int, kv_heads: int,
                        hd: int, slots: int,
                        pages_per_slot: int) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((n_pages, page_size, kv_heads, hd), jnp.int8),
        v=jnp.zeros((n_pages, page_size, kv_heads, hd), jnp.int8),
        k_scale=jnp.zeros((n_pages, page_size, kv_heads), jnp.float32),
        v_scale=jnp.zeros((n_pages, page_size, kv_heads), jnp.float32),
        pos=jnp.full((n_pages, page_size), -1, jnp.int32),
        page_table=jnp.full((slots, pages_per_slot), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# host-side page allocator (free-list + refcounts + prefix registry)
# ---------------------------------------------------------------------------
class PagePool:
    """Host bookkeeping for one physical page-id space.

    Pages are reference-counted: a slot mapping a page holds one
    reference, and every registered prefix-chain entry pins its pages with
    one more, so a popular prompt prefix survives its requests.  A page's
    contents become recyclable exactly when its refcount hits zero
    (``release`` returns the freed ids so the engine can clear their
    device-side ``pos`` rows).  ``fork`` is the copy-on-write seam: a
    writer holding a shared page (rc > 1) gets a fresh page and drops its
    reference — the shared original is never mutated.
    """

    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self.refcount = [0] * self.n_pages
        # prefix chain key -> tuple of page ids (each entry pins its pages)
        self._registry: "OrderedDict[bytes, Tuple[int, ...]]" = OrderedDict()

    # -- allocation ---------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` fresh pages (rc 1 each); evicts LRU registered
        prefixes to make room; raises when the pool is truly exhausted.
        Returns ``(ids, freed)`` via :meth:`alloc_with_freed` semantics —
        use that variant when the caller must clear recycled pages."""
        ids, _ = self.alloc_with_freed(n)
        return ids

    def alloc_with_freed(self, n: int) -> Tuple[List[int], List[int]]:
        freed: List[int] = []
        while len(self._free) < n and self._registry:
            freed.extend(self.drop_lru_prefix())
        if len(self._free) < n:
            raise RuntimeError(
                f"page pool exhausted: need {n}, "
                f"free {len(self._free)}/{self.n_pages}")
        ids = [self._free.pop() for _ in range(n)]
        for p in ids:
            self.refcount[p] = 1
        return ids, freed

    def ref(self, ids: Sequence[int]) -> None:
        for p in ids:
            assert self.refcount[p] > 0, f"ref of free page {p}"
            self.refcount[p] += 1

    def release(self, ids: Sequence[int]) -> List[int]:
        """Drop one reference per id; returns the ids whose refcount hit
        zero (now recycled onto the free list)."""
        freed: List[int] = []
        for p in ids:
            if p < 0:
                continue
            assert self.refcount[p] > 0, f"double free of page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def fork(self, pid: int) -> Tuple[int, bool, List[int]]:
        """Copy-on-write: exclusive pages (rc 1) return unchanged; shared
        pages allocate a fresh id and drop the caller's reference.
        Returns ``(page_id, needs_copy, freed)``."""
        if self.refcount[pid] <= 1:
            return pid, False, []
        new, freed = self.alloc_with_freed(1)
        self.refcount[pid] -= 1
        return new[0], True, freed

    # -- shared-prefix registry ---------------------------------------------
    def register_prefix(self, chain_keys: Sequence[bytes],
                        page_ids: Sequence[int]) -> None:
        """Pin this prompt's full-page prefix chains: ``chain_keys[j]``
        hashes the first ``(j + 1) * page_size`` tokens and maps to
        ``page_ids[: j + 1]``.  Every registered entry pins its pages with
        one reference, so shorter shared prefixes match too."""
        for j, key in enumerate(chain_keys):
            if key in self._registry:
                self._registry.move_to_end(key)
                continue
            pages = tuple(page_ids[: j + 1])
            self._registry[key] = pages
            self.ref(pages)

    def lookup_prefix(self, chain_keys: Sequence[bytes]) -> Tuple[int, ...]:
        """Longest registered chain matching this prompt's page-aligned
        prefix; ``()`` on a miss.  A hit marks the entry most-recently
        used."""
        for j in range(len(chain_keys) - 1, -1, -1):
            pages = self._registry.get(chain_keys[j])
            if pages is not None:
                self._registry.move_to_end(chain_keys[j])
                return pages
        return ()

    def drop_lru_prefix(self) -> List[int]:
        """Unpin the least-recently-used registry entry; returns any page
        ids that became free."""
        if not self._registry:
            return []
        _, pages = self._registry.popitem(last=False)
        return self.release(pages)

    def flush_prefixes(self) -> List[int]:
        """Unpin EVERY registered prefix chain; returns all page ids that
        became free. The elastic engine calls this at a policy hot-swap:
        registered pages hold KV computed under the *previous* variant's
        weights, so a post-swap ``lookup_prefix`` hit would splice stale
        numerics into a request that must match its variant's single-policy
        reference bit-for-bit."""
        freed: List[int] = []
        while self._registry:
            freed.extend(self.drop_lru_prefix())
        return freed

    # -- accounting / invariants --------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def unique_pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def registered_prefixes(self) -> int:
        return len(self._registry)

    @property
    def reclaimable_count(self) -> int:
        """Pages pinned ONLY by the prefix registry — the ones
        ``alloc_with_freed`` could recover by dropping LRU prefixes.  A
        page is reclaimable when its refcount equals its registry pins
        (no slot maps it)."""
        pins: Dict[int, int] = {}
        for pages in self._registry.values():
            for p in pages:
                pins[p] = pins.get(p, 0) + 1
        return sum(1 for p, k in pins.items() if self.refcount[p] == k)

    @property
    def available_count(self) -> int:
        """Worst-case pages an admission could obtain: free pages plus
        registry-only pages.  This — not ``free_count`` — is what the
        scheduler's pressure check must compare against, otherwise a
        pool full of evictable prefixes would defer admissions forever."""
        return self.free_count + self.reclaimable_count

    def meta_bytes(self) -> int:
        """Resident bytes of the allocator's own state: the free list and
        the refcount array (one int32 each per page) — counted by
        ``inventory()`` so the reconciliation gate sees them."""
        return 2 * self.n_pages * 4

    def check(self) -> None:
        """Leak/consistency invariants (the property tests' oracle):
        free + referenced partitions the pool; free pages have rc 0."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        for p in range(self.n_pages):
            if p in free:
                assert self.refcount[p] == 0, f"free page {p} has refs"
            else:
                assert self.refcount[p] > 0, f"leaked page {p} (rc 0, not free)"


# ---------------------------------------------------------------------------
# tree-level accounting
# ---------------------------------------------------------------------------
def inventory(cache) -> dict:
    """Resident HBM bytes of one cache leaf, itemized by part: ``codes``
    (k+v), ``scales`` (f32 write-time scales), ``pos`` (the int32 position
    buffer), and for the paged layout ``table`` (the slot page table) +
    ``meta`` (the pool's free-list/refcount arrays).  Every part is part
    of the resident cache — omitting any undercounts measured HBM vs what
    the roofline's ``decode_step_cost(kv_bits<=8)`` models; both use this
    same inventory, and the engine exports it as ``engine.kv_*_bytes``
    gauges."""
    return cache.inventory()


def cache_bytes(cache) -> int:
    """Measured HBM bytes of one cache (sum of its :func:`inventory`)."""
    return sum(inventory(cache).values())


def tree_inventory(state) -> dict:
    """Itemized :func:`inventory` summed over every quantized cache leaf
    of an engine state tree (zeros when the state holds fp caches).  The
    paged pool's ``meta`` is shared across layers, so it counts once."""
    total = {"codes": 0, "scales": 0, "pos": 0}
    meta_counted = False
    for leaf in jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, QUANT_CACHE_TYPES)):
        if isinstance(leaf, QUANT_CACHE_TYPES):
            for part, n in inventory(leaf).items():
                if part == "meta":
                    if meta_counted:
                        continue
                    meta_counted = True
                total[part] = total.get(part, 0) + n
    return total


def tree_cache_bytes(state) -> int:
    """Total quantized-cache HBM bytes of an engine state tree."""
    return sum(tree_inventory(state).values())


def find_paged(state) -> Optional[PagedKVCache]:
    """First ``PagedKVCache`` leaf of a state tree (None when ring)."""
    for leaf in jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, CACHE_TYPES)):
        if isinstance(leaf, PagedKVCache):
            return leaf
    return None
