"""Int8 KV-cache quantization with per-head write-time scales.

Decode-time KV rows are quantized at *write* time: each cached row keeps a
per-head symmetric scale ``s = max|x| / 127`` (shape ``(..., Sc, KV)``), so
dequantization is exact per row and independent of when later rows arrive —
a "running" scale that never has to re-quantize history. HBM per cache row
drops from ``2 * KV * hd`` bf16 bytes to ``KV * hd + 4 * KV`` (int8 codes +
f32 scales), and the scheduler's roofline sees the difference through
``dist.roofline.decode_step_cost(kv_bits=8)``.

Numerics contract: ``dequantize(*quantize(x)) == fake_quant_kv(x)`` exactly
— the serving engine with int8 slots is therefore token-identical to a
reference engine that stores ``fake_quant_kv`` values in an fp cache
(``QuantContext.kv_quant = "fake"``), which is how the serve smoke asserts
the packed runtime against the fake-quant graph.

``QuantKVCache`` mirrors ``models.attention.KVCache`` (same ``k``/``v``/
``pos`` field names and both position layouts), so the engine's insert /
evict / per-slot plumbing treats both through ``attention.CACHE_TYPES``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

KV_QMAX = 127.0          # symmetric int8 grid (−127..127; −128 unused)
KV_SCALE_EPS = 1e-8


class QuantKVCache(NamedTuple):
    """Int8 decode-time ring buffer (see module docstring).

    Position layouts match ``attention.KVCache``: shared ``pos (Sc,)`` or
    per-slot ``pos (B, Sc)`` for the continuous-batching engine.
    """

    k: Array          # (B, Sc, KV, hd) int8 codes (body-stacked: (R, B, ...))
    v: Array          # (B, Sc, KV, hd) int8 codes
    k_scale: Array    # (B, Sc, KV) f32 per-row per-head write-time scale
    v_scale: Array    # (B, Sc, KV) f32
    pos: Array        # (Sc,) or (B, Sc) int32 absolute position, -1 = empty


def quantize_rows(x: Array) -> Tuple[Array, Array]:
    """Quantize ``(..., hd)`` rows onto the symmetric int8 grid with one
    scale per leading index (per token-row, per head)."""
    x32 = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1) / KV_QMAX, KV_SCALE_EPS)
    q = jnp.clip(jnp.round(x32 / s[..., None]), -KV_QMAX, KV_QMAX)
    return q.astype(jnp.int8), s


def dequantize(q: Array, s: Array, dtype=jnp.float32) -> Array:
    """Exact inverse map of :func:`quantize_rows` codes -> values."""
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def fake_quant_kv(x: Array) -> Array:
    """Value-level int8 KV quantization (quantize-dequantize in fp) — the
    reference graph's view of what an int8 slot stores."""
    q, s = quantize_rows(x)
    return dequantize(q, s, x.dtype)


def init_quant_kv_cache(batch: int, capacity: int, kv_heads: int, hd: int,
                        per_slot: bool = False) -> QuantKVCache:
    pos_shape = (batch, capacity) if per_slot else (capacity,)
    return QuantKVCache(
        k=jnp.zeros((batch, capacity, kv_heads, hd), jnp.int8),
        v=jnp.zeros((batch, capacity, kv_heads, hd), jnp.int8),
        k_scale=jnp.zeros((batch, capacity, kv_heads), jnp.float32),
        v_scale=jnp.zeros((batch, capacity, kv_heads), jnp.float32),
        pos=jnp.full(pos_shape, -1, jnp.int32),
    )


def inventory(cache: QuantKVCache) -> dict:
    """Resident HBM bytes of one quantized cache, itemized by part:
    ``codes`` (int8 k+v), ``scales`` (f32 write-time scales) and ``pos``
    (the int32 position buffer). The ``pos`` rows are part of the resident
    cache (and of every decode step's attention read — the mask is
    position-driven), so omitting them undercounted measured HBM vs what
    the roofline's ``decode_step_cost(kv_bits<=8)`` models; both use this
    same inventory, and the engine exports it as ``engine.kv_*_bytes``
    gauges."""
    import numpy as np

    def nbytes(*arrs: Array) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in arrs)

    return {"codes": nbytes(cache.k, cache.v),
            "scales": nbytes(cache.k_scale, cache.v_scale),
            "pos": nbytes(cache.pos)}


def cache_bytes(cache: QuantKVCache) -> int:
    """Measured HBM bytes of one quantized cache (sum of its
    :func:`inventory`)."""
    return sum(inventory(cache).values())


def tree_inventory(state) -> dict:
    """Itemized :func:`inventory` summed over every ``QuantKVCache`` leaf
    of an engine state tree (zeros when the state holds fp caches)."""
    total = {"codes": 0, "scales": 0, "pos": 0}
    for leaf in jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, QuantKVCache)):
        if isinstance(leaf, QuantKVCache):
            for part, n in inventory(leaf).items():
                total[part] += n
    return total


def tree_cache_bytes(state) -> int:
    """Total quantized-cache HBM bytes of an engine state tree."""
    return sum(tree_inventory(state).values())
