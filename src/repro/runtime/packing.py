"""Weight packing: searched-grid quantization + sub-8-bit bit-packing.

The storage half of executing an ILP-searched ``MPQPolicy``: every searched
projection is quantized onto its per-layer b-bit signed grid with the exact
rounding of the fake-quant training graph (``round(clip(w/s, qmin, qmax))``
with ``s = max(s, 1e-9)``), and the integer codes are bit-packed so HBM
holds ``ceil(n * b / 8)`` bytes — matching ``MPQPolicy.size_bytes`` to
within padding. Three storage layouts:

* ``int8``      — b == 8: codes stored as int8 in the weight's own shape.
* ``nib4``      — b == 4: two codes per byte along the contraction dim
                  (``codes[k//2, n]``; low nibble = even k). This is the
                  layout the ``kernels.quant_matmul.quant_matmul_w4``
                  unpack-in-VMEM prologue consumes directly.
* ``quad2``     — b == 2: four codes per byte along the contraction dim.
* ``bitstream`` — any other b (3, 5, 6): little-endian bitstream over the
                  row-major flattened codes, 1-D uint8.

Codes are stored offset-binary (``u = q - qmin``) so packed bytes are
unsigned; ``unpack_*`` restores the signed grid exactly (round-trip is
property-tested in tests/test_runtime.py for odd channel counts).

Tensor-parallel serving packs *per shard*: ``pack_linear(...,
shard_dim=d, shard_count=n)`` splits the weight into ``n`` equal shards
along its original tensor-parallel dim and packs each shard independently
(each padded to its own byte/word boundary), then concatenates the shard
layouts back along the packed counterpart of ``d``. The result is
bit-identical, shard for shard, to packing each shard on its own — so
sharding ``codes`` over a mesh axis hands every device exactly the packed
slab it would have produced locally, and per-device HBM is
``packed_bytes / shard_count`` (``per_shard_bytes``). Only two layouts
actually change bytes under this: ``nib4``/``quad2`` when the shard dim IS
the packed contraction dim (row-parallel) and the per-shard row count is
not a multiple of the codes-per-byte, and ``bitstream`` always (the flat
stream must break at shard boundaries). Everything else degenerates to the
plain packing.

Scales are per-channel ``(out,)`` over the weight's last dim. The serving
session fills them with the trained per-tensor indicator-bank scale
broadcast per channel (bit-exact with the fake-quant graph); statistics
per-channel scales (``per_channel=True``) trade that exactness for lower
quantization error when no trained scale is available.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import bit_range

Array = jax.Array

SCALE_EPS = 1e-9  # fake_quant's scale floor — must match for bit-exactness


# ---------------------------------------------------------------------------
# generic bitstream codec (any bits <= 8)
# ---------------------------------------------------------------------------
def pack_codes(q, bits: int, *, signed: bool = True) -> Array:
    """Bit-pack integer codes ``q`` (values on the `bits`-wide grid) into a
    little-endian uint8 bitstream of ``ceil(q.size * bits / 8)`` bytes."""
    if not 1 <= bits <= 8:
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    qmin, qmax = bit_range(bits, signed)
    u = jnp.asarray(q, jnp.int32).reshape(-1) - int(qmin)
    bitmat = (u[:, None] >> jnp.arange(bits, dtype=jnp.int32)) & 1
    flat = bitmat.reshape(-1)
    pad = (-flat.size) % 8
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.int32)])
    weights = (1 << jnp.arange(8, dtype=jnp.int32))
    return (flat.reshape(-1, 8) * weights).sum(-1).astype(jnp.uint8)


def unpack_codes(codes, bits: int, n: int, *, signed: bool = True) -> Array:
    """Exact inverse of :func:`pack_codes` -> ``(n,)`` int8 codes."""
    qmin, _ = bit_range(bits, signed)
    b = (jnp.asarray(codes, jnp.int32)[:, None] >> jnp.arange(8)) & 1
    b = b.reshape(-1)[: n * bits].reshape(n, bits)
    u = (b << jnp.arange(bits, dtype=jnp.int32)).sum(-1)
    return (u + int(qmin)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# kernel-friendly nibble / crumb layouts (packed along the contraction dim)
# ---------------------------------------------------------------------------
def _pad_rows(q: Array, mult: int) -> Array:
    k = q.shape[-2]
    pad = (-k) % mult
    if pad:
        width = [(0, 0)] * q.ndim
        width[-2] = (0, pad)
        q = jnp.pad(q, width)  # code 0 rows; offset applied after padding
    return q


def pack_nib4(q: Array) -> Array:
    """Signed int4 codes ``(..., K, N)`` -> ``(..., ceil(K/2), N)`` uint8,
    two per byte along K (low nibble = even k), offset-binary (q + 8)."""
    u = _pad_rows(jnp.asarray(q, jnp.int32) + 8, 2)
    lo = u[..., 0::2, :]
    hi = u[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nib4(codes: Array, k: int) -> Array:
    """Inverse of :func:`pack_nib4` -> ``(..., k, N)`` int8 codes."""
    c = jnp.asarray(codes, jnp.int32)
    lo = (c & 0xF) - 8
    hi = (c >> 4) - 8
    full = jnp.stack([lo, hi], axis=-2)              # (..., K2, 2, N)
    shape = full.shape[:-3] + (2 * c.shape[-2], c.shape[-1])
    return full.reshape(shape)[..., :k, :].astype(jnp.int8)


def pack_quad2(q: Array) -> Array:
    """Signed int2 codes ``(..., K, N)`` -> ``(..., ceil(K/4), N)`` uint8,
    four per byte along K, offset-binary (q + 2)."""
    u = _pad_rows(jnp.asarray(q, jnp.int32) + 2, 4)
    parts = [u[..., i::4, :] << (2 * i) for i in range(4)]
    return (parts[0] | parts[1] | parts[2] | parts[3]).astype(jnp.uint8)


def unpack_quad2(codes: Array, k: int) -> Array:
    """Inverse of :func:`pack_quad2` -> ``(..., k, N)`` int8 codes."""
    c = jnp.asarray(codes, jnp.int32)
    parts = [((c >> (2 * i)) & 0x3) - 2 for i in range(4)]
    full = jnp.stack(parts, axis=-2)                 # (..., K4, 4, N)
    shape = full.shape[:-3] + (4 * c.shape[-2], c.shape[-1])
    return full.reshape(shape)[..., :k, :].astype(jnp.int8)


def _layout_for(bits: int) -> str:
    return {8: "int8", 4: "nib4", 2: "quad2"}.get(bits, "bitstream")


_PACK_MULT = {"nib4": 2, "quad2": 4}
_PACK_FN = {"nib4": pack_nib4, "quad2": pack_quad2}


def _split_shards(q: Array, dim: int, count: int):
    if q.shape[dim] % count:
        raise ValueError(
            f"shard dim {dim} of size {q.shape[dim]} does not split into "
            f"{count} equal shards")
    return jnp.split(q, count, axis=dim)


def _pack_sharded(q: Array, layout: str, bits: int, dim: int,
                  count: int) -> Array:
    """Pack each of ``count`` shards of ``q`` along ``dim`` independently.

    Per-shard layouts are byte-aligned on their own (``nib4``/``quad2``
    pad each shard's rows to the codes-per-byte multiple; ``bitstream``
    gives each shard its own byte-aligned stream), then concatenated along
    the packed counterpart of ``dim`` — dim itself for the row layouts,
    axis 0 of the flat stream for ``bitstream``."""
    shards = _split_shards(q, dim, count)
    if layout == "bitstream":
        return jnp.concatenate([pack_codes(s, bits) for s in shards])
    return jnp.concatenate([_PACK_FN[layout](s) for s in shards], axis=dim)


# ---------------------------------------------------------------------------
# PackedLinear — the packed param-tree leaf
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedLinear:
    """One searched projection in deployable form.

    ``codes``/``scale``/``s_a`` are pytree children (device arrays); the
    grid metadata is static aux data, so a jitted function closing over a
    packed param tree sees the bit-widths as compile-time constants —
    exactly what the unpack/dispatch code needs.
    """

    codes: Array                      # packed weight codes (layout-dependent)
    scale: Array                      # f32 dequant scale: (out,) per-channel
    #                                   or (E,1,1) per-expert broadcast form
    s_a: Array                        # f32 activation scale (trained bank):
    #                                   () scalar or (E,) per-expert
    w_bits: int = dataclasses.field(metadata=dict(static=True), default=8)
    a_bits: int = dataclasses.field(metadata=dict(static=True), default=8)
    a_signed: bool = dataclasses.field(metadata=dict(static=True), default=True)
    layout: str = dataclasses.field(metadata=dict(static=True), default="int8")
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    per_channel: bool = dataclasses.field(metadata=dict(static=True),
                                          default=False)
    # tensor-parallel packing: the weight dim the codes were packed
    # per-shard along (None = plain packing) and the shard count. Static so
    # ``unpack`` can reassemble the per-shard layouts at trace time and
    # ``dist.sharding.packed_specs`` can tell a shardable layout from one
    # whose bytes would split mid-shard.
    shard_dim: Optional[int] = dataclasses.field(metadata=dict(static=True),
                                                 default=None)
    shard_count: int = dataclasses.field(metadata=dict(static=True),
                                         default=1)
    # activation-reuse group: projections with the same input and the same
    # (a_bits, a_signed, trained bank-scale values) share a tag, so the
    # dispatch layer quantizes their common activation once per forward
    # ("" = never reuse). Assigned by the serving session at pack time,
    # where the bank values are concrete and comparable.
    a_group: str = dataclasses.field(metadata=dict(static=True), default="")

    # -- accounting ---------------------------------------------------------
    @property
    def packed_bytes(self) -> int:
        """HBM bytes of the weight codes (scales reported separately)."""
        return int(np.prod(self.codes.shape)) * self.codes.dtype.itemsize

    @property
    def per_shard_bytes(self) -> int:
        """Per-device HBM bytes of the codes once sharded ``shard_count``
        ways (the full ``packed_bytes`` when packed unsharded/replicated).
        Exact — per-shard packing makes the sharded codes dim divisible."""
        return self.packed_bytes // max(self.shard_count, 1)

    @property
    def scale_bytes(self) -> int:
        return int(np.prod(self.scale.shape)) * self.scale.dtype.itemsize

    @property
    def a_range(self) -> Tuple[float, float]:
        lo, hi = bit_range(self.a_bits, self.a_signed)
        return float(lo), float(hi)

    # -- codes --------------------------------------------------------------
    def sharded_layout(self) -> bool:
        """True when the codes bytes differ from the plain packing — i.e.
        they are a concatenation of independently packed shard slabs that
        ``unpack`` must reassemble shard by shard."""
        if self.shard_count <= 1 or self.shard_dim is None:
            return False
        if self.layout == "bitstream":
            return True
        d = self.shard_dim % len(self.shape)
        return (self.layout in _PACK_MULT and d == len(self.shape) - 2
                and (self.shape[-2] // self.shard_count) % _PACK_MULT[
                    self.layout] != 0)

    def unpack(self) -> Array:
        """Exact signed integer codes in the weight's original shape."""
        n = int(np.prod(self.shape))
        if self.layout == "int8":
            return self.codes
        if self.sharded_layout():
            return self._unpack_sharded()
        if self.layout == "nib4":
            return unpack_nib4(self.codes, self.shape[-2])
        if self.layout == "quad2":
            return unpack_quad2(self.codes, self.shape[-2])
        return unpack_codes(self.codes, self.w_bits, n).reshape(self.shape)

    def _unpack_sharded(self) -> Array:
        """Inverse of the per-shard packing: split the codes into their
        ``shard_count`` slabs, unpack each, and concatenate along the
        original shard dim."""
        d = (self.shard_dim or 0) % len(self.shape)
        shard_shape = list(self.shape)
        shard_shape[d] //= self.shard_count
        if self.layout == "bitstream":
            n_s = int(np.prod(shard_shape))
            slabs = jnp.split(self.codes, self.shard_count)
            parts = [unpack_codes(s, self.w_bits, n_s).reshape(shard_shape)
                     for s in slabs]
            return jnp.concatenate(parts, axis=d)
        ks = shard_shape[-2]
        unpack = unpack_nib4 if self.layout == "nib4" else unpack_quad2
        slabs = jnp.split(self.codes, self.shard_count, axis=-2)
        return jnp.concatenate([unpack(s, ks) for s in slabs], axis=-2)

    def dequant(self, dtype=jnp.float32) -> Array:
        """Dequantized weight — bit-exact with the fake-quant graph when
        ``scale`` came from the trained indicator bank."""
        q = self.unpack().astype(jnp.float32)
        s = _broadcast_scale(self.scale, len(self.shape), self.shape)
        return (q * s).astype(dtype)


def _broadcast_scale(s: Array, w_ndim: int, w_shape) -> Array:
    """Align a scale against a weight: scalars broadcast plainly; a
    per-channel ``(out,)`` vector reshapes onto the LAST dim; anything of
    the weight's own rank (e.g. per-expert ``(E, 1, 1)``, already shaped
    like ``fake_quant_indexed``'s trailing-ones broadcast) passes through.
    """
    if s.ndim == 0:
        return s
    if s.ndim == w_ndim:
        return s
    if s.ndim == 1 and s.shape[0] == w_shape[-1]:
        return s.reshape((1,) * (w_ndim - 1) + (-1,))
    raise ValueError(f"scale shape {s.shape} does not align with weight "
                     f"shape {tuple(w_shape)}")


def quantize_to_grid(w: Array, bits: int, scale: Array) -> Array:
    """``round(clip(w/s, qmin, qmax))`` on the signed `bits` grid — the
    value map of ``core.quantizer.fake_quant`` (including its scale floor),
    so ``codes * s == fake_quant(w, s)`` exactly."""
    qmin, qmax = bit_range(bits, True)
    s = jnp.maximum(jnp.asarray(scale, jnp.float32), SCALE_EPS)
    s = _broadcast_scale(s, w.ndim, w.shape)
    return jnp.round(jnp.clip(w.astype(jnp.float32) / s, qmin, qmax))


def channel_scales(w: Array, bits: int) -> Array:
    """Statistics per-channel scales over the last (output) dim:
    ``max|w| / qmax`` reduced over every other axis."""
    _, qmax = bit_range(bits, True)
    red = tuple(range(w.ndim - 1))
    s = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=red) / float(qmax)
    return jnp.maximum(s, SCALE_EPS)


def pack_linear(w: Array, w_bits: int, s_w, a_bits: int, s_a, *,
                a_signed: bool = True,
                per_channel: bool = False,
                shard_dim: Optional[int] = None,
                shard_count: int = 1) -> PackedLinear:
    """Quantize ``w`` onto its searched grid and bit-pack the codes.

    ``s_w`` is the trained scale (the selected indicator-bank entry):
    a scalar for plain projections, or — for expert-stacked tensors whose
    banks select per expert — an array already shaped for the trailing-ones
    broadcast (e.g. ``(E, 1, 1)`` against ``(E, K, N)``). With
    ``per_channel=True`` it is ignored and statistics per-channel scales
    are computed instead (not bit-exact vs the trained fake-quant graph —
    see module docstring).

    ``shard_dim``/``shard_count`` request tensor-parallel per-shard packing
    (module docstring): the quantized codes are identical — only the byte
    layout changes, so each mesh shard of ``codes`` is exactly the packing
    of its weight shard. ``w.shape[shard_dim]`` must split evenly.
    """
    w = jnp.asarray(w)
    out = w.shape[-1]
    if per_channel:
        scale = channel_scales(w, w_bits)
    else:
        s = jnp.maximum(jnp.asarray(s_w, jnp.float32), SCALE_EPS)
        scale = jnp.broadcast_to(s.reshape(()), (out,)) if s.ndim == 0 \
            else s
    q = quantize_to_grid(w, w_bits, scale)
    layout = _layout_for(w_bits)
    sharded = shard_count > 1 and shard_dim is not None
    if sharded and w.shape[shard_dim] % shard_count:
        raise ValueError(
            f"shard dim {shard_dim} of weight shape {tuple(w.shape)} does "
            f"not split into {shard_count} shards")
    if layout == "int8":
        codes = q.astype(jnp.int8)   # byte-per-code: sharding never splits
    elif sharded and (layout == "bitstream"
                      or shard_dim % w.ndim == w.ndim - 2):
        codes = _pack_sharded(q, layout, w_bits, shard_dim % w.ndim,
                              shard_count)
    elif layout == "nib4":
        codes = pack_nib4(q)
    elif layout == "quad2":
        codes = pack_quad2(q)
    else:
        codes = pack_codes(q, w_bits)
    return PackedLinear(
        codes=codes, scale=scale,
        s_a=jnp.asarray(s_a, jnp.float32),
        w_bits=int(w_bits), a_bits=int(a_bits), a_signed=bool(a_signed),
        layout=layout, shape=tuple(int(d) for d in w.shape),
        per_channel=bool(per_channel),
        shard_dim=(int(shard_dim) % w.ndim if sharded else None),
        shard_count=int(shard_count) if sharded else 1)


# ---------------------------------------------------------------------------
# tree-level accounting
# ---------------------------------------------------------------------------
def is_packed(leaf) -> bool:
    return isinstance(leaf, PackedLinear)


def packed_leaves(tree):
    return [x for x in jax.tree.leaves(tree, is_leaf=is_packed)
            if is_packed(x)]


def tree_packed_bytes(tree) -> int:
    """Measured HBM bytes of all packed weight codes in ``tree`` — the
    number the serve smoke checks against ``MPQPolicy.size_bytes``."""
    return sum(pl.packed_bytes for pl in packed_leaves(tree))


def tree_scale_bytes(tree) -> int:
    return sum(pl.scale_bytes for pl in packed_leaves(tree))


def tree_per_shard_bytes(tree) -> int:
    """Per-device HBM bytes of the packed codes under tensor-parallel
    sharding: sharded leaves contribute ``packed_bytes / shard_count``,
    replicated ones their full bytes — the number the per-chip memory gate
    checks against ``MPQPolicy.size_bytes(..., per_shard=tp)``."""
    return sum(pl.per_shard_bytes for pl in packed_leaves(tree))
