"""Shared harness for the sharded-serving checks.

One place builds the "packed session under a host mesh vs the same
session on a single device" comparison that both the slow-tier test
(``tests/test_multidevice.py``) and the quantized-serving benchmark
(``benchmarks/quant_serve_bench.py``) run in an 8-device subprocess —
so a change to the session/engine construction or the request preset
cannot drift between the two.

MUST run in a process where ``xla_force_host_platform_device_count`` was
set before jax initialized (the callers spawn a subprocess for exactly
that reason); the main pytest/bench process keeps its single device.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data import SyntheticLM
from repro.dist import sharding
from repro.dist.axes import NO_AXES, MeshAxes
from repro.launch.engine import DecodeEngine
from repro.launch.serve import ServeConfig, build_requests, demo_mixed_policy
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.runtime.session import QuantizedSession

DEFAULT_PRESET = dict(arch="limpq-demo", slots=4, prompt_len=16, gen=6,
                      n_requests=6, arrive_every=1)


def run_sharded_vs_single(preset: Dict[str, Any] | None = None,
                          mesh_shape: Tuple[int, int] = (2, 4)):
    """Serve one staggered request set twice — single-device (``NO_AXES``)
    and under a ``mesh_shape`` ('data', 'model') host mesh — through the
    packed quantized runtime. Returns ``(ref_tokens, sharded)`` where
    ``sharded`` carries the mesh run's session/engine/axes/tokens for the
    caller's assertions."""
    p = dict(DEFAULT_PRESET, **(preset or {}))
    scfg = ServeConfig(arch=p["arch"], requests=p["n_requests"],
                       slots=p["slots"], prompt_len=p["prompt_len"],
                       gen=p["gen"], stagger=True,
                       arrive_every=p["arrive_every"])
    cfg = smoke_config(scfg.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    policy = demo_mixed_policy(cfg)
    data = SyntheticLM(cfg)
    reqs = build_requests(data, scfg.requests, scfg.prompt_len, scfg.gen,
                          stagger=scfg.stagger,
                          arrive_every=scfg.arrive_every)

    def run(axes: MeshAxes):
        sess = QuantizedSession(cfg, params, policy, ctx, axes,
                                mode="packed", kv_quant="int8")
        eng = DecodeEngine(sess.params, cfg, None, ctx, axes,
                           scfg.engine_config(kv_quant="int8"), adapter=sess)
        eng.submit_all(reqs)
        out = eng.run()
        return sess, eng, {r.rid: out[r.rid].tokens for r in reqs}

    _, _, ref_tokens = run(NO_AXES)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    axes = sharding.make_axes_for(cfg, mesh, shard_seq=False)
    sess, eng, tokens = run(axes)
    return ref_tokens, dict(cfg=cfg, session=sess, engine=eng, axes=axes,
                            tokens=tokens)


def sharded_counters(ref_tokens, sharded) -> Dict[str, Any]:
    """The deterministic, regression-gated view of one harness run —
    the ``sharded_*`` keys of ``BENCH_quant_serve.json``."""
    sess, eng, axes = sharded["session"], sharded["engine"], sharded["axes"]
    per_shard = sess.packed_bytes(per_shard=True)
    budget = sess.per_shard_policy_bytes()
    return {
        "sharded_token_identical": sharded["tokens"] == ref_tokens,
        "sharded_decode_steps": eng.stats.decode_steps,
        "sharded_tokens_generated": eng.stats.tokens_generated,
        "sharded_prefill_compiles": eng.stats.prefill_compiles,
        "sharded_per_shard_vs_policy": per_shard / budget,
        "sharded_tp_size": axes.tp_size,
        "sharded_per_shard_bytes": per_shard,
    }
