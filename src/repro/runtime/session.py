"""QuantizedSession: compile a searched MPQPolicy into a servable model.

This is the "searched policy -> deployed low-bit model" step. Construction
packs once:

1. validate the policy against the model's QLayer table (stale files fail
   loudly),
2. flatten the scan-stacked param tree into per-site subtrees (one per
   ``lm.iter_sites`` entry — serving decode is one token, so unrolling
   trades nothing and gives every site its *own* searched bit-width with
   statically-shaped packed storage),
3. for every searched projection, select the trained indicator-bank scales
   at the policy's bit-widths and quantize + bit-pack the weight
   (``runtime.packing.pack_linear``) — HBM then holds ``ceil(bits/8)``
   bytes per weight, matching ``MPQPolicy.size_bytes`` to within padding.
   Under a real mesh (``axes`` from ``dist.sharding.make_axes_for``) the
   packing is *shard-aware*: each projection packs per shard along its
   megatron tensor-parallel dim (``dist.sharding.projection_shard_fn``),
   so ``codes`` shard over ``tp`` instead of replicating and per-chip HBM
   is ``packed_bytes(per_shard=True)`` ≈ ``policy.size_bytes(per_shard=
   tp)``. ``param_specs()`` exposes the matching PartitionSpec tree
   (``dist.sharding.packed_specs``) for the engine's in_shardings.

Packing also tags activation-reuse groups: projections on one site whose
(a_bits, signedness, trained bank scale values) coincide get a shared
``PackedLinear.a_group``, letting ``runtime.dispatch.act_reuse_scope``
quantize their common input once per forward (wq/wk/wv; MoE wi/wg) —
counted in ``act_quant_reused`` and surfaced as
``EngineStats.act_quant_reused``.

The session then exposes the engine's model-adapter interface (``prefill``
/ ``decode`` / ``init_state`` / ``state_per_slot``), so
``launch.serve --policy`` runs the packed model through the unmodified
continuous-batching engine. Matmuls route through
``runtime.dispatch.packed_qeinsum`` (Pallas int8/int4 kernels on TPU, the
bit-exact dequant-then-fp fallback elsewhere).

Numerics: with per-tensor bank scales (the default) and ``mode="packed"``,
the dequantized weights and on-the-fly activation fake-quant reproduce the
fake-quant training graph *bitwise* on the fallback route, so greedy
tokens are asserted identical against an ``LMAdapter`` reference engine —
including with int8 KV slots, whose reference is ``kv_quant="fake"``.
``mode="reference"`` keeps fake-quant param dicts (same unrolled forward,
no packing) for A/B debugging of the packing itself.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import MPQPolicy
from repro.core.quantizer import (
    bit_range,
    grad_scale,
    lsq_grad_scale_factor,
)
from repro.dist.axes import NO_AXES, MeshAxes
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.obs import health as obs_health
from repro.runtime import packing

Array = jax.Array


def _site_key(gidx: int) -> str:
    return f"{gidx:03d}"


def _get_path(tree, path: Tuple[str, ...]):
    for k in path:
        tree = tree[k]
    return tree


def _set_path(tree, path: Tuple[str, ...], leaf):
    for k in path[:-1]:
        tree = tree[k]
    tree[path[-1]] = leaf


def effective_weight_scale(s_bank: Array, idx: int, numel: int, bits: int,
                           w_ndim: Optional[int] = None) -> Array:
    """The scale value the fake-quant training graph actually divides by:
    bank entry (selected on the LAST axis — leading axes are expert
    stacks) -> floor at 1e-9 -> LSQ grad-scale wrapper (identity in exact
    arithmetic, replicated op-for-op for bitwise parity). Per-expert
    selections are returned in the trailing-ones broadcast form
    ``fake_quant_indexed`` uses (e.g. ``(E, 1, 1)`` for a rank-3 weight,
    via ``w_ndim``)."""
    qmax = float(bit_range(bits, True)[1])
    sel = jnp.asarray(s_bank)[..., idx]
    s = jnp.maximum(sel.astype(jnp.float32), 1e-9)
    s = grad_scale(s, lsq_grad_scale_factor(numel, qmax))
    if s.ndim and w_ndim is not None:
        s = s.reshape(s.shape + (1,) * (w_ndim - s.ndim))
    return s


class QuantizedSession:
    """A packed, policy-quantized model behind the engine adapter API."""

    def __init__(self, cfg: ModelConfig, params, policy: MPQPolicy,
                 ctx: Optional[QuantContext] = None,
                 axes: MeshAxes = NO_AXES, *, mode: str = "packed",
                 kv_quant: str = "int8", per_channel: bool = False):
        if mode not in ("packed", "reference"):
            raise ValueError(f"unknown session mode {mode!r}")
        self.cfg = cfg
        self.policy = policy
        self.mode = mode
        self.axes = axes
        ctx = ctx or QuantContext.make(cfg.bits, cfg.quant_act_signed,
                                       compute_dtype=jnp.float32)
        # the reference view of an int8 slot is quantize-dequantize in fp
        kv_ctx = {"packed": kv_quant,
                  "reference": "fake" if kv_quant == "int8" else kv_quant}
        self.ctx = dataclasses.replace(ctx, kv_quant=kv_ctx[mode])
        self._kv_quant = kv_quant
        # per-channel statistics scales lower quantization error but break
        # bitwise parity with the trained per-tensor indicator scales — the
        # token-identity gate requires the default False
        self.per_channel = bool(per_channel)

        self.qlayers = lm.enumerate_qlayers(cfg)
        policy.validate(self.qlayers, bits=cfg.bits)
        self.sites = lm.iter_sites(cfg)
        self._lut = {int(b): i for i, b in enumerate(cfg.bits)}
        self.act_quant_reused = 0      # trace-time hits, see dispatch
        # per-site pack-time health (saturation / scale utilization),
        # computed host-side in _build_params from the materialized weights
        # and the scales packing actually used; the engine publishes it
        # into its registry each epoch (obs.health.publish_pack_health)
        self.pack_health: Dict[str, Dict[str, float]] = {}
        # obs.metrics.MetricsRegistry shared by the engine (it assigns this
        # at build/reset): _forward binds it so dispatch counts the routes
        # each packed matmul resolves to, per trace
        self.metrics = None
        # Off-TPU, the model axis is a STORAGE axis only: packed codes
        # shard over tp in HBM and gather at use (dispatch docstring), but
        # the layer graph keeps no model-sharded intermediates — compute
        # splits over dp alone (``dist.axes.dp_only`` rationale). On a TPU
        # backend the full megatron split stays on, where the
        # int-accumulating kernel routes make the eqn split exact.
        from repro.dist.axes import dp_only
        self.compute_axes = axes
        if axes.enabled and jax.default_backend() != "tpu":
            self.compute_axes = dp_only(axes)
        self.params = self._build_params(params)

    # -- construction -------------------------------------------------------
    def _site_params(self, params, site) -> Dict[str, Any]:
        seg, idx = site.segment.split(".")
        sub = params[seg][idx]
        if seg == "body":
            sub = jax.tree.map(lambda a: a[site.unit], sub)
        else:
            sub = jax.tree.map(lambda a: a, sub)   # private copy of the dicts
        return sub

    def _build_params(self, params) -> Dict[str, Any]:
        from repro.dist import sharding

        by_site: Dict[int, List] = {}
        for q in self.qlayers:
            by_site.setdefault((q.segment, q.unit), []).append(q)
        shard_info = (sharding.projection_shard_fn(self.cfg, self.axes)
                      if self.axes.enabled else None)

        out: Dict[str, Any] = {
            k: params[k] for k in params if k not in ("prefix", "body",
                                                      "suffix")
        }
        sites_p: Dict[str, Any] = {}
        self._site_bits: Dict[str, Any] = {}
        self._shard_plan: Dict[str, int] = {}
        for site in self.sites:
            key = _site_key(site.gidx)
            sp = self._site_params(params, site)
            bits_d: Dict[str, Any] = {}
            packed_paths: List[Tuple[str, ...]] = []
            for q in by_site[(site.segment, site.unit)]:
                leaf = _get_path(sp, q.path)
                w_idx = self._lut[self.policy.w_bits[q.name]]
                a_idx = self._lut[self.policy.a_bits[q.name]]
                if self.mode == "packed":
                    wb = int(self.policy.w_bits[q.name])
                    s_w = effective_weight_scale(leaf["s_w"], w_idx,
                                                 leaf["w"].size, wb,
                                                 w_ndim=leaf["w"].ndim)
                    sd, sc = (None, 1)
                    if shard_info is not None:
                        name = "/".join(("sites", key) + q.path + ("w",))
                        sd, sc = shard_info(name, tuple(leaf["w"].shape))
                    self._shard_plan[q.name] = sc
                    pl = packing.pack_linear(
                        leaf["w"], wb, s_w,
                        int(self.policy.a_bits[q.name]),
                        jnp.asarray(leaf["s_a"])[..., a_idx],
                        a_signed=self.cfg.quant_act_signed,
                        per_channel=self.per_channel,
                        shard_dim=sd, shard_count=sc)
                    # health from the scale the packing actually used
                    # (pl.scale covers both bank and per-channel modes)
                    self.pack_health[q.name] = obs_health.site_health(
                        leaf["w"], wb, pl.scale)
                    _set_path(sp, q.path, pl)
                    packed_paths.append(q.path)
                else:
                    d: Dict[str, Any] = {}
                    lm._nest(d, q.path, {"w": w_idx, "a": a_idx})
                    # merged below via bits_d
                    bits_d = _merge(bits_d, d)
            _tag_act_groups(sp, packed_paths, key)
            sites_p[key] = sp
            self._site_bits[key] = bits_d if self.mode == "reference" else None
        out["sites"] = sites_p
        return out

    # -- accounting ---------------------------------------------------------
    def packed_bytes(self, per_shard: bool = False) -> int:
        """Measured HBM bytes of the packed weight codes.

        ``per_shard=True`` gives the per-device view under the session's
        mesh: tensor-parallel-sharded leaves count ``bytes / shard_count``,
        replicated ones their full bytes — comparable against
        ``policy.size_bytes(qlayers, per_shard=axes.tp_size)``."""
        if per_shard:
            return packing.tree_per_shard_bytes(self.params)
        return packing.tree_packed_bytes(self.params)

    def param_specs(self):
        """PartitionSpec tree for ``self.params`` under the session's axes
        (``dist.sharding.packed_specs``) — the engine's in_shardings hook."""
        from repro.dist import sharding
        return sharding.packed_specs(self.cfg, self.params, self.axes)

    def per_shard_policy_bytes(self) -> float:
        """Per-chip weight-bytes budget under this session's ACTUAL shard
        plan: each searched projection's policy bytes divided by the
        tensor-parallel factor its partition rule grants it. Equals
        ``policy.size_bytes(per_shard=tp)`` when every projection shards
        (the limpq-demo case); on archs where the divisibility fallbacks
        legitimately replicate some projections (e.g. heads not dividing
        the model axis) those count in full per chip — the per-chip gate
        must not blame packing for a partition-rule fallback."""
        total = 0.0
        for q in self.qlayers:
            bytes_q = q.w_params * self.policy.w_bits[q.name] / 8.0
            total += bytes_q / max(self._shard_plan.get(q.name, 1), 1)
        return total

    def scale_bytes(self) -> int:
        return packing.tree_scale_bytes(self.params)

    def policy_bytes(self) -> float:
        """What the ILP accounted for: ``MPQPolicy.size_bytes``."""
        return self.policy.size_bytes(self.qlayers)

    def fp_bytes(self, bytes_per_param: int = 4) -> int:
        """Unquantized weight bytes of the searched projections."""
        return sum(q.w_params for q in self.qlayers) * bytes_per_param

    @property
    def kv_quant(self) -> str:
        return self._kv_quant

    @property
    def w_bits_total(self) -> float:
        """Exact packed weight-storage bits for the roofline's bytes term."""
        return self.policy_bytes() * 8.0

    # -- engine adapter API -------------------------------------------------
    def _forward(self, params, x, img_x, mode, states, pos, prefill_cap,
                 slot=None):
        from repro.runtime import dispatch

        new_states = {"sites": {}}
        with dispatch.axes_scope(self.axes), \
                dispatch.metrics_scope(self.metrics), \
                dispatch.act_reuse_scope() as scope:
            for site in self.sites:
                key = _site_key(site.gidx)
                st = None if states is None else states["sites"].get(key)
                x, st, _ = lm.apply_layer(
                    site.kind, x, params["sites"][key], self._site_bits[key],
                    self.cfg, self.ctx, self.compute_axes, mode=mode,
                    state=st, pos=pos, img_x=img_x, prefill_cap=prefill_cap,
                    slot=slot)
                new_states["sites"][key] = st
        # trace-time count: quantize ops elided from this compiled graph
        self.act_quant_reused += scope["hits"]
        if self.metrics is not None and scope["hits"]:
            self.metrics.counter("dispatch.act_reuse_hits").inc(scope["hits"])
        return x, new_states

    def prefill(self, params, inputs, *, prefill_cap, true_len=None):
        x, img_x = lm.embed_inputs(params, self.cfg, inputs, self.ctx,
                                   self.compute_axes)
        x, states = self._forward(params, x, img_x, "prefill", None, None,
                                  prefill_cap)
        return lm.finish_prefill(x, states, params, self.cfg, self.ctx,
                                 self.compute_axes, true_len)

    def decode(self, params, tok, pos, states):
        x, _ = lm.embed_inputs(params, self.cfg, {"tokens": tok}, self.ctx,
                               self.compute_axes)
        x, new_states = self._forward(params, x, None, "decode", states, pos,
                                      None)
        logits = lm.lm_head(x, params, self.cfg, self.ctx, self.compute_axes)
        return logits[:, 0], new_states

    def verify(self, params, tok, pos, states):
        """Speculative verify: run S = k+1 tokens per slot in ONE
        multi-token step over the cached KV (``lm`` mode="verify"),
        appending all S rows and attending each query only to rows at
        positions <= its own — via the exact per-route single-token
        attention primitive, so hidden states and written KV rows are
        bitwise what S sequential ``decode`` calls would produce.
        ``tok``/``pos`` are (B, S); returns (logits (B, S, V), states)."""
        x, _ = lm.embed_inputs(params, self.cfg, {"tokens": tok}, self.ctx,
                               self.compute_axes)
        x, new_states = self._forward(params, x, None, "verify", states, pos,
                                      None)
        logits = lm.lm_head(x, params, self.cfg, self.ctx, self.compute_axes)
        return logits, new_states

    def append(self, params, tok, pos, slot, last_idx, states):
        """Chunked (paged) prefill: run a (1, C) token chunk through the
        model for ONE slot, writing KV rows at absolute positions ``pos``
        ((C,), -1 marks pad rows that are dropped at the cache write) into
        that slot's pages. Returns (last-valid-row logits (1, V), states)."""
        x, _ = lm.embed_inputs(params, self.cfg, {"tokens": tok}, self.ctx,
                               self.compute_axes)
        x, new_states = self._forward(params, x, None, "append", states, pos,
                                      None, slot=slot)
        x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
        logits = lm.lm_head(x_last, params, self.cfg, self.ctx,
                            self.compute_axes)
        return logits[:, 0], new_states

    def init_state(self, batch, capacity, dtype, per_slot=True, layout=None):
        kv = "int8" if self.ctx.kv_quant == "int8" else "none"
        return {"sites": {
            _site_key(s.gidx): lm.init_site_state(
                self.cfg, s.kind, batch, capacity, dtype=dtype,
                per_slot=per_slot, kv_quant=kv, layout=layout)
            for s in self.sites}}

    def state_per_slot(self, row):
        return lm.decode_state_per_slot(row)

    # -- persistence --------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, directory: str, cfg: ModelConfig, *,
                        step: Optional[int] = None,
                        ctx: Optional[QuantContext] = None,
                        axes: MeshAxes = NO_AXES,
                        **kwargs) -> "QuantizedSession":
        """Restore a ``checkpoint.save_serving_bundle`` artifact (params +
        policy) and pack it for serving.

        The bundled policy is validated against ``cfg``'s QLayer table
        BEFORE the param restore touches the template: a stale or foreign
        bundle fails loudly with the same ``MPQPolicy.validate`` message
        path as ``lm.bits_from_policy``, instead of a cryptic
        missing-array/shape error from the checkpoint reader."""
        from repro import checkpoint as ckpt

        template = lm.init_params(jax.random.PRNGKey(0), cfg)
        params, policy, _ = ckpt.load_serving_bundle(
            directory, template, step=step,
            validate=lambda p: p.validate(lm.enumerate_qlayers(cfg),
                                          bits=cfg.bits))
        return cls(cfg, params, policy, ctx, axes, **kwargs)


def draft_policy(policy: MPQPolicy, qlayers, bits,
                 draft_w_bits: int = 2) -> MPQPolicy:
    """Derive the self-speculative DRAFT policy from the searched target.

    Same layers, same a_bits (so activation quantization — and the
    act-reuse grouping — is bitwise the target's), weights uniformly at
    ``draft_w_bits``. Both policies select from the SAME trained
    indicator banks, so the draft costs zero extra trained state: the
    paper's bit-width menu, read at a second (cheaper) point. The draft
    width must be one of the searched ``bits`` — otherwise there is no
    trained bank entry to select and packing would be meaningless."""
    db = int(draft_w_bits)
    if db not in {int(b) for b in bits}:
        raise ValueError(
            f"draft_w_bits={db} is not in the searched bit set "
            f"{sorted(int(b) for b in bits)}; the draft policy can only "
            "read bit-widths the indicator banks were trained for")
    return MPQPolicy({q.name: db for q in qlayers}, dict(policy.a_bits),
                     meta={"kind": "spec-draft", "draft_w_bits": db,
                           "target": dict(policy.meta)})


class SpecSession(QuantizedSession):
    """Dual-policy pack for self-speculative decoding.

    ONE set of trained weights and banks, TWO packed param trees:
    ``self.params`` is the searched target policy (the quality contract
    — emitted tokens are its greedy tokens, by construction), and
    ``self.draft_params`` is a uniform low-bit (int2/int3) repack of the
    same weights used only to PROPOSE tokens. Both trees run through the
    same ``_forward`` / engine adapter; the engine jits draft steps
    against ``draft_params`` and verify steps against ``params``.

    The draft shares the target's a_bits and indicator-bank scales
    (``draft_policy``), so activation quantization in the draft pass is
    bitwise the target's — the bank-sharing requirement ``ServeConfig``
    validates for ``--speculate``."""

    def __init__(self, cfg: ModelConfig, params, policy: MPQPolicy,
                 ctx: Optional[QuantContext] = None,
                 axes: MeshAxes = NO_AXES, *, draft_w_bits: int = 2,
                 mode: str = "packed", **kwargs):
        if mode != "packed":
            raise ValueError(
                "SpecSession packs two policies over one weight set; "
                "mode='reference' keeps fake-quant params and has nothing "
                "to dual-pack — build a plain QuantizedSession instead")
        super().__init__(cfg, params, policy, ctx, axes, mode=mode, **kwargs)
        self.draft_w_bits = int(draft_w_bits)
        self.policy_draft = draft_policy(policy, self.qlayers, cfg.bits,
                                         self.draft_w_bits)
        # pack the second tree through the same machinery by swapping the
        # active policy; _site_bits/_shard_plan come out identical (packed
        # mode, same shapes) so restoring the policy restores the session
        target_policy, target_health = self.policy, self.pack_health
        self.policy, self.pack_health = self.policy_draft, {}
        self.draft_params = self._build_params(params)
        self.draft_pack_health = self.pack_health
        self.policy, self.pack_health = target_policy, target_health

    def draft_bytes(self) -> int:
        """Measured HBM bytes of the draft tree's packed codes — the bytes
        the roofline charges k times per speculative round."""
        return packing.tree_packed_bytes(self.draft_params)


def bank_fingerprint(params) -> str:
    """Fingerprint of the trained indicator-bank scales.

    Hashes every ``s_w`` / ``s_a`` leaf in sorted-path order. Policy
    variants searched over the same banks carry this stamp in
    ``meta["indicator_family"]``; ``MPQPolicy.validate(family=...)`` then
    rejects a bundle mixing variants from different trainings — their bit
    assignments were learned against scales this checkpoint does not
    have, and a hot-swap between them would break the shared
    activation-quantization contract the token-identity gate relies on.
    """
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    picked = []
    for path, leaf in leaves:
        keys = tuple(str(getattr(p, "key", getattr(p, "name",
                                                   getattr(p, "idx", p))))
                     for p in path)
        if keys and keys[-1] in ("s_w", "s_a"):
            picked.append((keys, leaf))
    if not picked:
        raise ValueError(
            "no indicator-bank scale leaves (s_w/s_a) in params: cannot "
            "fingerprint the bank family — was this checkpoint trained "
            "with learned importance indicators?")
    h = hashlib.sha1()
    for keys, leaf in sorted(picked, key=lambda kv: kv[0]):
        h.update("/".join(keys).encode())
        h.update(np.asarray(leaf, np.float32).tobytes())
    return h.hexdigest()[:16]


class ElasticSession(QuantizedSession):
    """Policy-variant bank for elastic precision serving.

    ONE set of trained weights and indicator banks, N packed param trees
    — one per ``MPQPolicy`` variant (e.g. 3/4/6-bit average budgets
    searched over the same banks; ``launch.elastic.build_variant_bank``).
    Every variant packs ONCE at build through the same policy-swap
    machinery ``SpecSession`` dual-packs with; serving then switches the
    active tree between batches via ``set_active`` — the engine
    ``device_put``s the returned pre-packed tree, so no repacking ever
    happens on the hot path.

    Build fails loudly if any variant's ``meta["indicator_family"]``
    stamp disagrees with ``bank_fingerprint(params)``: variants searched
    from different trainings do not share the activation-quantization
    contract a hot-swap assumes.
    """

    def __init__(self, cfg: ModelConfig, params,
                 variants: Mapping[str, MPQPolicy],
                 ctx: Optional[QuantContext] = None,
                 axes: MeshAxes = NO_AXES, *, active: Optional[str] = None,
                 mode: str = "packed", **kwargs):
        if mode != "packed":
            raise ValueError(
                "ElasticSession packs N policy variants over one weight "
                "set; mode='reference' keeps fake-quant params and has "
                "nothing to swap — build a plain QuantizedSession instead")
        items = [(str(pid), pol) for pid, pol in variants.items()]
        if len(items) < 2:
            raise ValueError(
                "ElasticSession needs >= 2 policy variants; a single "
                "policy is a plain QuantizedSession")
        family = bank_fingerprint(params)
        qlayers = lm.enumerate_qlayers(cfg)
        for pid, pol in items:
            try:
                pol.validate(qlayers, bits=cfg.bits, family=family)
            except ValueError as e:
                raise ValueError(f"policy variant {pid!r}: {e}") from e
        by_id = dict(items)
        active = items[0][0] if active is None else str(active)
        if active not in by_id:
            raise ValueError(
                f"active variant {active!r} not in bank {sorted(by_id)}")
        super().__init__(cfg, params, by_id[active], ctx, axes, mode=mode,
                         **kwargs)
        self.family = family
        self.active_policy = active
        self.variant_policies: Dict[str, MPQPolicy] = by_id
        self.variants: Dict[str, Any] = {active: self.params}
        self.variant_pack_health: Dict[str, Dict[str, Dict[str, float]]] = {
            active: self.pack_health}
        for pid, pol in items:
            if pid == active:
                continue
            # pack through the same machinery by swapping the active
            # policy (the SpecSession dual-pack pattern): _site_bits /
            # _shard_plan come out identical in packed mode, so restoring
            # the policy restores the session
            keep_policy, keep_health = self.policy, self.pack_health
            self.policy, self.pack_health = pol, {}
            self.variants[pid] = self._build_params(params)
            self.variant_pack_health[pid] = self.pack_health
            self.policy, self.pack_health = keep_policy, keep_health

    # -- variant bank -------------------------------------------------------
    def params_for(self, pid: str):
        """The pre-packed param tree of one variant (no packing here)."""
        return self.variants[str(pid)]

    def set_active(self, pid: str):
        """Make ``pid`` the serving variant — accounting (``policy``,
        ``pack_health``, ``packed_bytes``) follows the swap — and return
        its pre-packed tree for the engine to ``device_put``."""
        pid = str(pid)
        if pid not in self.variants:
            raise KeyError(
                f"unknown policy variant {pid!r}: {sorted(self.variants)}")
        self.active_policy = pid
        self.policy = self.variant_policies[pid]
        self.pack_health = self.variant_pack_health[pid]
        self.params = self.variants[pid]
        return self.params

    def variant_bytes(self) -> Dict[str, int]:
        """Measured packed-code HBM bytes per resident variant — what
        keeping the whole bank on-device costs."""
        return {pid: packing.tree_packed_bytes(tree)
                for pid, tree in self.variants.items()}


def _tag_act_groups(sp, packed_paths, site_key: str) -> None:
    """Assign ``PackedLinear.a_group`` reuse tags within one site.

    Two packed projections may share a quantized activation only when
    their quantization of it is bitwise the same op: equal a_bits, equal
    signedness, and equal *values* in the selected trained bank scale.
    The values are concrete here (packing happens eagerly at build), so
    the grouping is exact — a tag is assigned only to groups of two or
    more, and it embeds the site key so identical banks on different
    sites (e.g. the same init value) can never alias across sites."""
    import numpy as np

    groups: Dict[Tuple, List[Tuple[str, ...]]] = {}
    for path in packed_paths:
        pl = _get_path(sp, path)
        fp = (pl.a_bits, pl.a_signed,
              np.asarray(pl.s_a, np.float32).tobytes())
        groups.setdefault(fp, []).append(path)
    gi = 0
    for fp, paths in groups.items():
        if len(paths) < 2:
            continue
        tag = f"{site_key}.a{gi}"
        gi += 1
        for path in paths:
            pl = _get_path(sp, path)
            _set_path(sp, path, dataclasses.replace(pl, a_group=tag))


def _merge(dst: dict, src: dict) -> dict:
    for k, v in src.items():
        if isinstance(v, dict) and isinstance(dst.get(k), dict):
            _merge(dst[k], v)
        else:
            dst[k] = v
    return dst


def summarize(session: QuantizedSession) -> Dict[str, Any]:
    """HBM accounting for logs / the quant-serve benchmark."""
    packed = session.packed_bytes()
    target = session.policy_bytes()
    tp = session.axes.tp_size if session.axes.enabled else 1
    per_shard = session.packed_bytes(per_shard=True)
    shard_target = session.per_shard_policy_bytes()
    return {
        "mode": session.mode,
        "packed_bytes": int(packed),
        "scale_bytes": int(session.scale_bytes()),
        "policy_bytes": float(target),
        "fp32_bytes": int(session.fp_bytes()),
        "packed_vs_policy": packed / target if target else float("nan"),
        "compression_vs_fp32": session.fp_bytes() / packed if packed
        else float("nan"),
        "avg_bits": session.policy.avg_bits(),
        "kv_quant": session.kv_quant,
        "tp_size": int(tp),
        "per_shard_bytes": int(per_shard),
        "per_shard_vs_policy": (per_shard / shard_target if shard_target
                                else float("nan")),
        "act_quant_reused": int(session.act_quant_reused),
    }
