"""QuantizedSession: compile a searched MPQPolicy into a servable model.

This is the "searched policy -> deployed low-bit model" step. Construction
packs once:

1. validate the policy against the model's QLayer table (stale files fail
   loudly),
2. flatten the scan-stacked param tree into per-site subtrees (one per
   ``lm.iter_sites`` entry — serving decode is one token, so unrolling
   trades nothing and gives every site its *own* searched bit-width with
   statically-shaped packed storage),
3. for every searched projection, select the trained indicator-bank scales
   at the policy's bit-widths and quantize + bit-pack the weight
   (``runtime.packing.pack_linear``) — HBM then holds ``ceil(bits/8)``
   bytes per weight, matching ``MPQPolicy.size_bytes`` to within padding.

The session then exposes the engine's model-adapter interface (``prefill``
/ ``decode`` / ``init_state`` / ``state_per_slot``), so
``launch.serve --policy`` runs the packed model through the unmodified
continuous-batching engine. Matmuls route through
``runtime.dispatch.packed_qeinsum`` (Pallas int8/int4 kernels on TPU, the
bit-exact dequant-then-fp fallback elsewhere).

Numerics: with per-tensor bank scales (the default) and ``mode="packed"``,
the dequantized weights and on-the-fly activation fake-quant reproduce the
fake-quant training graph *bitwise* on the fallback route, so greedy
tokens are asserted identical against an ``LMAdapter`` reference engine —
including with int8 KV slots, whose reference is ``kv_quant="fake"``.
``mode="reference"`` keeps fake-quant param dicts (same unrolled forward,
no packing) for A/B debugging of the packing itself.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import MPQPolicy
from repro.core.quantizer import (
    bit_range,
    grad_scale,
    lsq_grad_scale_factor,
)
from repro.dist.axes import NO_AXES, MeshAxes
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.runtime import packing

Array = jax.Array


def _site_key(gidx: int) -> str:
    return f"{gidx:03d}"


def _get_path(tree, path: Tuple[str, ...]):
    for k in path:
        tree = tree[k]
    return tree


def _set_path(tree, path: Tuple[str, ...], leaf):
    for k in path[:-1]:
        tree = tree[k]
    tree[path[-1]] = leaf


def effective_weight_scale(s_bank: Array, idx: int, numel: int, bits: int,
                           w_ndim: Optional[int] = None) -> Array:
    """The scale value the fake-quant training graph actually divides by:
    bank entry (selected on the LAST axis — leading axes are expert
    stacks) -> floor at 1e-9 -> LSQ grad-scale wrapper (identity in exact
    arithmetic, replicated op-for-op for bitwise parity). Per-expert
    selections are returned in the trailing-ones broadcast form
    ``fake_quant_indexed`` uses (e.g. ``(E, 1, 1)`` for a rank-3 weight,
    via ``w_ndim``)."""
    qmax = float(bit_range(bits, True)[1])
    sel = jnp.asarray(s_bank)[..., idx]
    s = jnp.maximum(sel.astype(jnp.float32), 1e-9)
    s = grad_scale(s, lsq_grad_scale_factor(numel, qmax))
    if s.ndim and w_ndim is not None:
        s = s.reshape(s.shape + (1,) * (w_ndim - s.ndim))
    return s


class QuantizedSession:
    """A packed, policy-quantized model behind the engine adapter API."""

    def __init__(self, cfg: ModelConfig, params, policy: MPQPolicy,
                 ctx: Optional[QuantContext] = None,
                 axes: MeshAxes = NO_AXES, *, mode: str = "packed",
                 kv_quant: str = "int8", per_channel: bool = False):
        if mode not in ("packed", "reference"):
            raise ValueError(f"unknown session mode {mode!r}")
        self.cfg = cfg
        self.policy = policy
        self.mode = mode
        self.axes = axes
        ctx = ctx or QuantContext.make(cfg.bits, cfg.quant_act_signed,
                                       compute_dtype=jnp.float32)
        # the reference view of an int8 slot is quantize-dequantize in fp
        kv_ctx = {"packed": kv_quant,
                  "reference": "fake" if kv_quant == "int8" else kv_quant}
        self.ctx = dataclasses.replace(ctx, kv_quant=kv_ctx[mode])
        self._kv_quant = kv_quant
        # per-channel statistics scales lower quantization error but break
        # bitwise parity with the trained per-tensor indicator scales — the
        # token-identity gate requires the default False
        self.per_channel = bool(per_channel)

        self.qlayers = lm.enumerate_qlayers(cfg)
        policy.validate(self.qlayers, bits=cfg.bits)
        self.sites = lm.iter_sites(cfg)
        self._lut = {int(b): i for i, b in enumerate(cfg.bits)}
        self.params = self._build_params(params)

    # -- construction -------------------------------------------------------
    def _site_params(self, params, site) -> Dict[str, Any]:
        seg, idx = site.segment.split(".")
        sub = params[seg][idx]
        if seg == "body":
            sub = jax.tree.map(lambda a: a[site.unit], sub)
        else:
            sub = jax.tree.map(lambda a: a, sub)   # private copy of the dicts
        return sub

    def _build_params(self, params) -> Dict[str, Any]:
        by_site: Dict[int, List] = {}
        for q in self.qlayers:
            by_site.setdefault((q.segment, q.unit), []).append(q)

        out: Dict[str, Any] = {
            k: params[k] for k in params if k not in ("prefix", "body",
                                                      "suffix")
        }
        sites_p: Dict[str, Any] = {}
        self._site_bits: Dict[str, Any] = {}
        for site in self.sites:
            sp = self._site_params(params, site)
            bits_d: Dict[str, Any] = {}
            for q in by_site[(site.segment, site.unit)]:
                leaf = _get_path(sp, q.path)
                w_idx = self._lut[self.policy.w_bits[q.name]]
                a_idx = self._lut[self.policy.a_bits[q.name]]
                if self.mode == "packed":
                    wb = int(self.policy.w_bits[q.name])
                    s_w = effective_weight_scale(leaf["s_w"], w_idx,
                                                 leaf["w"].size, wb,
                                                 w_ndim=leaf["w"].ndim)
                    pl = packing.pack_linear(
                        leaf["w"], wb, s_w,
                        int(self.policy.a_bits[q.name]),
                        jnp.asarray(leaf["s_a"])[..., a_idx],
                        a_signed=self.cfg.quant_act_signed,
                        per_channel=self.per_channel)
                    _set_path(sp, q.path, pl)
                else:
                    d: Dict[str, Any] = {}
                    lm._nest(d, q.path, {"w": w_idx, "a": a_idx})
                    # merged below via bits_d
                    bits_d = _merge(bits_d, d)
            key = _site_key(site.gidx)
            sites_p[key] = sp
            self._site_bits[key] = bits_d if self.mode == "reference" else None
        out["sites"] = sites_p
        return out

    # -- accounting ---------------------------------------------------------
    def packed_bytes(self) -> int:
        """Measured HBM bytes of the packed weight codes."""
        return packing.tree_packed_bytes(self.params)

    def scale_bytes(self) -> int:
        return packing.tree_scale_bytes(self.params)

    def policy_bytes(self) -> float:
        """What the ILP accounted for: ``MPQPolicy.size_bytes``."""
        return self.policy.size_bytes(self.qlayers)

    def fp_bytes(self, bytes_per_param: int = 4) -> int:
        """Unquantized weight bytes of the searched projections."""
        return sum(q.w_params for q in self.qlayers) * bytes_per_param

    @property
    def kv_quant(self) -> str:
        return self._kv_quant

    @property
    def w_bits_total(self) -> float:
        """Exact packed weight-storage bits for the roofline's bytes term."""
        return self.policy_bytes() * 8.0

    # -- engine adapter API -------------------------------------------------
    def _forward(self, params, x, img_x, mode, states, pos, prefill_cap):
        new_states = {"sites": {}}
        for site in self.sites:
            key = _site_key(site.gidx)
            st = None if states is None else states["sites"].get(key)
            x, st, _ = lm.apply_layer(
                site.kind, x, params["sites"][key], self._site_bits[key],
                self.cfg, self.ctx, self.axes, mode=mode, state=st, pos=pos,
                img_x=img_x, prefill_cap=prefill_cap)
            new_states["sites"][key] = st
        return x, new_states

    def prefill(self, params, inputs, *, prefill_cap, true_len=None):
        x, img_x = lm.embed_inputs(params, self.cfg, inputs, self.ctx,
                                   self.axes)
        x, states = self._forward(params, x, img_x, "prefill", None, None,
                                  prefill_cap)
        return lm.finish_prefill(x, states, params, self.cfg, self.ctx,
                                 self.axes, true_len)

    def decode(self, params, tok, pos, states):
        x, _ = lm.embed_inputs(params, self.cfg, {"tokens": tok}, self.ctx,
                               self.axes)
        x, new_states = self._forward(params, x, None, "decode", states, pos,
                                      None)
        logits = lm.lm_head(x, params, self.cfg, self.ctx, self.axes)
        return logits[:, 0], new_states

    def init_state(self, batch, capacity, dtype, per_slot=True):
        kv = "int8" if self.ctx.kv_quant == "int8" else "none"
        return {"sites": {
            _site_key(s.gidx): lm.init_site_state(
                self.cfg, s.kind, batch, capacity, dtype=dtype,
                per_slot=per_slot, kv_quant=kv)
            for s in self.sites}}

    def state_per_slot(self, row):
        return lm.decode_state_per_slot(row)

    # -- persistence --------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, directory: str, cfg: ModelConfig, *,
                        step: Optional[int] = None,
                        ctx: Optional[QuantContext] = None,
                        axes: MeshAxes = NO_AXES,
                        **kwargs) -> "QuantizedSession":
        """Restore a ``checkpoint.save_serving_bundle`` artifact (params +
        policy) and pack it for serving."""
        from repro import checkpoint as ckpt

        template = lm.init_params(jax.random.PRNGKey(0), cfg)
        params, policy, _ = ckpt.load_serving_bundle(directory, template,
                                                     step=step)
        return cls(cfg, params, policy, ctx, axes, **kwargs)


def _merge(dst: dict, src: dict) -> dict:
    for k, v in src.items():
        if isinstance(v, dict) and isinstance(dst.get(k), dict):
            _merge(dst[k], v)
        else:
            dst[k] = v
    return dst


def summarize(session: QuantizedSession) -> Dict[str, Any]:
    """HBM accounting for logs / the quant-serve benchmark."""
    packed = session.packed_bytes()
    target = session.policy_bytes()
    return {
        "mode": session.mode,
        "packed_bytes": int(packed),
        "scale_bytes": int(session.scale_bytes()),
        "policy_bytes": float(target),
        "fp32_bytes": int(session.fp_bytes()),
        "packed_vs_policy": packed / target if target else float("nan"),
        "compression_vs_fp32": session.fp_bytes() / packed if packed
        else float("nan"),
        "avg_bits": session.policy.avg_bits(),
        "kv_quant": session.kv_quant,
    }
