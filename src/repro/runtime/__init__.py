"""repro.runtime — policy-driven mixed-precision serving runtime.

Compiles a searched ``MPQPolicy`` into a deployable quantized model:

* ``packing``  — quantize weights onto the searched per-layer grid and
  bit-pack sub-8-bit codes (int4 two-per-byte, int2 four-per-byte, generic
  bitstream otherwise) with per-channel or per-tensor scales, plus exact
  unpack. ``PackedLinear`` is the packed param-tree leaf.
* ``dispatch`` — per-layer kernel registry keyed by bit-width/shape that
  routes packed matmuls to the Pallas int8/int4 kernels, falling back to
  an exact dequant-then-fp einsum for shapes the kernels can't tile.
* ``kv_cache`` — int8 per-slot KV quantization (per-head write-time
  scales) integrated into ``models.attention.decode_attention`` behind the
  ``QuantContext.kv_quant`` flag.
* ``session``  — ``QuantizedSession``: load a checkpointed policy+params,
  pack once, and expose prefill/decode drop-ins so the continuous-batching
  engine serves the quantized model (imported as ``repro.runtime.session``;
  not imported here to keep ``models`` -> ``runtime.kv_cache`` acyclic).
"""
from repro.runtime import dispatch, kv_cache, packing  # noqa: F401
from repro.runtime.kv_cache import QuantKVCache  # noqa: F401
from repro.runtime.packing import PackedLinear  # noqa: F401
