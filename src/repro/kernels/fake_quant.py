"""Fused LSQ fake-quant Pallas kernel (TPU target, validated interpret=True).

XLA lowers Eq. 1 (`round(clip(v/s)) * s`) plus the LSQ backward into several
elementwise HBM round-trips; memory-bound at ~3x the minimum traffic. The
kernel fuses forward into ONE VMEM pass, and the backward (dv, partial ds)
into one more. Tiles are (block_rows, 128·lanes) — VPU-aligned.

The scalar step size `s` rides along as a (1, 1) block broadcast to every
tile; ds is reduced hierarchically: each tile writes one partial, the (tiny)
final sum happens in the jitted wrapper (`ops.fake_quant`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)


def _fwd_kernel(v_ref, s_ref, o_ref, *, qmin, qmax):
    s = jnp.maximum(s_ref[0, 0], 1e-9)
    vs = v_ref[...].astype(jnp.float32) / s
    vbar = jnp.clip(vs, qmin, qmax)
    o_ref[...] = (jnp.round(vbar) * s).astype(o_ref.dtype)


def _bwd_kernel(v_ref, s_ref, g_ref, dv_ref, ds_ref, *, qmin, qmax):
    s = jnp.maximum(s_ref[0, 0], 1e-9)
    vs = v_ref[...].astype(jnp.float32) / s
    g = g_ref[...].astype(jnp.float32)
    inside = (vs > qmin) & (vs < qmax)
    # dv: straight-through inside the clip range
    dv_ref[...] = jnp.where(inside, g, 0.0).astype(dv_ref.dtype)
    # ds: (round(vs) - vs) inside; clip boundary outside
    r = jnp.round(jnp.clip(vs, qmin, qmax))
    dsd = jnp.where(inside, r - vs, jnp.clip(vs, qmin, qmax))
    ds_ref[0, 0] = jnp.sum(g * dsd)


def _pad2d(v, bm, bn):
    M, N = v.shape
    pm, pn = (-M) % bm, (-N) % bn
    if pm or pn:
        v = jnp.pad(v, ((0, pm), (0, pn)))
    return v


def fake_quant_fwd(v2d, s, qmin: float, qmax: float,
                   block=DEFAULT_BLOCK, interpret: bool = False):
    """v2d: (M, N) f32; s: scalar f32. Returns quant-dequant of v2d."""
    M, N = v2d.shape
    bm, bn = min(block[0], M), min(block[1], N)
    vp = _pad2d(v2d, bm, bn)
    Mp, Np = vp.shape
    grid = (Mp // bm, Np // bn)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, qmin=qmin, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), v2d.dtype),
        interpret=interpret,
    )(vp, s.reshape(1, 1))
    return out[:M, :N]


def fake_quant_bwd(v2d, s, g2d, qmin: float, qmax: float,
                   block=DEFAULT_BLOCK, interpret: bool = False):
    """Returns (dv (M,N), ds_partials (grid_m, grid_n))."""
    M, N = v2d.shape
    bm, bn = min(block[0], M), min(block[1], N)
    vp, gp = _pad2d(v2d, bm, bn), _pad2d(g2d, bm, bn)
    Mp, Np = vp.shape
    grid = (Mp // bm, Np // bn)
    dv, ds = pl.pallas_call(
        functools.partial(_bwd_kernel, qmin=qmin, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np), v2d.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(vp, s.reshape(1, 1), gp)
    return dv[:M, :N], ds
