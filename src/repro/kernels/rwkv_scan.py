"""Chunked RWKV6 wkv recurrence as a Pallas kernel (TPU target).

The wkv recurrence is the sequential hot loop of the rwkv6 arch — the one
assigned architecture whose core compute is NOT a plain matmul. The pure-JAX
chunked form (repro.models.recurrent.wkv_chunked) materializes a
(B, H, T, T, hd) decay tensor per chunk in HBM; this kernel keeps everything
for one (batch*head, chunk) tile in VMEM:

  grid = (B*H parallel, n_chunks sequential)
  state (hd, hd) f32 lives in a VMEM scratch that persists across the
  sequential chunk dimension — the TPU-idiomatic replacement for a
  carried-scan in HBM.

Math identical to wkv_chunked (exponents of non-positive numbers only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_ref, *, chunk):
    T = chunk

    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)        # (T, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)        # (1, hd) broadcast row
    S0 = s_ref[...]                         # (hd, hd)

    L = jnp.cumsum(lw, axis=0)              # inclusive
    Lx = L - lw                             # exclusive

    # inter-chunk contribution
    r_in = r * jnp.exp(Lx)
    y = jnp.dot(r_in, S0, preferred_element_type=jnp.float32)

    # intra-chunk strict-causal pairs (exponents <= 0 by construction)
    expo = Lx[:, None, :] - L[None, :, :]               # (t, tau, hd)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (T, T), 1))
    dec = jnp.exp(jnp.minimum(expo, 0.0)) * tri[..., None]
    A = jnp.einsum("ti,tsi,si->ts", r, dec, k)          # (T, T)
    y += jnp.dot(A, v, preferred_element_type=jnp.float32)

    # bonus diagonal
    y += jnp.sum(r * (u * k), axis=-1, keepdims=True) * v

    # state update
    LT = L[-1:]                                          # (1, hd)
    k_dec = k * jnp.exp(LT - L)
    s_ref[...] = jnp.exp(LT).T * S0 + jnp.dot(
        k_dec.T, v, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


def wkv_pallas(r, k, v, log_w, u, chunk: int = DEFAULT_CHUNK,
               interpret: bool = False):
    """r/k/v/log_w: (B, S, H, hd); u: (H, hd). Returns y (B, S, H, hd) f32.

    Zero initial state (training/prefill-from-scratch semantics; carried
    state across calls is handled by the pure-JAX wrapper in models).
    """
    B, S, H, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    rb, kb, vb, lwb = map(to_bh, (r, k, v, log_w))
    ub = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    y = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rb, kb, vb, lwb, ub)
    return y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
