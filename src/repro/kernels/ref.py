"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.recurrent import wkv_scan_ref as _wkv_scan_ref


def fake_quant_ref(v, s, qmin: float, qmax: float):
    """Eq. 1 forward: round(clip(v/s, qmin, qmax)) * s (no STE plumbing)."""
    s = jnp.maximum(s.astype(v.dtype), 1e-9)
    return jnp.round(jnp.clip(v / s, qmin, qmax)) * s


def fake_quant_grads_ref(v, s, g, qmin: float, qmax: float):
    """LSQ backward: (dv, ds) per Esser et al. — the oracle for the fused
    backward kernel (and cross-checked against jax.grad of the core STE
    composition in tests)."""
    s = jnp.maximum(s.astype(jnp.float32), 1e-9)
    vs = v.astype(jnp.float32) / s
    inside = (vs > qmin) & (vs < qmax)
    dv = jnp.where(inside, g, 0.0)
    dsd = jnp.where(inside, jnp.round(jnp.clip(vs, qmin, qmax)) - vs,
                    jnp.clip(vs, qmin, qmax))
    ds = jnp.sum(g.astype(jnp.float32) * dsd)
    return dv.astype(v.dtype), ds


def quant_matmul_ref(x_q, w_q, s_x, s_w):
    """(q_x s_x) @ (q_w s_w) in f32 via int32 accumulation."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (s_x * s_w)


def wkv_ref(r, k, v, log_w, u):
    """Step-by-step wkv recurrence from zero state (f32)."""
    B, S, H, hd = r.shape
    state = jnp.zeros((B, H, hd, hd), jnp.float32)
    y, _ = _wkv_scan_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), log_w.astype(jnp.float32),
                         u.astype(jnp.float32), state)
    return y
