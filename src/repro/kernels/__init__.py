"""Pallas TPU kernels for the quantization hot spots.

  fake_quant   — fused Eq.-1 quantize-dequantize + LSQ backward (VPU tiles)
  quant_matmul — int8 x int8 -> int32 MXU matmul, scale epilogue in VMEM
  rwkv_scan    — chunked RWKV6 wkv recurrence, state resident in VMEM

`ops` holds the jitted public wrappers (interpret=True on CPU), `ref` the
pure-jnp oracles that tests assert against.
"""
from repro.kernels import ops, ref  # noqa: F401
