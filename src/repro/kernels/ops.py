"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) kernels run with interpret=True — the kernel body
executes in Python, validating the exact TPU program logic. On a real TPU
backend `interpret` flips to False automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fake_quant as _fq
from repro.kernels import quant_matmul as _qmm
from repro.kernels import rwkv_scan as _wkv


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# fake_quant with LSQ custom_vjp
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def fake_quant(v, s, qmin: float, qmax: float, grad_scale: float = 1.0):
    """Fused LSQ fake-quant on an arbitrary-shape tensor (flattened 2D)."""
    return _fq_fwd(v, s, qmin, qmax, grad_scale)[0]


def _as2d(v):
    if v.ndim == 1:
        return v.reshape(1, -1)
    return v.reshape(-1, v.shape[-1])


def _fq_fwd(v, s, qmin, qmax, grad_scale):
    out2d = _fq.fake_quant_fwd(_as2d(v), s.astype(jnp.float32), qmin, qmax,
                               interpret=_interpret_default())
    return out2d.reshape(v.shape), (v, s)


def _fq_bwd(qmin, qmax, grad_scale, res, g):
    v, s = res
    dv2d, ds_part = _fq.fake_quant_bwd(_as2d(v), s.astype(jnp.float32),
                                       _as2d(g), qmin, qmax,
                                       interpret=_interpret_default())
    ds = jnp.sum(ds_part) * grad_scale
    return dv2d.reshape(v.shape), ds.astype(s.dtype)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------
def quant_matmul(x_q, w_q, s_x, s_w, blocks=_qmm.DEFAULT_BLOCKS):
    """(M,K) int8 x (K,N) int8 -> (M,N) f32 with per-tensor scale epilogue."""
    return _qmm.quant_matmul(x_q, w_q, jnp.asarray(s_x, jnp.float32),
                             jnp.asarray(s_w, jnp.float32), blocks=blocks,
                             interpret=_interpret_default())


def quant_matmul_w4(x_q, w_p, s_x, s_w, *, k=None, blocks=_qmm.DEFAULT_BLOCKS):
    """(M,K) int8 x nib4-packed (K/2,N) uint8 int4 weights -> (M,N) f32.
    The weight nibbles unpack in the kernel's VMEM prologue."""
    return _qmm.quant_matmul_w4(x_q, w_p, jnp.asarray(s_x, jnp.float32),
                                jnp.asarray(s_w, jnp.float32), k=k,
                                blocks=blocks,
                                interpret=_interpret_default())


def quantize_int8(v, s, bits: int = 8):
    """Round v/s to the signed `bits`-wide integer grid, stored as int8."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    return jnp.clip(jnp.round(v / s), qmin, qmax).astype(jnp.int8)


# ---------------------------------------------------------------------------
# fused int8 decode attention
# ---------------------------------------------------------------------------
def decode_attn_quant(q, k_codes, k_scale, v_codes, v_scale, pos_arr, q_pos,
                      *, window=None, interpret=None):
    """One-token decode attention directly on int8 KV codes + f32 scales
    (no HBM-resident dequantized cache). ``interpret=None`` follows the
    backend; the ``fused-interpret`` dispatch route pins it True."""
    from repro.kernels import quant_attention as _qa
    if interpret is None:
        interpret = _interpret_default()
    return _qa.decode_attn_quant(q, k_codes, k_scale, v_codes, v_scale,
                                 pos_arr, q_pos, window=window,
                                 interpret=interpret)


def decode_attn_quant_paged(q, k_pages, k_scale, v_pages, v_scale, page_pos,
                            page_table, q_pos, *, window=None,
                            interpret=None):
    """One-token decode attention over the paged int8 KV layout: the page
    table rides in as a scalar-prefetch operand and blocks gather by page
    index (see ``kernels.quant_attention.decode_attn_quant_paged``)."""
    from repro.kernels import quant_attention as _qa
    if interpret is None:
        interpret = _interpret_default()
    return _qa.decode_attn_quant_paged(q, k_pages, k_scale, v_pages, v_scale,
                                       page_pos, page_table, q_pos,
                                       window=window, interpret=interpret)


def verify_attn_quant(q, k_codes, k_scale, v_codes, v_scale, pos_arr, q_pos,
                      *, window=None, interpret=None):
    """S-token speculative-verify attention on int8 KV codes: unrolled onto
    the exact one-token kernel program per query position (see
    ``kernels.quant_attention.verify_attn_quant`` for why the unroll is
    the bitwise-identity contract)."""
    from repro.kernels import quant_attention as _qa
    if interpret is None:
        interpret = _interpret_default()
    return _qa.verify_attn_quant(q, k_codes, k_scale, v_codes, v_scale,
                                 pos_arr, q_pos, window=window,
                                 interpret=interpret)


def verify_attn_quant_paged(q, k_pages, k_scale, v_pages, v_scale, page_pos,
                            page_table, q_pos, *, window=None,
                            interpret=None):
    """S-token speculative-verify attention over the paged int8 KV layout
    (``kernels.quant_attention.verify_attn_quant_paged``)."""
    from repro.kernels import quant_attention as _qa
    if interpret is None:
        interpret = _interpret_default()
    return _qa.verify_attn_quant_paged(q, k_pages, k_scale, v_pages, v_scale,
                                       page_pos, page_table, q_pos,
                                       window=window, interpret=interpret)


# ---------------------------------------------------------------------------
# rwkv wkv
# ---------------------------------------------------------------------------
def wkv(r, k, v, log_w, u, chunk: int = _wkv.DEFAULT_CHUNK):
    """Chunked wkv recurrence from zero state. (B,S,H,hd) -> (B,S,H,hd) f32."""
    return _wkv.wkv_pallas(r, k, v, log_w, u, chunk=chunk,
                           interpret=_interpret_default())


# ---------------------------------------------------------------------------
# flash attention forward
# ---------------------------------------------------------------------------
def flash_fwd(q, k, v, *, causal: bool, window=None, q_block: int = 512,
              kv_block: int = 512):
    """Online-softmax attention forward with VMEM-resident state.
    q: (B,S,KV,G,hd) pre-scaled; returns (out, lse)."""
    from repro.kernels import flash_attention as _fa
    return _fa.flash_fwd_pallas(q, k, v, causal=causal, window=window,
                                q_block=q_block, kv_block=kv_block,
                                interpret=_interpret_default())
