"""Fused int8 decode-attention Pallas kernel (TPU target, interpret-validated).

The serving engine's int8 KV cache stores codes + per-row per-head f32
scales, but until this kernel the decode step dequantized the *whole* ring
buffer to fp in HBM before attending (``models.attention`` dequant path) —
decode-attention HBM traffic stayed bf16/f32-sized and the ``kv_bits=8``
roofline term was storage-only. Here the codes are the kernel operands:

* K codes (int8) load straight from the cache ring buffer into VMEM; the
  logits compute as ``(q . k_codes) * k_scale`` — the K-scale folds into
  the logit columns *after* the dot, so the MXU/VPU contraction runs on the
  raw codes and HBM never holds a dequantized K row.
* V codes likewise: the PV accumulation is ``(p * v_scale) @ v_codes`` —
  the V-scale rides the probability row into the second dot.
* Masking is position-driven, exactly the dequant reference's inventory:
  a slot attends iff ``0 <= slot_pos <= q_pos`` (and, for sliding-window
  archs, ``q_pos - slot_pos < window``). Ring wraparound therefore needs
  no special handling — slots carry absolute positions, order never
  matters — and evicted slots (``pos == -1``) mask out wherever they sit.
* GQA: the grid runs one program per (batch row, kv head); its q block is
  the (G, hd) group sharing that head, so K/V blocks are fetched once per
  group (same layout trick as ``kernels.flash_attention``).

Softmax state (m, l, acc) lives in VMEM scratch across the sequential kv
grid dimension (online softmax), so capacities larger than one kv block
stream block-by-block. Numerics: logits/probs/PV all accumulate in f32;
the result matches the dequant reference to fp-rounding (scale folding
reassociates one multiply), which preserves greedy-argmax tokens — the
contract the serve smoke and ``benchmarks/quant_serve_bench.py`` gate.

A zero KV row quantizes to codes 0 with the ``KV_SCALE_EPS`` floor scale;
its logit here is ``(q . 0) * eps = 0`` *exactly*, bit-identical to the
reference's ``q . (0 * eps) = 0`` — no ``0 * eps^-1`` term ever forms
because the kernel multiplies by the scale, never divides.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30
DEFAULT_KV_BLOCK = 256


def _qdec_kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, pos_ref, qp_ref,
                 o_ref, m_ref, l_ref, acc_ref, *, n_kv, window):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (G, hd) f32, pre-scaled
    kc = k_ref[0].astype(jnp.float32)              # (kvb, hd) from int8 codes
    ks = ks_ref[0]                                 # (kvb,) f32 row scales
    kpos = pos_ref[0]                              # (kvb,) int32 abs position
    qp = qp_ref[0, 0]                              # scalar int32 query pos

    # contraction on the CODES; the K-scale folds into the logit columns in
    # VMEM — a zero row (codes 0, eps-floored scale) lands at exactly 0.0
    logits = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = logits * ks[None, :]
    valid = (kpos >= 0) & (kpos <= qp)
    if window is not None:
        valid &= qp - kpos < window
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[None, :]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])           # (G, kvb)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    # V-scale folds into the probability row; the second dot runs on codes
    pv = jax.lax.dot_general(p * vs_ref[0][None, :],
                             v_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attn_quant(q, k_codes, k_scale, v_codes, v_scale, pos_arr, q_pos,
                      *, window: Optional[int] = None,
                      kv_block: int = DEFAULT_KV_BLOCK,
                      interpret: bool = False):
    """One-token decode attention directly on int8 KV codes.

    q: (B, 1, H, hd) fp queries; k/v_codes: (B, Sc, KV, hd) int8;
    k/v_scale: (B, Sc, KV) f32 per-row per-head write-time scales;
    pos_arr: (B, Sc) int32 absolute slot positions (-1 = empty);
    q_pos: (B,) int32 per-row query positions. The shared-position cache
    layout broadcasts its ``(Sc,)`` pos / scalar q_pos before calling.
    Returns (B, 1, H, hd) f32.

    Rows whose slots are ALL masked softmax uniformly (the engine discards
    inactive-slot output); note the uniform mean then includes kv-block
    padding slots, so such rows are finite but not comparable against the
    unpadded reference — same contract as the engine's.
    """
    B, Sc, KV, hd = k_codes.shape
    H = q.shape[2]
    G = H // KV
    assert H == KV * G and q.shape[1] == 1, (q.shape, k_codes.shape)

    qf = (q.reshape(B, KV, G, hd).astype(jnp.float32) * (hd ** -0.5))
    qf = qf.reshape(B * KV, G, hd)
    kf = k_codes.transpose(0, 2, 1, 3).reshape(B * KV, Sc, hd)
    vf = v_codes.transpose(0, 2, 1, 3).reshape(B * KV, Sc, hd)
    ks = k_scale.transpose(0, 2, 1).reshape(B * KV, Sc).astype(jnp.float32)
    vs = v_scale.transpose(0, 2, 1).reshape(B * KV, Sc).astype(jnp.float32)
    pos2 = jnp.asarray(pos_arr, jnp.int32)
    qp = jnp.asarray(q_pos, jnp.int32).reshape(B, 1)

    kvb = min(kv_block, Sc)
    pad = (-Sc) % kvb
    if pad:
        # padded slots carry pos -1: masked exactly like evicted slots
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, pad)))
        vs = jnp.pad(vs, ((0, 0), (0, pad)))
        pos2 = jnp.pad(pos2, ((0, 0), (0, pad)), constant_values=-1)
    n_kv = (Sc + pad) // kvb

    out = pl.pallas_call(
        functools.partial(_qdec_kernel, n_kv=n_kv, window=window),
        grid=(B * KV, n_kv),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, kvb, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, kvb), lambda b, j: (b, j)),
            pl.BlockSpec((1, kvb, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, kvb), lambda b, j: (b, j)),
            pl.BlockSpec((1, kvb), lambda b, j, KV=KV: (b // KV, j)),
            pl.BlockSpec((1, 1), lambda b, j, KV=KV: (b // KV, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, ks, vf, vs, pos2, qp)

    return out.reshape(B, KV, G, hd).reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# paged variant: gather-by-page-index via scalar-prefetched page table
# ---------------------------------------------------------------------------
def _qdec_paged_kernel(tbl_ref, qp_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                       pos_ref, o_ref, m_ref, l_ref, acc_ref, *, n_blocks,
                       kv_heads, window):
    p = pl.program_id(0)
    j = pl.program_id(1)
    b = p // kv_heads

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (G, hd) f32, pre-scaled
    kc = k_ref[0, 0].astype(jnp.float32)           # (ps, hd) from int8 codes
    ks = ks_ref[0, 0]                              # (ps,) f32 row scales
    kpos = pos_ref[0]                              # (ps,) int32 abs position
    qp = qp_ref[b]                                 # scalar int32 query pos

    logits = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits = logits * ks[None, :]
    # an unmapped table entry (-1) aliased to physical page 0 by the index
    # map's clip — mask the whole block so it contributes exact zeros
    valid = (tbl_ref[b, j] >= 0) & (kpos >= 0) & (kpos <= qp)
    if window is not None:
        valid &= qp - kpos < window
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[None, :]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p_blk = jnp.exp(logits - m_new[:, None])       # (G, ps)
    l_ref[...] = l_ref[...] * alpha + p_blk.sum(axis=-1)
    pv = jax.lax.dot_general(p_blk * vs_ref[0, 0][None, :],
                             v_ref[0, 0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attn_quant_paged(q, k_pages, k_scale, v_pages, v_scale, page_pos,
                            page_table, q_pos, *, window: Optional[int] = None,
                            interpret: bool = False):
    """One-token decode attention over the paged int8 KV layout.

    Same online-softmax body as :func:`decode_attn_quant`, but the kv grid
    dimension walks each slot's *page list* instead of a dense ring: the
    page table and query positions ride in as scalar-prefetch operands
    (``pltpu.PrefetchScalarGridSpec``), and the K/V/scale/pos block index
    maps read ``page_table[slot, j]`` to point block ``j`` at its physical
    page — the gather happens in the block fetch, and HBM never holds a
    densely gathered per-slot cache.

    q: (B, 1, H, hd) fp queries; k/v_pages: (n_pages, ps, KV, hd) int8;
    k/v_scale: (n_pages, ps, KV) f32; page_pos: (n_pages, ps) int32
    absolute positions (-1 = empty row); page_table: (B, P) int32 physical
    page per logical block (-1 = unmapped: its block masks out entirely);
    q_pos: (B,) int32. Returns (B, 1, H, hd) f32.
    """
    n_pages, ps, KV, hd = k_pages.shape
    B, P = page_table.shape
    H = q.shape[2]
    G = H // KV
    assert H == KV * G and q.shape[1] == 1, (q.shape, k_pages.shape)

    qf = (q.reshape(B, KV, G, hd).astype(jnp.float32) * (hd ** -0.5))
    qf = qf.reshape(B * KV, G, hd)
    kf = k_pages.transpose(0, 2, 1, 3)             # (n_pages, KV, ps, hd)
    vf = v_pages.transpose(0, 2, 1, 3)
    ks = k_scale.transpose(0, 2, 1).astype(jnp.float32)   # (n_pages, KV, ps)
    vs = v_scale.transpose(0, 2, 1).astype(jnp.float32)
    tbl = jnp.asarray(page_table, jnp.int32)
    qp = jnp.asarray(q_pos, jnp.int32)
    pos = jnp.asarray(page_pos, jnp.int32)

    def page_of(p, j, tbl_ref):
        # clip unmapped (-1) to physical page 0; the kernel masks the block
        return jnp.maximum(tbl_ref[p // KV, j], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * KV, P),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda p, j, tbl, qp: (p, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda p, j, tbl, qp: (page_of(p, j, tbl),
                                                p % KV, 0, 0)),
            pl.BlockSpec((1, 1, ps),
                         lambda p, j, tbl, qp: (page_of(p, j, tbl),
                                                p % KV, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda p, j, tbl, qp: (page_of(p, j, tbl),
                                                p % KV, 0, 0)),
            pl.BlockSpec((1, 1, ps),
                         lambda p, j, tbl, qp: (page_of(p, j, tbl),
                                                p % KV, 0)),
            pl.BlockSpec((1, ps),
                         lambda p, j, tbl, qp: (page_of(p, j, tbl), 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda p, j, tbl, qp: (p, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_qdec_paged_kernel, n_blocks=P, kv_heads=KV,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tbl, qp, qf, kf, ks, vf, vs, pos)

    return out.reshape(B, KV, G, hd).reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# multi-token verify (self-speculative decoding)
# ---------------------------------------------------------------------------
def verify_attn_quant(q, k_codes, k_scale, v_codes, v_scale, pos_arr, q_pos,
                      *, window: Optional[int] = None,
                      kv_block: int = DEFAULT_KV_BLOCK,
                      interpret: bool = False):
    """S-token verify attention on int8 KV codes (ring layout).

    ``q (B, S, H, hd)``, ``q_pos (B, S)`` — the speculative verify step
    attends the current token plus the k draft proposals in one launch,
    each query masking by its own absolute position.

    Deliberately UNROLLED over the ``S`` query positions, each reusing the
    EXACT one-token :func:`decode_attn_quant` kernel program (same block
    shapes, same grid, same accumulation order). A true multi-query q
    block would be fewer programs, but changing the operand shapes can
    change tiling — and with it the fp accumulation order — which would
    break the bitwise contract that makes speculative decode KV- and
    token-identical to token-at-a-time decode. ``S = k + 1`` is small and
    static, so the unroll stays one jit launch with S kernel calls.
    """
    outs = [
        decode_attn_quant(q[:, j:j + 1], k_codes, k_scale, v_codes, v_scale,
                          pos_arr, q_pos[:, j], window=window,
                          kv_block=kv_block, interpret=interpret)
        for j in range(q.shape[1])
    ]
    return jnp.concatenate(outs, axis=1)


def verify_attn_quant_paged(q, k_pages, k_scale, v_pages, v_scale, page_pos,
                            page_table, q_pos, *,
                            window: Optional[int] = None,
                            interpret: bool = False):
    """S-token verify attention over the paged int8 KV layout: the paged
    counterpart of :func:`verify_attn_quant`, unrolled over the S query
    positions onto the exact :func:`decode_attn_quant_paged` program for
    the same bitwise-identity reason (see there). ``q (B, S, H, hd)``,
    ``q_pos (B, S)``; rejected-draft rows already written to the pages
    mask out per query position exactly like future rows."""
    outs = [
        decode_attn_quant_paged(q[:, j:j + 1], k_pages, k_scale, v_pages,
                                v_scale, page_pos, page_table, q_pos[:, j],
                                window=window, interpret=interpret)
        for j in range(q.shape[1])
    ]
    return jnp.concatenate(outs, axis=1)
