"""Version-compat shims for the pallas TPU API surface."""
from jax.experimental.pallas import tpu as pltpu

# jax<=0.4.x names it TPUCompilerParams; >=0.5 renamed to CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:                       # fail fast, at import
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; update repro.kernels._compat for this jax version")
