"""Flash-attention forward Pallas kernel (TPU target, interpret-validated).

The §Perf analysis (EXPERIMENTS.md) shows the optimized attention cells are
bound by per-block probability tiles streaming through HBM — an artifact of
the XLA-only lowering. This kernel is the TPU-native fix: the online-softmax
state (m, l, acc) and the (qb, kvb) probability tile live in VMEM scratch
across the sequential kv grid dimension; HBM sees only q/k/v in and
(out, lse) back.

GQA layout: q rows are (B*KV*G); k/v rows are (B*KV) — the index map folds
the group dim (bh // G) so kv blocks are fetched once per group.

The backward pairs this forward with the recompute-based custom-VJP in
`models/attention.py` (same residuals: out + lse), so training uses the
kernel's forward on TPU with no extra plumbing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30
DEFAULT_BLOCKS = (512, 512)      # q_block, kv_block


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
               *, causal, window, q_block, kv_block, n_kv):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = i * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    kpos = j * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)
    valid = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= qpos - kpos < window
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)              # (qb, hd)
    k = k_ref[0].astype(jnp.float32)              # (kvb, hd)
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) + bias

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])          # (qb, kvb) — VMEM only
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l)).astype(lse_ref.dtype)


def flash_fwd_pallas(q, k, v, *, causal: bool, window=None,
                     q_block: int = DEFAULT_BLOCKS[0],
                     kv_block: int = DEFAULT_BLOCKS[1],
                     interpret: bool = False):
    """q: (B, S, KV, G, hd) pre-scaled; k/v: (B, S, KV, hd).
    Returns (out (B,S,KV,G,hd) f32, lse (B,KV,G,S) f32)."""
    B, S, KV, G, hd = q.shape
    assert S % q_block == 0 and S % kv_block == 0, (S, q_block, kv_block)
    nqb, nkv = S // q_block, S // kv_block
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    out, lse = pl.pallas_call(
        functools.partial(_fa_kernel, causal=causal, window=window,
                          q_block=q_block, kv_block=kv_block, n_kv=nkv),
        grid=(B * KV * G, nqb, nkv),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KV * G, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * KV * G, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4)
    lse = lse.reshape(B, KV, G, S)
    return out, lse
