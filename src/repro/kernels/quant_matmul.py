"""Int8 quantized matmul Pallas kernel with scale epilogue (TPU MXU target).

The serving-time execution of a searched policy: weights are pre-quantized
to the int8 grid (any searched bit-width b <= 8 lands on a subset of int8
codes), activations quantize on the fly, and the matmul runs int8 x int8 ->
int32 on the MXU — the TPU analog of the paper's low-bit GPU inference.
The epilogue applies `s_x * s_w` in VMEM, so HBM sees only int8 operands
and the f32 result.

Grid is (M/bm, N/bn, K/bk) with the K dimension sequential ("arbitrary"):
an f32 VMEM scratch accumulates partial products across K steps and the
epilogue fires on the last step. 128-aligned tiles keep the MXU full.

Numerics contract (tested): out == (q_x * s_x) @ (q_w * s_w) exactly in f32
for shapes where K * 127^2 < 2^31 (int32 accumulation, always true here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (256, 256, 512)     # bm, bn, bk


def _qmm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        scale = sx_ref[0, 0] * sw_ref[0, 0]
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def _qmm_w4_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, k_steps):
    """int8 x packed-int4 matmul: the weight block arrives as nib4 bytes
    (two K-rows per byte, offset-binary q+8) and unpacks in the VMEM
    prologue — HBM traffic for the weight is half the int8 kernel's."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wp = w_ref[...].astype(jnp.int32)            # (bk//2, bn) nib4 bytes
    lo = (wp & 0xF) - 8
    hi = (wp >> 4) - 8
    bk2, bn = wp.shape
    w = jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn).astype(jnp.int8)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        scale = sx_ref[0, 0] * sw_ref[0, 0]
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def quant_matmul_w4(x_q, w_p, s_x, s_w, *, k=None, blocks=DEFAULT_BLOCKS,
                    interpret: bool = False):
    """x_q: (M, K) int8; w_p: (K/2, N) uint8 nib4-packed int4 codes
    (``runtime.packing.pack_nib4`` layout); scalar scales -> (M, N) f32.

    ``k`` is the true contraction length (defaults to 2 * w_p.shape[0]);
    x_q columns beyond ``k`` must be absent. K must be even — odd
    contraction dims take the dequant-fp dispatch fallback.
    """
    M, K = x_q.shape
    K2, N = w_p.shape
    k = K if k is None else k
    assert k == K == 2 * K2, (x_q.shape, w_p.shape, k)
    bm, bn, bk = (min(blocks[0], M), min(blocks[1], N), min(blocks[2], K))
    bk += bk % 2
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x_q = jnp.pad(x_q, ((0, pm), (0, pk)))   # zero codes: null products
    if pk or pn:
        # pad bytes are 0x88 = two offset-binary zeros (plain 0x00 would
        # decode to q = -8 rows; harmless only because x pads are zero —
        # keep the buffer self-consistent anyway)
        w_p = jnp.pad(w_p, ((0, pk // 2), (0, pn)), constant_values=0x88)
    Mp, Kp = x_q.shape
    Np = w_p.shape[1]
    k_steps = Kp // bk
    grid = (Mp // bm, Np // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_qmm_w4_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_p, s_x.reshape(1, 1), s_w.reshape(1, 1))
    return out[:M, :N]


def quant_matmul(x_q, w_q, s_x, s_w, blocks=DEFAULT_BLOCKS,
                 interpret: bool = False):
    """x_q: (M, K) int8; w_q: (K, N) int8; s_x/s_w scalar f32 -> (M, N) f32."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    bm, bn, bk = (min(blocks[0], M), min(blocks[1], N), min(blocks[2], K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x_q = jnp.pad(x_q, ((0, pm), (0, pk)))
    if pk or pn:
        w_q = jnp.pad(w_q, ((0, pk), (0, pn)))
    Mp, Kp = x_q.shape
    Np = w_q.shape[1]
    k_steps = Kp // bk
    grid = (Mp // bm, Np // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, s_x.reshape(1, 1), s_w.reshape(1, 1))
    return out[:M, :N]
