"""Minimal optax-style optimizers (optax is not available offline).

An ``Optimizer`` is (init, update); ``update`` maps (grads, state, params)
-> (updates, state) where updates are ADDED to params. Provided:

  * ``sgd`` (momentum), ``adamw`` (decoupled weight decay, f32 master)
  * ``cosine_warmup`` schedule
  * ``clip_by_global_norm`` gradient transform
  * ``masked`` — freeze subsets of the tree (paper §3.4's freeze-backbone
    indicator training; also embedding-frozen finetune ablations)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Schedule = Callable[[Array], Array]      # step -> lr


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                  end_frac: float = 0.0) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = end_frac + (1 - end_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)
    return sched


# ---------------------------------------------------------------------------
# global-norm clipping
# ---------------------------------------------------------------------------
def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: l * scale.astype(l.dtype), tree), g


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
class SGDState(NamedTuple):
    step: Array
    momentum: Any


def sgd(lr: Schedule | float, momentum: float = 0.9,
        clip_norm: Optional[float] = None) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
        lr_t = sched(state.step)
        updates = jax.tree.map(lambda m: -lr_t.astype(m.dtype) * m, mom)
        return updates, SGDState(state.step + 1, mom)

    return Optimizer(init, update)


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def adamw(lr: Schedule | float, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = None,
          wd_mask: Optional[Callable] = None) -> Optimizer:
    """AdamW with decoupled weight decay. `wd_mask(path, leaf) -> bool`
    selects which leaves decay (default: every leaf with ndim >= 2)."""
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        zeros = lambda p: jax.tree.map(
            lambda l: jnp.zeros(l.shape, jnp.float32), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        t = state.step + 1
        tf = t.astype(jnp.float32)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, g32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, g32)
        lr_t = sched(state.step)

        def upd(path, m_, v_, p):
            u = -(lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            decay = (wd_mask(path, p) if wd_mask is not None else p.ndim >= 2)
            if weight_decay and decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map_with_path(upd, m, v, params)
        return updates, AdamWState(t, m, v)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# masking / application
# ---------------------------------------------------------------------------
def path_str(path) -> str:
    """'body/0/wq/s_w'-style string from a tree_map_with_path key path."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def indicator_only_mask(path, leaf) -> bool:
    """Trainable = the per-bit indicator banks (scale factors) only."""
    p = path_str(path)
    return p.endswith("s_w") or p.endswith("s_a")


def masked(opt: Optimizer, trainable: Callable) -> Optimizer:
    """Zero updates (and skip state) for leaves where trainable() is False."""

    def init(params):
        return opt.init(params)

    def update(grads, state, params):
        grads = jax.tree_util.tree_map_with_path(
            lambda path, g: g if trainable(path, g) else jnp.zeros_like(g),
            grads)
        updates, state = opt.update(grads, state, params)
        updates = jax.tree_util.tree_map_with_path(
            lambda path, u: u if trainable(path, u) else jnp.zeros_like(u),
            updates)
        return updates, state

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
