"""Config system: model architecture configs and input-shape specs.

Every assigned architecture is expressed as a ``ModelConfig``. The model code
(`repro.models.lm`) is driven entirely by this dataclass — adding an arch means
adding a config file, not model code.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Bit-width options searched by the paper (first/last layers pinned to 8).
DEFAULT_BITS: Tuple[int, ...] = (2, 3, 4, 5, 6)
PINNED_BITS: int = 8


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0          # routed experts
    top_k: int = 0
    n_shared: int = 0           # always-on shared experts (deepseek-moe)
    d_ff: int = 0               # per-expert hidden dim
    first_dense_layers: int = 0  # leading layers that stay dense
    dense_d_ff: int = 0         # d_ff used by those dense layers


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # positional / attention flavour
    rope_theta: float = 10000.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA window (None = full attention)
    causal: bool = True                    # False for encoder-only
    # MLP flavour
    mlp_gated: bool = True       # llama-style gate*up; False -> plain 2-matmul
    act: str = "silu"            # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    norm_type: str = "rms"       # rms | ln (hubert/w2v2 use LayerNorm)
    # MoE
    moe: Optional[MoEConfig] = None
    # VLM: insert a cross-attention block after every `cross_attn_every`-th
    # self-attention layer (mllama: 8 extra cross blocks for 40 self layers).
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    # audio (encoder-only, stub frontend provides frame embeddings)
    encoder_only: bool = False
    frontend: str = "none"       # none | audio_stub | vision_stub
    # ssm / hybrid
    block_pattern: Tuple[str, ...] = ("attn",)   # repeated; e.g. (rec,rec,attn)
    local_window: int = 0        # recurrentgemma local-attn window
    lru_width: int = 0           # RG-LRU state width (0 -> d_model)
    conv1d_width: int = 4        # temporal conv width in recurrent block
    rwkv_head_dim: int = 64
    # quantization
    bits: Tuple[int, ...] = DEFAULT_BITS
    quant_act_signed: bool = True   # LM activations are signed (DESIGN.md §8)
    # misc
    max_seq_len: int = 524288
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def n_bits(self) -> int:
        return len(self.bits)

    @property
    def is_subquadratic(self) -> bool:
        """True when a 500k-token context is feasible (skip rule for long_500k)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Skip rules from DESIGN.md §5. Returns (applicable, reason_if_not)."""
    if cfg.encoder_only and shape.is_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "pure full-attention arch; 500k context needs sub-quadratic attention"
    return True, ""
