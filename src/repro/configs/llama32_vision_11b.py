"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].
Text backbone: 40 self-attn layers; an extra cross-attention block (with its
own gated MLP, mllama-style) after every 5th self layer -> 8 cross blocks.
Vision frontend is a STUB: input_specs() supplies precomputed patch
embeddings (B, n_image_tokens, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
    mlp_gated=True,
    act="silu",
    cross_attn_every=5,
    n_image_tokens=1600,
    frontend="vision_stub",
)
