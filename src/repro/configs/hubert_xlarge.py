"""HuBERT-XLarge [arXiv:2106.07447; unverified]. Encoder-only (bidirectional)
transformer, MHA, plain-gelu MLP. The conv waveform frontend is a STUB:
input_specs() supplies precomputed frame embeddings. vocab=504 is the
masked-unit prediction codebook. No decode step (encoder-only)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    encoder_only=True,
    mlp_gated=False,
    act="gelu",
    norm_type="ln",
    frontend="audio_stub",
)
