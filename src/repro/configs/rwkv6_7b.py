"""RWKV6-World-7B 'Finch' [arXiv:2404.05892; hf]. Attention-free: per-layer
time-mix (data-dependent decay wkv recurrence, 64 heads of dim 64) +
channel-mix (d_ff = 3.5x d_model). O(1) decode state -> long_500k applicable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    mlp_gated=False,       # channel-mix is its own structure
    act="relu2",
)
