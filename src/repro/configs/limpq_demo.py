"""Paper-representative demo config: a small LM whose layer mix (cheap
narrow projections vs wide MLP matmuls) mirrors the paper's DW-vs-PW-conv
sensitivity contrast. Used by examples/ and benchmarks/ for end-to-end
importance training + ILP search + QAT finetune on CPU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="limpq-demo",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=1024,
    vocab=512,
    mlp_gated=True,
    act="silu",
    max_seq_len=512,
)
