"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf]. 26 layers, repeating
(rec, rec, attn): RG-LRU recurrent blocks with temporal conv1d(4), 1 local
(window 2048) MQA attention per 2 recurrent. Gated-gelu MLP, tied embeddings.
Sub-quadratic -> long_500k applicable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=2560,
    conv1d_width=4,
    mlp_gated=True,
    act="gelu",
    tie_embeddings=True,
    norm_eps=1e-6,
    notes="10 heads do not divide the 16-way model axis; local attention "
          "falls back to batch-sharded compute.",
)
