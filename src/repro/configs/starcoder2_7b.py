"""StarCoder2-7B [arXiv:2402.19173; hf]. GQA(kv=4), RoPE, plain-gelu MLP,
sliding-window attention (4096, per the HF config) -> long_500k applicable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    rope_theta=1e5,
    sliding_window=4096,
    mlp_gated=False,
    act="gelu",
    notes="36 heads do not divide the 16-way model axis; attention falls back "
          "to batch-sharded compute (dist/sharding.py).",
)
