"""Mixtral-8x7B [arXiv:2401.04088; hf]. 8 routed experts top-2, GQA(kv=8),
sliding-window attention (4096) -> long_500k applicable."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,            # per-expert hidden dim
    vocab=32000,
    rope_theta=1e6,
    sliding_window=4096,
    mlp_gated=True,
    act="silu",
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff=14336),
    notes="8 experts do not divide the 16-way model axis; experts use "
          "tensor-parallel d_ff sharding instead of expert parallelism.",
)
