"""DeepSeekMoE-16B [arXiv:2401.06066; hf]. Fine-grained MoE: 64 routed experts
top-6 + 2 shared experts (d_ff 1408 each); first layer dense (d_ff 10944).
MHA (kv=16)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,             # per-routed-expert hidden dim
    vocab=102400,
    rope_theta=1e4,
    mlp_gated=True,
    act="silu",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff=1408,
                  first_dense_layers=1, dense_d_ff=10944),
    notes="64 experts shard 4-per-device over the 16-way model axis (EP).",
)
