"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf]. GQA(kv=8), per-head qk RMS-norm,
head_dim=128 (q_dim 2048 != d_model), tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151936,
    head_dim=128,
    rope_theta=1e6,
    qk_norm=True,
    mlp_gated=True,
    act="silu",
    tie_embeddings=True,
)
