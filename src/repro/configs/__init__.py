"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

Every assigned architecture from the brief plus the paper-representative
demo config. Reduced smoke variants live in ``smoke_config``.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    DEFAULT_BITS,
    PINNED_BITS,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeSpec,
    shape_applicable,
)

from repro.configs import (  # noqa: E402
    deepseek_moe_16b,
    granite_20b,
    hubert_xlarge,
    limpq_demo,
    llama32_vision_11b,
    mixtral_8x7b,
    qwen3_0_6b,
    recurrentgemma_2b,
    rwkv6_7b,
    starcoder2_7b,
    yi_9b,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        starcoder2_7b, yi_9b, qwen3_0_6b, granite_20b, llama32_vision_11b,
        mixtral_8x7b, deepseek_moe_16b, hubert_xlarge, rwkv6_7b,
        recurrentgemma_2b, limpq_demo,
    )
}

ASSIGNED_ARCHS = tuple(n for n in _REGISTRY if n != "limpq-demo")


def list_archs(include_demo: bool = False):
    return tuple(_REGISTRY) if include_demo else ASSIGNED_ARCHS


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def smoke_config(name: str) -> ModelConfig:
    """A drastically reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    overrides = dict(
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        max_seq_len=256,
    )
    # keep the block pattern but shrink depth to one full repeat (>=2 layers)
    overrides["n_layers"] = max(2, len(cfg.block_pattern))
    if cfg.family == "vlm":
        overrides["n_layers"] = cfg.cross_attn_every  # one self-unit + 1 cross
        overrides["n_image_tokens"] = 16
    if cfg.moe is not None:
        overrides["moe"] = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            n_shared=cfg.moe.n_shared,
            d_ff=64,
            first_dense_layers=cfg.moe.first_dense_layers,
            dense_d_ff=128 if cfg.moe.dense_d_ff else 0,
        )
        overrides["n_layers"] = 2 + cfg.moe.first_dense_layers
    if cfg.sliding_window:
        overrides["sliding_window"] = 64
    if cfg.local_window:
        overrides["local_window"] = 64
    if cfg.lru_width:
        overrides["lru_width"] = 128
    if cfg.family == "ssm":   # rwkv: heads = d_model / 64
        overrides["n_heads"] = 128 // cfg.rwkv_head_dim
        overrides["n_kv_heads"] = overrides["n_heads"]
        overrides["head_dim"] = 0
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **overrides)
