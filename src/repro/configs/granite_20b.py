"""Granite-20B-Code [arXiv:2405.04324; hf]. MQA (kv=1), plain-gelu MLP
(param count pins this: gated would give ~28B), RoPE per the 'llama-arch'
note in the assignment (upstream gpt_bigcode uses learned positions; RoPE
avoids a 500k-row table — deviation recorded in DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    rope_theta=1e4,
    mlp_gated=False,
    act="gelu",
)
