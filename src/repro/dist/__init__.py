"""Distributed-execution layer.

Five small modules with one responsibility each:

  axes        — logical-axis bundle (``MeshAxes``) + the ``NO_AXES``
                single-device default every model/step function accepts
  sharding    — per-arch partition rules with divisibility fallbacks
  collectives — gradient compression (int8 + error feedback) and
                shard_map matmul/collective overlap kernels
  hlo         — compiled-HLO cost analyzer (trip-count-scaled flops,
                HBM bytes, collective wire bytes)
  roofline    — three-term (compute / HBM / ICI) step-time model fed by
                ``hlo.analyze`` outputs

The model code never imports a mesh directly: it receives a ``MeshAxes``
and calls ``axes.shard(x, "dp", "sp", None)`` — a no-op under ``NO_AXES``,
a ``with_sharding_constraint`` under a real mesh.
"""
from repro.dist import axes, collectives, hlo, roofline, sharding  # noqa: F401
from repro.dist.axes import NO_AXES, MeshAxes  # noqa: F401
