"""Compressed collectives and matmul/collective overlap kernels.

Gradient compression (cross-pod DCN traffic):
  * ``compress_int8`` — per-tensor symmetric int8 with a single f32 scale
    (s = max|g| / 127), worst-case elementwise error s/2.
  * ``ef_compress_tree`` / ``ef_decompress_tree`` — error-feedback
    compression over a gradient pytree. The residual carries the signal
    the int8 grid dropped, so the conservation invariant
        dequant(q) + new_residual == g + old_residual
    holds exactly (up to f32 rounding) and accumulated compressed
    gradients stay within one quantization step of the true sum.

Overlap kernels (shard_map, portable to any backend with a mesh):
  * ``psum_matmul`` — contraction-sharded matmul + ring all-reduce via
    collective-permute (n-1 ppermute+add steps), the decomposition XLA
    can interleave with neighbouring compute.
  * ``ag_matmul_rotating`` — all-gather matmul: the contraction shards of
    ``x`` rotate around the ring while each device multiplies the chunk
    it currently holds against the matching row block of its local
    output-column shard — the gather is hidden behind the matmuls.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

Tree = Any

INT8_MAX = 127.0


# ---------------------------------------------------------------------------
# int8 compression + error feedback
# ---------------------------------------------------------------------------
def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric quantization. Returns (q int8, scale f32[])."""
    g = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / INT8_MAX, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(g / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads: Tree, residual: Optional[Tree]
                     ) -> Tuple[Tree, Tree, Tree]:
    """Error-feedback compress a gradient pytree.

    residual=None starts from zero. Returns (q_tree, scale_tree,
    new_residual_tree); invariant per leaf:
        decompress(q, s) + new_residual == g + residual.
    """
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    err = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs = jax.tree.map(compress_int8, err)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda e, qq, ss: e - decompress_int8(qq, ss),
                           err, q, s)
    return q, s, new_res


def ef_decompress_tree(q: Tree, s: Tree) -> Tree:
    return jax.tree.map(decompress_int8, q, s)


# ---------------------------------------------------------------------------
# shard_map overlap kernels
# ---------------------------------------------------------------------------
def _ring_allreduce(partial: jax.Array, axis: str, n: int) -> jax.Array:
    """Ring all-reduce via n-1 collective-permute + add steps."""
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = partial
    for _ in range(n - 1):
        acc = jax.lax.ppermute(acc, axis, perm) + partial
    return acc


def psum_matmul(x: jax.Array, w: jax.Array, mesh, axis: str) -> jax.Array:
    """x @ w with the contraction dim sharded over ``axis``.

    Each device multiplies its (cols-of-x, rows-of-w) chunk, then the
    partial products ring-reduce via collective-permute — the ppermute
    chain is overlappable with adjacent compute, unlike a monolithic
    all-reduce.
    """
    n = int(dict(mesh.shape)[axis])

    def body(xl, wl):
        return _ring_allreduce(xl @ wl, axis, n)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, axis), P(axis, None)),
                     out_specs=P(None, None), check_rep=False)(x, w)


def ag_matmul_rotating(x: jax.Array, w: jax.Array, mesh, axis: str) -> jax.Array:
    """x @ w with x contraction-sharded and w output-column-sharded.

    Instead of all-gathering x up front, the x shards rotate around the
    ring; at step t a device holds chunk (idx - t) mod n and multiplies
    it against the matching row block of its local w columns. After n
    steps every device has its full output-column block and the gather
    cost is hidden behind the chunked matmuls.
    """
    n = int(dict(mesh.shape)[axis])
    k = x.shape[-1]
    assert k % n == 0, (k, n)
    chunk = k // n
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(xl, wl):
        # xl: (M, k/n) — this device's contraction chunk
        # wl: (k, N/n) — all contraction rows of the local output columns
        idx = jax.lax.axis_index(axis)
        out = jnp.zeros((xl.shape[0], wl.shape[1]), jnp.float32)
        for t in range(n):
            chunk_id = (idx - t) % n
            w_rows = jax.lax.dynamic_slice_in_dim(wl, chunk_id * chunk,
                                                  chunk, axis=0)
            out = out + xl.astype(jnp.float32) @ w_rows.astype(jnp.float32)
            if t != n - 1:
                xl = jax.lax.ppermute(xl, axis, perm)
        return out

    out = shard_map(body, mesh=mesh,
                    in_specs=(P(None, axis), P(None, axis)),
                    out_specs=P(None, axis), check_rep=False)(x, w)
    return out.astype(x.dtype)
