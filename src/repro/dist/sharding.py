"""Per-arch partition rules with divisibility fallbacks.

``make_axes_for`` resolves the logical axes of ``MeshAxes`` against a
concrete mesh: an axis is only assigned when the arch's dimension divides
the mesh axis size, otherwise it falls back to replication (e.g.
starcoder2's 36 heads don't divide a 16-wide model axis -> attention runs
replicated while the MLPs still shard).

``param_spec_fn`` encodes the megatron layout:

  column-parallel (out-dim sharded):  wq wk wv · mlp_wi mlp_wg · rwkv
      wr/wk/wv/wg/cm_wk/cm_wr · rg wx/wgate · shared_wi shared_wg
  row-parallel (in-dim sharded):      wo · mlp_wo · cm_wv · rg wo ·
      shared_wo
  expert-parallel:                    moe wi/wg/wo on the expert dim, or
      on the per-expert d_ff dim when n_experts doesn't divide (mixtral)
  vocab-parallel:                     embed (dim 0) and head (dim -1)
  replicated:                         scale banks, norms, router, gates,
      mixing/decay tables — everything that is not a projection weight

Every rule re-checks divisibility against the actual tensor dim, so the
emitted specs are always valid for the mesh (tests/test_sharding.py
asserts this for every arch).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.axes import Axes, MeshAxes

# Projection classification by the weight's parent key in the param tree.
_ATTN_CORE = {"wq", "wk", "wv", "wo"}
_COL = {"wq", "wk", "wv", "wr", "wg", "wx", "wgate",
        "mlp_wi", "mlp_wg", "shared_wi", "shared_wg", "cm_wk", "cm_wr"}
_ROW = {"wo", "mlp_wo", "shared_wo", "cm_wv"}


def _axis_sizes(mesh) -> dict:
    return {k: int(v) for k, v in dict(mesh.shape).items()}


def make_axes_for(cfg: ModelConfig, mesh, shard_seq="auto") -> MeshAxes:
    """Resolve logical axes for (cfg, mesh) with divisibility fallbacks.

    ``mesh`` needs only ``axis_names`` and ``shape`` (tests use a pure
    stand-in; specs are shape arithmetic, not device state).

    ``shard_seq``: "auto"/True enables sequence parallelism over the
    model axis; False keeps sequence dims replicated (exact-numerics
    comparisons against single-device execution).
    """
    names = tuple(mesh.axis_names)
    sizes = _axis_sizes(mesh)
    tp: Axes = ("model",) if "model" in names else ()
    tp_size = sizes.get("model", 1)
    dp: Axes = tuple(n for n in names if n != "model")
    dp_size = int(np.prod([sizes[n] for n in dp])) if dp else 1

    def fits(dim: int) -> Axes:
        return tp if (tp and dim % tp_size == 0) else ()

    ep: Axes = ()
    mtp: Axes = ()
    if cfg.moe and cfg.moe.n_experts:
        ep = fits(cfg.moe.n_experts)
        if not ep:                       # mixtral: 8 experts vs 16-wide axis
            mtp = fits(cfg.moe.d_ff)

    return MeshAxes(
        mesh=mesh,
        dp=dp,
        sp=tp if (shard_seq and tp) else (),
        tp=tp,
        th=fits(cfg.n_heads),
        tv=fits(cfg.vocab),
        ep=ep,
        mtp=mtp,
        dp_size=dp_size,
        tp_size=tp_size,
    )


# ---------------------------------------------------------------------------
# parameter partition rules
# ---------------------------------------------------------------------------
def _replicate(rank: int) -> P:
    return P(*([None] * rank))


def _shard_dim(rank: int, dim: int, ax: Axes) -> P:
    entries = [None] * rank
    entries[dim % rank] = ax
    return P(*entries)


def param_spec_fn(cfg: ModelConfig,
                  axes: MeshAxes) -> Callable[[str, Tuple[int, ...]], P]:
    """Returns ``fn(param_name, shape) -> PartitionSpec``.

    ``param_name`` is the '/'-joined tree path ("body/0/wq/w"). Only
    leaves named "w" are projection weights; every other leaf (scale
    banks, norms, gates, mixing tables) replicates.

    Packed serving-time weights (``runtime.packing.PackedLinear``) do NOT
    route through this fn directly — ``packed_specs`` maps each packed
    leaf's *original* projection rule (looked up here under the synthetic
    "/w" name) onto its packed code/scale layout, and
    ``projection_shard_fn`` feeds the same rule to shard-aware packing so
    the sharded codes split on per-shard byte boundaries. The int8 KV
    cache needs no rule here — ``decode_state_specs`` shards its
    code/scale slot axis like any other decode-state leaf.
    """
    tps = axes.tp_size

    def ok(shape, dim: int, ax: Axes) -> bool:
        return bool(ax) and shape[dim] % tps == 0

    def fn(name: str, shape: Tuple[int, ...]) -> P:
        parts = name.split("/")
        rank = len(shape)
        rep = _replicate(rank)
        if parts[-1] != "w" or rank < 2:
            return rep
        parent = parts[-2]
        gp = parts[-3] if len(parts) >= 3 else ""

        if gp == "moe":                        # routed expert stacks
            if parent == "router":
                return rep
            if axes.ep and rank >= 3 and shape[-3] % tps == 0:
                return _shard_dim(rank, -3, axes.ep)
            if axes.mtp:
                dim = -1 if parent in ("wi", "wg") else -2
                if ok(shape, dim, axes.mtp):
                    return _shard_dim(rank, dim, axes.mtp)
            return rep
        if parent == "embed":
            if axes.tv and shape[0] == cfg.vocab:
                return _shard_dim(rank, 0, axes.tv)
            return rep
        if parent == "head":
            if axes.tv and shape[-1] == cfg.vocab:
                return _shard_dim(rank, -1, axes.tv)
            return rep
        if parent in ("img_proj", "router"):
            return rep

        # attention projections shard only when heads divide (megatron);
        # ssm-family layers reuse the wk/wv/wo names for non-attention
        # projections and rg.* is the recurrent block — those follow the
        # plain tensor-parallel axis.
        is_attn = (parent in _ATTN_CORE and cfg.family != "ssm"
                   and gp != "rg")
        gate = axes.th if is_attn else axes.tp
        if not gate:
            return rep
        if parent in _ROW and ok(shape, -2, gate):
            return _shard_dim(rank, -2, gate)
        if parent in _COL and ok(shape, -1, gate):
            return _shard_dim(rank, -1, gate)
        return rep

    return fn


def _spec_shard_axes(spec: P) -> Tuple[Optional[int], Axes]:
    """First sharded dim of a weight spec -> (dim, mesh axes); (None, ())
    when fully replicated. Projection rules shard at most one dim."""
    for d, e in enumerate(tuple(spec)):
        if e is not None:
            return d, (e if isinstance(e, tuple) else (e,))
    return None, ()


def _axes_size(mesh, ax: Axes) -> int:
    sizes = _axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in ax])) if ax else 1


def projection_shard_fn(cfg: ModelConfig, axes: MeshAxes):
    """Returns ``fn(name, w_shape) -> (shard_dim, shard_count)`` — the
    tensor-parallel split of one projection weight under ``axes``, in the
    form ``runtime.packing.pack_linear(shard_dim=, shard_count=)`` takes.
    ``name`` is the '/'-joined path of the weight leaf (ending "/w"), so
    the packed layout always follows the same megatron rule the fake-quant
    param tree would shard under."""
    fn = param_spec_fn(cfg, axes)

    def info(name: str, shape: Tuple[int, ...]):
        if not axes.enabled:
            return None, 1
        d, ax = _spec_shard_axes(fn(name, shape))
        if d is None:
            return None, 1
        return d, _axes_size(axes.mesh, ax)

    return info


def packed_specs(cfg: ModelConfig, params, axes: MeshAxes):
    """PartitionSpec tree for a packed serving param tree
    (``runtime.session.QuantizedSession.params``).

    Every ``PackedLinear`` leaf expands to a spec node of the same pytree
    structure (codes/scale/s_a children carry PartitionSpecs; the static
    bit metadata stays aux data, outside the spec tree) built from the
    *original* projection's partition rule:

    * ``codes`` shard along the packed counterpart of the weight's
      tensor-parallel dim — the same dim for the row layouts, axis 0 of
      the flat stream for ``bitstream``. A leaf is only sharded when it
      was packed per-shard for this mesh degree (or its layout is
      byte-per-code / packed off the shard dim, where plain packing is
      already per-shard exact); anything else replicates rather than
      splitting a byte mid-shard.
    * ``scale`` follows the out-dim: sharded for column-parallel layers
      (per-channel ``(out,)``) and expert-parallel stacks (``(E, 1, 1)``),
      replicated for row-parallel ones (their per-channel scale spans the
      unsharded out dim).
    * ``s_a`` replicates except per-expert ``(E,)`` banks under expert
      parallelism.

    Non-packed leaves (embed/head, norms, reference-mode fake-quant
    dicts) follow ``param_spec_fn`` unchanged.
    """
    import dataclasses as _dc

    from repro.runtime.packing import PackedLinear

    fn = param_spec_fn(cfg, axes)

    def one(path, leaf):
        name = _path_name(path)
        if not isinstance(leaf, PackedLinear):
            return fn(name, tuple(leaf.shape))
        rank = len(leaf.shape)
        d, ax = _spec_shard_axes(fn(name + "/w", leaf.shape))
        n = _axes_size(axes.mesh, ax) if ax else 1
        codes = _replicate(leaf.codes.ndim)
        scale = _replicate(leaf.scale.ndim)
        s_a = _replicate(leaf.s_a.ndim)
        if d is not None and n > 1:
            per_shard = leaf.shard_dim == d and leaf.shard_count == n
            if leaf.layout == "bitstream":
                if per_shard:
                    codes = P(ax)
            elif per_shard or leaf.codes.shape[d] % n == 0:
                codes = _shard_dim(leaf.codes.ndim, d, ax)
            if (leaf.scale.ndim == 1 and d == rank - 1
                    and leaf.scale.shape[0] % n == 0):
                scale = P(ax)                       # column-parallel (out,)
            elif (leaf.scale.ndim == rank and d == 0
                    and leaf.scale.shape[0] % n == 0):
                scale = _shard_dim(rank, 0, ax)     # expert stack (E, 1, 1)
            if leaf.s_a.ndim == 1 and d == 0 and leaf.s_a.shape[0] % n == 0:
                s_a = P(ax)                         # per-expert (E,) bank
        return _dc.replace(leaf, codes=codes, scale=scale, s_a=s_a)

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedLinear))


def _path_name(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
        for k in path)


def param_specs(cfg: ModelConfig, params, axes: MeshAxes):
    """PartitionSpec tree mirroring ``params`` (arrays or ShapeDtypeStructs)."""
    fn = param_spec_fn(cfg, axes)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_name(path), tuple(leaf.shape)), params)


def zero_sharded_specs(cfg: ModelConfig, params, axes: MeshAxes):
    """ZeRO-style optimizer-state specs: the base param spec widened by the
    data axes on the largest still-replicated dim that divides ``dp_size``
    (gradients/optimizer moments never need to be fully replicated)."""
    base = param_specs(cfg, params, axes)

    def widen(leaf, spec):
        if not axes.dp:
            return spec
        shape = tuple(leaf.shape)
        entries = list(spec) + [None] * (len(shape) - len(tuple(spec)))
        best = -1
        for i, (dim, e) in enumerate(zip(shape, entries)):
            if e is None and dim > 1 and dim % axes.dp_size == 0:
                if best < 0 or dim > shape[best]:
                    best = i
        if best >= 0:
            entries[best] = axes.dp
        return P(*entries)

    return jax.tree.map(widen, params, base)


def batch_specs(cfg: ModelConfig, batch, axes: MeshAxes):
    """Input specs: leading (batch) dim over the data axes when it divides;
    batch-1 cells (long-context decode) replicate."""
    def one(leaf):
        shape = tuple(leaf.shape)
        rank = len(shape)
        if (rank and axes.dp and shape[0] > 1
                and shape[0] % axes.dp_size == 0):
            return P(*((axes.dp,) + (None,) * (rank - 1)))
        return _replicate(rank)

    return jax.tree.map(one, batch)


def decode_state_specs(cfg: ModelConfig, state, axes: MeshAxes):
    """Decode-state (KV cache / recurrent state) specs: shard the batch dim
    — the continuous-batching engine's *slot* axis — over data axes. Body
    segments carry a leading (repeats,) stack dim, so their slot dim is
    index 1. Rank-(2+b) leaves cover the per-slot bookkeeping the engine
    adds (per-slot KVCache position rows (slots, cap), rank-2 recurrent
    hidden states); shared position vectors (cap,) and body-stacked shared
    positions (repeats, cap) stay below the rank gate and replicate.

    Int8 KV caches (``runtime.kv_cache.QuantKVCache``) need no special
    casing: their code tensors (slots, cap, KV, hd) and per-head scale
    tensors (slots, cap, KV) clear the same rank gate and shard on the
    slot dim, and the quantized runtime's flat per-site state ("sites"
    segment, no stack dim) takes the b = 0 branch."""
    def one(path, leaf):
        shape = tuple(leaf.shape)
        rank = len(shape)
        body = bool(path) and str(getattr(path[0], "key", "")) == "body"
        b = 1 if body else 0
        entries = [None] * rank
        if (rank >= 2 + b and axes.dp and shape[b] > 1
                and shape[b] % axes.dp_size == 0):
            entries[b] = axes.dp
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, state)


def named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
