"""Three-term roofline: compute / HBM / interconnect step-time model.

Feeds on the per-device ``HloCost`` from ``repro.dist.hlo``. Each term is
an independent lower bound on step time; their max is the roofline step
time and the arg-max names the bottleneck the dry-run tables report:

  compute_s     = flops / peak_flops
  memory_s      = bytes_hbm / hbm_bandwidth
  collective_s  = wire_bytes / ici_bandwidth

``useful_ratio`` compares the analytic model flops (from the QLayer MAC
table) against what the compiled graph actually executes — remat,
fake-quant chains and padding all push it below 1 — and ``mfu`` is the
classic model-flops utilization at the roofline step time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware envelope (defaults approximate a TPU v5e)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bytes_s: float = 819e9        # HBM bandwidth
    ici_bytes_s: float = 180e9        # ICI bandwidth (all links)
    dcn_bytes_s: float = 25e9         # cross-pod DCN, per chip share
    hbm_bytes: float = 16 * 2**30


DEFAULT_CHIP = ChipSpec()


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str                     # compute | memory | collective
    step_time_s: float
    model_flops_total: float
    useful_ratio: float
    mfu: float


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic model flops per step from the QLayer MAC table.

    train: 6 MAC-factors (fwd 2 + bwd 4); prefill/decode: 2. Decode runs
    one token per sequence.
    """
    from repro.models import lm   # local import: lm imports dist.axes
    macs_per_token = sum(q.macs_per_token * q.n_mats
                         for q in lm.enumerate_qlayers(cfg))
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * macs_per_token * tokens


def report(arch: str, shape: ShapeSpec, mesh_label: str, n_chips: int,
           costs, cfg: Optional[ModelConfig] = None,
           chip: ChipSpec = DEFAULT_CHIP) -> RooflineReport:
    """Build the three-term roofline from a per-device ``HloCost``."""
    compute_s = costs.flops / chip.peak_flops
    memory_s = costs.bytes_hbm / chip.hbm_bytes_s
    collective_s = costs.wire_bytes / chip.ici_bytes_s
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_time_s = max(terms.values())

    mft = model_flops(cfg, shape) if cfg is not None else 0.0
    executed_total = costs.flops * max(n_chips, 1)
    useful_ratio = mft / executed_total if executed_total else 0.0
    denom = step_time_s * max(n_chips, 1) * chip.peak_flops
    mfu = mft / denom if denom else 0.0

    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_label, n_chips=n_chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, step_time_s=step_time_s,
        model_flops_total=mft, useful_ratio=useful_ratio, mfu=mfu)
