"""Three-term roofline: compute / HBM / interconnect step-time model.

Feeds on the per-device ``HloCost`` from ``repro.dist.hlo``. Each term is
an independent lower bound on step time; their max is the roofline step
time and the arg-max names the bottleneck the dry-run tables report:

  compute_s     = flops / peak_flops
  memory_s      = bytes_hbm / hbm_bandwidth
  collective_s  = wire_bytes / ici_bandwidth

``useful_ratio`` compares the analytic model flops (from the QLayer MAC
table) against what the compiled graph actually executes — remat,
fake-quant chains and padding all push it below 1 — and ``mfu`` is the
classic model-flops utilization at the roofline step time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware envelope (defaults approximate a TPU v5e)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bytes_s: float = 819e9        # HBM bandwidth
    ici_bytes_s: float = 180e9        # ICI bandwidth (all links)
    dcn_bytes_s: float = 25e9         # cross-pod DCN, per chip share
    hbm_bytes: float = 16 * 2**30


DEFAULT_CHIP = ChipSpec()


def chip_from_table(table: dict, base: ChipSpec = DEFAULT_CHIP) -> ChipSpec:
    """Build a ``ChipSpec`` from a measured device-table stanza.

    ``table`` is what ``repro.obs.calibrate.calibrate`` emits (and what
    ``benchmarks/roofline_calibration.py`` writes into its bench JSON):
    ``ChipSpec`` field names mapped to measured values, plus bookkeeping
    keys (``source``, ...) that are ignored. Unmeasured fields keep
    ``base``'s envelope, and non-positive measurements are rejected —
    a zero bandwidth would turn every roofline term infinite silently.
    """
    fields = {f.name for f in dataclasses.fields(ChipSpec)}
    updates = {k: v for k, v in table.items() if k in fields}
    for k, v in updates.items():
        if k != "name" and (not isinstance(v, (int, float)) or v <= 0):
            raise ValueError(f"device table {k}={v!r}: measured envelope "
                             "values must be positive numbers")
    return dataclasses.replace(base, **updates)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str                     # compute | memory | collective
    step_time_s: float
    model_flops_total: float
    useful_ratio: float
    mfu: float


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic model flops per step from the QLayer MAC table.

    train: 6 MAC-factors (fwd 2 + bwd 4); prefill/decode: 2. Decode runs
    one token per sequence.
    """
    from repro.models import lm   # local import: lm imports dist.axes
    macs_per_token = sum(q.macs_per_token * q.n_mats
                         for q in lm.enumerate_qlayers(cfg))
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * macs_per_token * tokens


# ---------------------------------------------------------------------------
# per-step analytic costs (the serving scheduler's hook)
# ---------------------------------------------------------------------------
def decode_step_cost(cfg: ModelConfig, n_slots: int, *,
                     cache_tokens: int = 0, tp_size: int = 1,
                     avg_weight_bits: float = 8.0,
                     kv_bits: float = 16.0,
                     kv_attend: str = "fused",
                     w_bits_total: Optional[float] = None,
                     unique_pages: Optional[int] = None,
                     page_size: int = 0,
                     spec_k: int = 0,
                     draft_w_bits: float = 2.0,
                     chip: ChipSpec = DEFAULT_CHIP) -> dict:
    """Analytic three-term roofline for ONE continuous-batching decode step.

    Unlike ``report`` this needs no compiled HLO — the serving scheduler
    calls it per step shape, so it is built from the QLayer MAC/param table:

      compute_s     2 * macs * n_slots / peak_flops (per chip: megatron
                    row+column parallel splits the matmuls over tp)
      memory_s      (weight bytes + KV-cache bytes actually attended, i.e.
                    cache_tokens rows per slot, both sharded over tp)
                    / hbm_bytes_s — decode re-reads every weight per token,
                    so this term usually dominates
      collective_s  2 activation all-reduces per layer over the tp group
                    (megatron row+column parallel) / ici_bytes_s

    The bytes term is bit-width aware, reflecting the quantized serving
    runtime: ``w_bits_total`` is the exact packed weight-storage bits of a
    searched policy (``MPQPolicy.size_bytes(qlayers) * 8``; falls back to
    ``w_params * avg_weight_bits``), and ``kv_bits`` sizes a cache element
    (16 = bf16, 8 = the int8 KV cache, which also charges its 4-byte
    per-row per-head write-time scales AND the int32 per-slot position
    rows — the same inventory ``runtime.kv_cache.cache_bytes`` measures).

    ``kv_attend`` distinguishes how an int8 cache is *attended* (it is
    ignored for fp caches):

    * ``"fused"``   — the fused decode-attention kernel reads the codes
      directly; cache traffic is codes + scales + pos.
    * ``"dequant"`` — int8 stored but fp-attended: the XLA fallback
      materializes the dequantized cache in HBM every step, adding a bf16
      write + read of every cache element on top of the code read. This
      is what the engine actually pays off-TPU, so ``suggest_prefill_chunk``
      budgets honestly instead of assuming the kernel route.

    ``spec_k > 0`` models ONE self-speculative decode ROUND instead of one
    token-at-a-time step: a ``draft_w_bits``-wide uniform repack of the
    same weights proposes ``spec_k`` tokens autoregressively (the draft
    weight bytes are re-read once per drafted token — that is the whole
    point of drafting low-bit), then the target policy verifies all of
    them in a single batched ``spec_k + 1``-token step (the target weight
    bytes move ONCE for the round, amortized over every verified token).
    Compute runs ``2 * spec_k + 1`` token-passes, the KV cache is attended
    ``spec_k + 1`` times (k draft reads + one batched verify read), and
    the tp all-reduce wire scales the same way. A round can emit up to
    ``spec_k + 1`` tokens, so the modeled win condition is
    ``round.step_s < (accepted + 1) * single.step_s`` — the benches gate
    the memory-bound version of it (``spec_k`` draft reads + one target
    read < ``spec_k`` target reads) on the demo preset.

    ``unique_pages`` + ``page_size`` switch the KV term to the paged
    layout's accounting: shared-prefix pages are physically one allocation,
    so a step touches ``unique_pages * page_size`` cache rows instead of
    ``cache_tokens`` rows per slot — prefix sharing shrinks the modeled KV
    traffic, not just prefill compute. The paged layout also charges the
    int32 slot -> page-list table (read every step to gather, unsharded
    like the pos rows). The pool's host-side free-list/refcount arrays are
    deliberately NOT charged here — they never move over HBM during a
    decode step (``kv_cache.inventory`` does count them, under ``meta``).

    Returns the three terms plus ``step_s``/``dominant`` and the raw
    ``hbm_bytes``/``kv_hbm_bytes``/``wire_bytes`` counters.
    """
    if kv_attend not in ("fused", "dequant"):
        raise ValueError(f"kv_attend must be 'fused' or 'dequant', "
                         f"got {kv_attend!r}")
    paged = unique_pages is not None
    if paged and page_size <= 0:
        raise ValueError("paged KV accounting needs page_size > 0")
    if spec_k < 0:
        raise ValueError(f"spec_k must be >= 0, got {spec_k}")
    if spec_k and not 0 < draft_w_bits <= 8:
        raise ValueError("speculative drafting is a sub-8-bit repack: "
                         f"draft_w_bits must be in (0, 8], got {draft_w_bits}")
    if paged and kv_bits > 8:
        raise ValueError("paged KV pages hold int8 codes: kv_bits must be "
                         f"<= 8, got {kv_bits}")
    from repro.models import lm   # local import: lm imports dist.axes
    qlayers = lm.enumerate_qlayers(cfg)
    macs = sum(q.macs_per_token * q.n_mats for q in qlayers)
    w_params = sum(q.w_params * q.n_mats for q in qlayers)
    # only self-attention sites hold a token KV cache (recurrent/LRU sites
    # carry O(1) state, cross-attn caches image tokens), and a sliding
    # window caps the rows a cache can hold
    n_kv_layers = sum(1 for s in lm.iter_sites(cfg)
                      if s.kind in ("attn", "dense", "moe"))
    window = cfg.local_window if cfg.family == "hybrid" else cfg.sliding_window
    kv_rows = min(cache_tokens, window) if window else cache_tokens

    tp = max(tp_size, 1)
    compute_s = 2.0 * macs * n_slots / tp / chip.peak_flops
    if w_bits_total is not None:
        w_bytes = (w_bits_total / 8.0) / tp
    else:
        w_bytes = w_params * (avg_weight_bits / 8.0) / tp
    # rows of cache a step actually touches: dense per-slot rows for the
    # ring layout; the pool's unique resident rows for the paged layout
    # (a prefix page shared by k slots is one physical read, not k)
    eff_rows = (unique_pages * page_size if paged
                else float(kv_rows) * n_slots)
    kv_elems = 2.0 * eff_rows * cfg.kv_dim * n_kv_layers
    kv_bytes = kv_elems * (kv_bits / 8.0) / tp
    if kv_bits <= 8:
        # int8 KV: per-row per-head f32 scales and the int32 per-slot
        # position row ride along with the codes (one pos buffer serves
        # both k and v) — matching runtime.kv_cache.cache_bytes
        n_heads_kv = max(cfg.kv_dim // max(cfg.hd, 1), 1)
        kv_bytes += 2.0 * eff_rows * n_heads_kv * n_kv_layers * 4.0 / tp
        # the pos row has no KV-head dim to split over tp: every model
        # shard reads the full position inventory to mask its attention
        kv_bytes += eff_rows * n_kv_layers * 4.0
        if kv_attend == "dequant":
            # int8 stored but fp-attended: the fallback materializes the
            # dequantized cache in HBM each step (bf16 write + read)
            kv_bytes += 2.0 * kv_elems * 2.0 / tp
    if paged:
        # int32 slot -> page-list indirection, gathered every step
        pages_per_slot = -(-max(kv_rows, 1) // page_size)
        kv_bytes += n_slots * pages_per_slot * n_kv_layers * 4.0
    draft_bytes = 0.0
    if spec_k:
        # one speculative ROUND: the draft weights move once per drafted
        # token (k autoregressive passes), the target weights move ONCE
        # for the whole batched (k+1)-token verify, and the KV cache is
        # attended k + 1 times (each draft step + one verify read)
        draft_bytes = spec_k * w_params * (draft_w_bits / 8.0) / tp
        kv_bytes = (spec_k + 1.0) * kv_bytes
        compute_s = (2 * spec_k + 1) * compute_s
    memory_s = (w_bytes + draft_bytes + kv_bytes) / chip.hbm_bytes_s
    wire = (2.0 * 2 * cfg.n_layers * n_slots * cfg.d_model
            * 2 * (tp_size - 1) / max(tp_size, 1)) if tp_size > 1 else 0.0
    wire *= (2 * spec_k + 1) if spec_k else 1
    collective_s = wire / chip.ici_bytes_s

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "step_s": max(terms.values()),
            "dominant": dominant,
            # raw byte counters for the serving benches: per-shard HBM
            # traffic of one decode step (weights + KV, and the KV share
            # alone — the decode-attention bytes gate compares kv_hbm_bytes
            # against the measured cache inventory) and the tp all-reduce
            # wire bytes
            "hbm_bytes": w_bytes + draft_bytes + kv_bytes,
            "kv_hbm_bytes": kv_bytes, "draft_hbm_bytes": draft_bytes,
            "wire_bytes": wire}


def suggest_prefill_chunk(cfg: ModelConfig, n_slots: int, *,
                          cache_tokens: int = 0, tp_size: int = 1,
                          avg_weight_bits: float = 8.0,
                          kv_bits: float = 16.0,
                          kv_attend: str = "fused",
                          w_bits_total: Optional[float] = None,
                          spec_k: int = 0,
                          draft_w_bits: float = 2.0,
                          chip: ChipSpec = DEFAULT_CHIP,
                          min_chunk: int = 16, max_chunk: int = 512) -> int:
    """Prefill-token budget per engine iteration, from the decode roofline.

    A decode step is HBM/ICI-bound: the weights (and tp activations) move
    regardless of how much compute rides along. Prefill tokens are compute
    bound and reuse the same weight traffic, so the headroom between the
    decode step's memory/collective ceiling and its compute term is "free"
    prefill compute. The chunk is that headroom divided by the per-token
    prefill compute time, clamped to [min_chunk, max_chunk] so admission
    neither starves (tiny models: huge headroom) nor stalls decode (big
    models: none).

    ``spec_k > 0`` budgets a self-speculative engine honestly: one
    iteration is then a whole draft-k/verify-once round
    (``decode_step_cost(spec_k=...)``), whose compute term is
    ``2 * spec_k + 1`` token-passes — the headroom that can carry prefill
    per iteration shrinks or grows with the round shape, not with the
    single-token step the engine no longer runs.
    """
    cost = decode_step_cost(cfg, n_slots, cache_tokens=cache_tokens,
                            tp_size=tp_size, avg_weight_bits=avg_weight_bits,
                            kv_bits=kv_bits, kv_attend=kv_attend,
                            w_bits_total=w_bits_total, spec_k=spec_k,
                            draft_w_bits=draft_w_bits, chip=chip)
    ceiling = max(cost["memory_s"], cost["collective_s"])
    headroom_s = max(ceiling - cost["compute_s"], 0.0)
    from repro.models import lm
    macs = sum(q.macs_per_token * q.n_mats for q in lm.enumerate_qlayers(cfg))
    per_token_s = 2.0 * macs / max(tp_size, 1) / chip.peak_flops
    chunk = int(headroom_s / per_token_s) if per_token_s > 0 else max_chunk
    return max(min_chunk, min(max_chunk, chunk))


def report(arch: str, shape: ShapeSpec, mesh_label: str, n_chips: int,
           costs, cfg: Optional[ModelConfig] = None,
           chip: ChipSpec = DEFAULT_CHIP) -> RooflineReport:
    """Build the three-term roofline from a per-device ``HloCost``."""
    compute_s = costs.flops / chip.peak_flops
    memory_s = costs.bytes_hbm / chip.hbm_bytes_s
    collective_s = costs.wire_bytes / chip.ici_bytes_s
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_time_s = max(terms.values())

    mft = model_flops(cfg, shape) if cfg is not None else 0.0
    executed_total = costs.flops * max(n_chips, 1)
    useful_ratio = mft / executed_total if executed_total else 0.0
    denom = step_time_s * max(n_chips, 1) * chip.peak_flops
    mfu = mft / denom if denom else 0.0

    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_label, n_chips=n_chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, step_time_s=step_time_s,
        model_flops_total=mft, useful_ratio=useful_ratio, mfu=mfu)
