"""Compiled-HLO cost analyzer.

``analyze(text)`` parses an XLA post-optimization HLO dump and returns a
``HloCost`` with

  flops        — total flops, matching XLA's HloCostAnalysis op-for-op on
                 while-free graphs (the calibration contract in
                 tests/test_hlo.py), but with while-loop bodies scaled by
                 their known trip counts — XLA reports one iteration,
                 which under-counts a scanned layer stack by ``repeats``x
  dot_flops    — the dot/conv subset (the "useful" math for MFU)
  bytes_hbm    — HBM traffic estimate (fusion-boundary semantics: fused
                 producers are free, slices read the slice not the
                 operand), also trip-count-scaled
  wire_bytes   — collective bytes on the wire per participating device,
                 using the standard ring-algorithm cost model
  by_collective / n_collectives / trip_counts — breakdowns for reports

The parser handles the real printer grammar: tuple types with
``/*index=N*/`` comments, typed operands, nested computations
(fusion ``calls=``, ``to_apply=``, while ``condition=``/``body=``), and
both replica-group formats (``{{0,1},{2,3}}`` and iota ``[2,4]<=[8]``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# --------------------------------------------------------------------------
# result type
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0
    transcendentals: float = 0.0
    bytes_hbm: float = 0.0
    wire_bytes: float = 0.0
    n_collectives: int = 0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    trip_counts: List[int] = dataclasses.field(default_factory=list)

    def _add(self, other: "HloCost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.dot_flops += mult * other.dot_flops
        self.transcendentals += mult * other.transcendentals
        self.bytes_hbm += mult * other.bytes_hbm
        self.wire_bytes += mult * other.wire_bytes
        self.n_collectives += int(mult * other.n_collectives)
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + mult * v
        self.trip_counts.extend(other.trip_counts)


# --------------------------------------------------------------------------
# shape utilities
# --------------------------------------------------------------------------
_ELEM_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "bf16": 2,
    "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0, "tuple": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,<=\s]*)\]")


@dataclasses.dataclass(frozen=True)
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> float:
        return self.elems * _ELEM_BYTES.get(self.dtype, 4)


def _parse_shapes(type_str: str) -> List[Shape]:
    """All array shapes inside a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _ELEM_BYTES:
            continue
        dim_list = tuple(int(re.sub(r"[^0-9]", "", d) or 0)
                         for d in dims.split(",") if d.strip()) \
            if dims.strip() else ()
        out.append(Shape(dtype, dim_list))
    return out


def _shapes_bytes(shapes: List[Shape]) -> float:
    return sum(s.bytes for s in shapes)


def _shapes_elems(shapes: List[Shape]) -> int:
    return sum(s.elems for s in shapes)


# --------------------------------------------------------------------------
# parsing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out: List[Shape]
    operands: List[str]            # referenced value names; shapes are
                                   # resolved via _Analyzer's defs table
    attrs: str
    is_root: bool


_COMMENT_RE = re.compile(r"/\*.*?\*/")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _balanced(s: str, start: int) -> int:
    """Index one past the paren group opening at s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _split_top(s: str) -> List[str]:
    """Split on top-level commas (ignoring (), {} and [] nesting)."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    is_root, name, rhs = bool(m.group(1)), m.group(2), m.group(3).strip()
    # type: either a balanced tuple "( ... )" or a single token
    if rhs.startswith("("):
        end = _balanced(rhs, 0)
        type_str, rest = rhs[:end], rhs[end:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    op_end = _balanced(rest, om.end() - 1)
    operand_str = rest[om.end():op_end - 1]
    attrs = rest[op_end:].lstrip(", ")
    operands = []
    for tok in _split_top(operand_str):
        ref = tok.split()[-1] if tok.split() else ""
        operands.append(ref.lstrip("%"))
    return Instr(name=name, opcode=opcode, out=_parse_shapes(type_str),
                 operands=operands, attrs=attrs,
                 is_root=is_root)


def _parse_module(text: str, pre_stripped: bool = False) -> Dict[str, List[Instr]]:
    if not pre_stripped:
        text = _COMMENT_RE.sub("", text)
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//") or line.startswith("HloModule"):
            continue
        if current is None:
            h = _HEADER_RE.match(line)
            if h:
                current = h.group(2)
                comps[current] = []
            continue
        if line == "}" or line.startswith("}"):
            current = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[current].append(ins)
    return comps


# --------------------------------------------------------------------------
# per-op cost rules
# --------------------------------------------------------------------------
# Elementwise opcodes that count 1 flop per output element (XLA's table).
_EW_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "compare", "select", "and", "or", "xor", "not",
    "clamp", "convert", "is-finite", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "remainder", "clz", "popcnt", "stochastic-convert",
}
# 1 transcendental per output element; zero flops.
_EW_TRANS = {
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "logistic", "cosine", "sine", "tan", "sqrt", "rsqrt", "cbrt", "tanh",
    "power", "atan2", "erf", "expm1", "log1p",
}
# free data movement / metadata
_FREE = {
    "parameter", "constant", "iota", "tuple", "get-tuple-element", "bitcast",
    "reshape", "transpose", "after-all", "partition-id", "replica-id",
    "rng-get-and-update-state", "copy-start", "copy-done", "bitcast-convert",
    "opt-barrier",
}
# collectives and their ring wire-bytes model: f(group, in_bytes, out_bytes)
_COLLECTIVES = {
    "all-reduce": lambda g, i, o: 2.0 * (g - 1) / g * o,
    "all-gather": lambda g, i, o: (g - 1) / g * o,
    "reduce-scatter": lambda g, i, o: (g - 1) / g * i,
    "all-to-all": lambda g, i, o: (g - 1) / g * o,
    "collective-permute": lambda g, i, o: float(o),
    "collective-broadcast": lambda g, i, o: float(o),
}

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*([0-9]+)")
_DIMS_RE = {
    "lhs_contracting": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "rhs_contracting": re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}"),
}


def _group_size(attrs: str, default: int = 1) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        dims = [int(x) for x in m.group(1).split(",") if x.strip()]
        return dims[-1] if dims else default
    return default


def _int_list(rx: re.Pattern, attrs: str) -> List[int]:
    m = rx.search(attrs)
    if not m or not m.group(1).strip():
        return []
    return [int(x) for x in m.group(1).split(",") if x.strip()]


def _param_utilization(users: List["Instr"], pname: str,
                       defs: Dict[str, List[Shape]]) -> Optional[float]:
    """Bytes a fused computation actually reads of parameter ``pname``.

    slice/dynamic-slice/gather consumers read their output size; a
    dynamic-update-slice with the parameter as the updated buffer reads
    the update region (in-place aliasing). Any other consumer touches the
    whole parameter -> return None (caller uses the full size).
    """
    if not users:
        return None
    total = 0.0
    for ci in users:
        if (ci.opcode in ("slice", "dynamic-slice", "gather")
                and ci.operands and ci.operands[0] == pname):
            total += _shapes_bytes(ci.out)
        elif (ci.opcode == "dynamic-update-slice"
              and ci.operands and ci.operands[0] == pname
              and len(ci.operands) > 1):
            total += _shapes_bytes(defs.get(ci.operands[1], []))
        else:
            return None
    return total


class _Analyzer:
    def __init__(self, comps: Dict[str, List[Instr]], num_partitions: int):
        self.comps = comps
        self.num_partitions = num_partitions
        self.defs: Dict[str, Dict[str, List[Shape]]] = {
            c: {i.name: i.out for i in instrs}
            for c, instrs in comps.items()
        }
        self._memo: Dict[str, HloCost] = {}

    # -- operand shape lookup ------------------------------------------------
    def _operand_shapes(self, comp: str, ins: Instr) -> List[List[Shape]]:
        table = self.defs.get(comp, {})
        return [table.get(ref, []) for ref in ins.operands]

    # -- computations --------------------------------------------------------
    def comp_cost(self, name: str) -> HloCost:
        name = name.lstrip("%")
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = HloCost()          # cycle guard
        total = HloCost()
        for ins in self.comps.get(name, []):
            total._add(self.instr_cost(name, ins))
        self._memo[name] = total
        return total

    def _callee(self, attrs: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", attrs)
        return m.group(1) if m else None

    def _fusion_in_bytes(self, callee: Optional[str], ins: Instr,
                         opnds: List[List[Shape]]) -> float:
        """Operand bytes of a fusion with slice-utilization awareness."""
        body = self.comps.get(callee or "", [])
        params: Dict[int, Instr] = {}
        for ci in body:
            if ci.opcode == "parameter" and ci.operands:
                try:
                    params[int(ci.operands[0])] = ci
                except ValueError:
                    pass
        defs = self.defs.get(callee or "", {})
        total = 0.0
        for pos, shapes in enumerate(opnds):
            full = _shapes_bytes(shapes)
            p = params.get(pos)
            if p is not None:
                users = [ci for ci in body if p.name in ci.operands]
                util = _param_utilization(users, p.name, defs)
                if util is not None:
                    full = util
            total += full
        return total

    def _fusion_out_bytes(self, callee: Optional[str], out_b: float) -> float:
        """A fusion rooted in dynamic-update-slice writes in place: only
        the update region costs HBM traffic, not the aliased buffer."""
        body = self.comps.get(callee or "", [])
        defs = self.defs.get(callee or "", {})
        root = next((ci for ci in body if ci.is_root), None)
        if (root is not None and root.opcode == "dynamic-update-slice"
                and len(root.operands) > 1):
            return _shapes_bytes(defs.get(root.operands[1], []))
        return out_b

    # -- instructions --------------------------------------------------------
    def instr_cost(self, comp: str, ins: Instr) -> HloCost:
        c = HloCost()
        op = ins.opcode
        out_b = _shapes_bytes(ins.out)
        out_e = _shapes_elems(ins.out)
        opnds = self._operand_shapes(comp, ins)
        in_b = sum(_shapes_bytes(s) for s in opnds)

        if op in _FREE:
            return c

        base = re.sub(r"-(start|done)$", "", op)
        if base in _COLLECTIVES:
            if op.endswith("-done"):       # counted at the matching -start
                return c
            g = _group_size(ins.attrs, default=max(self.num_partitions, 1))
            wire = _COLLECTIVES[base](max(g, 1), in_b, out_b)
            c.wire_bytes += wire
            c.n_collectives += 1
            c.by_collective[base] = c.by_collective.get(base, 0.0) + wire
            c.bytes_hbm += in_b + out_b
            return c

        if op == "dot":
            lhs = opnds[0][0] if opnds and opnds[0] else None
            contract = 1
            for d in _int_list(_DIMS_RE["lhs_contracting"], ins.attrs):
                if lhs and d < len(lhs.dims):
                    contract *= lhs.dims[d]
            flops = 2.0 * out_e * contract
            c.flops += flops
            c.dot_flops += flops
            c.bytes_hbm += in_b + out_b
            return c

        if op == "convolution":
            kernel = opnds[1][0] if len(opnds) > 1 and opnds[1] else None
            k_elems = kernel.elems if kernel else 1
            out_feat = ins.out[0].dims[-1] if ins.out and ins.out[0].dims else 1
            flops = 2.0 * out_e * max(k_elems // max(out_feat, 1), 1)
            c.flops += flops
            c.dot_flops += flops
            c.bytes_hbm += in_b + out_b
            return c

        if op == "fusion" or op == "call":
            callee = self._callee(ins.attrs, "calls")
            if callee:
                sub = self.comp_cost(callee)
                c.flops += sub.flops
                c.dot_flops += sub.dot_flops
                c.transcendentals += sub.transcendentals
                c.wire_bytes += sub.wire_bytes
                c.n_collectives += sub.n_collectives
                for k, v in sub.by_collective.items():
                    c.by_collective[k] = c.by_collective.get(k, 0.0) + v
                c.trip_counts.extend(sub.trip_counts)
            # fusion-boundary bytes only (internal producers are free),
            # with per-parameter utilization: a parameter consumed only by
            # slice/gather/in-place-update ops is read at slice size, not
            # full size, and a DUS-rooted fusion writes only the update
            c.bytes_hbm += (self._fusion_in_bytes(callee, ins, opnds)
                            + self._fusion_out_bytes(callee, out_b))
            return c

        if op == "while":
            trip_m = _TRIP_RE.search(ins.attrs)
            trip = int(trip_m.group(1)) if trip_m else 1
            body = self._callee(ins.attrs, "body")
            cond = self._callee(ins.attrs, "condition")
            if body:
                c._add(self.comp_cost(body), trip)
            if cond:
                c._add(self.comp_cost(cond), trip)
            c.trip_counts.append(trip)
            return c

        if op == "conditional":
            for m in re.finditer(r"%([\w\.\-]+)", ins.attrs):
                if m.group(1) in self.comps:
                    c._add(self.comp_cost(m.group(1)))
            c.bytes_hbm += in_b + out_b
            return c

        if op == "reduce" or op == "reduce-window":
            callee = self._callee(ins.attrs, "to_apply")
            per = self.comp_cost(callee).flops if callee else 1.0
            per = per or 1.0
            n_in = sum(_shapes_elems(s) for s in opnds[:max(1, len(opnds) // 2)])
            c.flops += max(n_in - out_e, 0) * per
            c.bytes_hbm += in_b + out_b
            return c

        if op == "map":
            callee = self._callee(ins.attrs, "to_apply")
            per = self.comp_cost(callee).flops if callee else 1.0
            c.flops += out_e * per
            c.bytes_hbm += in_b + out_b
            return c

        if op == "scatter":
            callee = self._callee(ins.attrs, "to_apply")
            per = self.comp_cost(callee).flops if callee else 1.0
            upd_e = _shapes_elems(opnds[-1]) if opnds else 0
            upd_b = _shapes_bytes(opnds[-1]) if opnds else 0.0
            c.flops += upd_e * per
            c.bytes_hbm += 2.0 * upd_b + out_b
            return c

        if op in ("dynamic-slice", "slice", "gather"):
            idx_b = sum(_shapes_bytes(s) for s in opnds[1:])
            c.bytes_hbm += 2.0 * out_b + idx_b
            return c

        if op == "dynamic-update-slice":
            upd_b = _shapes_bytes(opnds[1]) if len(opnds) > 1 else out_b
            idx_b = sum(_shapes_bytes(s) for s in opnds[2:])
            c.bytes_hbm += 2.0 * upd_b + idx_b
            return c

        if op in ("broadcast", "pad", "concatenate", "reverse", "copy",
                  "sort", "rng", "rng-bit-generator", "select-and-scatter",
                  "custom-call", "reduce-precision", "domain", "infeed",
                  "outfeed", "cholesky", "triangular-solve", "fft"):
            c.bytes_hbm += in_b + out_b
            return c

        if op in _EW_TRANS:
            c.transcendentals += out_e
            c.bytes_hbm += in_b + out_b
            return c

        # default: elementwise-ish — 1 flop / element, stream in + out
        if op in _EW_FLOPS:
            c.flops += out_e
        c.bytes_hbm += in_b + out_b
        return c


def _entry_name(comps: Dict[str, List[Instr]], text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps), None)


def analyze(text: str) -> HloCost:
    """Analyze a post-optimization HLO module dump (``compiled.as_text()``)."""
    stripped = _COMMENT_RE.sub("", text)
    comps = _parse_module(stripped, pre_stripped=True)
    m = re.search(r"num_partitions=(\d+)", stripped)
    num_partitions = int(m.group(1)) if m else 1
    an = _Analyzer(comps, num_partitions)
    entry = _entry_name(comps, stripped)
    if entry is None:
        return HloCost()
    return an.comp_cost(entry)
