"""Logical sharding axes.

``MeshAxes`` maps *logical* axis names (what the model code thinks in:
data, sequence, heads, vocab, experts) to *mesh* axis names (what the
hardware mesh provides: ``data`` / ``model`` / ``pod``). Model code calls

    x = axes.shard(x, "dp", "sp", None)

with one logical name (or None) per array dimension. Under ``NO_AXES``
this is the identity, so every step function runs unmodified on one
device; under a real mesh it becomes a ``with_sharding_constraint`` that
pins the intermediate to the arch's partition layout.

Construction goes through ``repro.dist.sharding.make_axes_for`` which
applies the per-arch divisibility fallbacks — this module holds only the
dataclass and the identity default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A logical axis resolves to a tuple of mesh axis names: ("model",),
# ("pod", "data"), or () when the arch can't use the axis (fallback).
Axes = Tuple[str, ...]

LOGICAL_AXES = ("dp", "sp", "tp", "th", "tv", "ep", "mtp")


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Resolved logical->mesh axis assignment for one (arch, mesh) pair.

    dp   data parallel (batch / token groups);  ("pod", "data") multi-pod
    sp   sequence parallel (norm/embed regions between matmuls)
    tp   tensor parallel feature dim (d_ff activations)
    th   tensor parallel attention heads
    tv   tensor parallel vocab (logits / embedding)
    ep   expert parallel (MoE routed experts)
    mtp  MoE per-expert d_ff fallback when experts don't divide the mesh
    """
    mesh: Optional[Any] = None
    dp: Axes = ()
    sp: Axes = ()
    tp: Axes = ()
    th: Axes = ()
    tv: Axes = ()
    ep: Axes = ()
    mtp: Axes = ()
    dp_size: int = 1
    tp_size: int = 1

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def resolve(self, name: Optional[str]) -> Optional[Axes]:
        """Logical name -> mesh axes tuple (None if unused/unsupported)."""
        if name is None:
            return None
        ax = getattr(self, name)
        return ax if ax else None

    def spec(self, *names: Optional[str]) -> P:
        """PartitionSpec with one logical name (or None) per dimension."""
        return P(*(self.resolve(n) for n in names))

    def shard(self, x: jax.Array, *names: Optional[str]) -> jax.Array:
        """Constrain ``x``'s sharding; identity when no mesh is bound.

        Per-dim divisibility guard: a dim that doesn't divide its mesh
        axes falls back to replication for that dim only. Training shapes
        always divide (make_axes_for checks the arch dims), but serving
        runs the same layer code on shapes the arch rules never saw —
        batch-1 prefill under a data axis, single-token decode under
        sequence parallelism — and an indivisible constraint is an XLA
        error, not a fallback."""
        if not self.enabled:
            return x
        sizes = {k: int(v) for k, v in dict(self.mesh.shape).items()}
        entries = []
        for dim, name in zip(x.shape, names):
            ax = self.resolve(name)
            if ax is not None:
                n = 1
                for a in ax:
                    n *= sizes[a]
                if dim % n:
                    ax = None
            entries.append(ax)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries)))


def dp_only(axes: MeshAxes) -> MeshAxes:
    """Demote every model-parallel logical axis, keeping the mesh and the
    data axes. This is the compute layout the off-TPU serving paths use:
    jax 0.4.37's CPU SPMD partitioner is not trustworthy with model-axis
    sharded intermediates (fp contraction splits reassociate — which
    quantization grids amplify into token flips — and sub-byte
    unpack/rope chains on multi-dim-tiled values miscompile outright, see
    runtime/dispatch.py), while batch/slot partitioning over ``dp`` is
    the well-trodden path. The full megatron split stays for TPU kernel
    routes.

    ``tp_size`` resets to 1 with the axes it describes — a demoted
    MeshAxes reports no tensor parallelism (callers wanting the original
    degree must read it before demoting)."""
    if not axes.enabled:
        return axes
    return dataclasses.replace(axes, sp=(), tp=(), th=(), tv=(), ep=(),
                               mtp=(), tp_size=1)


# Single-device default: every logical axis resolves to nothing and
# ``shard`` is the identity. Safe to close over in jit on any backend.
NO_AXES = MeshAxes()
