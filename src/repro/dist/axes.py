"""Logical sharding axes.

``MeshAxes`` maps *logical* axis names (what the model code thinks in:
data, sequence, heads, vocab, experts) to *mesh* axis names (what the
hardware mesh provides: ``data`` / ``model`` / ``pod``). Model code calls

    x = axes.shard(x, "dp", "sp", None)

with one logical name (or None) per array dimension. Under ``NO_AXES``
this is the identity, so every step function runs unmodified on one
device; under a real mesh it becomes a ``with_sharding_constraint`` that
pins the intermediate to the arch's partition layout.

Construction goes through ``repro.dist.sharding.make_axes_for`` which
applies the per-arch divisibility fallbacks — this module holds only the
dataclass and the identity default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A logical axis resolves to a tuple of mesh axis names: ("model",),
# ("pod", "data"), or () when the arch can't use the axis (fallback).
Axes = Tuple[str, ...]

LOGICAL_AXES = ("dp", "sp", "tp", "th", "tv", "ep", "mtp")


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Resolved logical->mesh axis assignment for one (arch, mesh) pair.

    dp   data parallel (batch / token groups);  ("pod", "data") multi-pod
    sp   sequence parallel (norm/embed regions between matmuls)
    tp   tensor parallel feature dim (d_ff activations)
    th   tensor parallel attention heads
    tv   tensor parallel vocab (logits / embedding)
    ep   expert parallel (MoE routed experts)
    mtp  MoE per-expert d_ff fallback when experts don't divide the mesh
    """
    mesh: Optional[Any] = None
    dp: Axes = ()
    sp: Axes = ()
    tp: Axes = ()
    th: Axes = ()
    tv: Axes = ()
    ep: Axes = ()
    mtp: Axes = ()
    dp_size: int = 1
    tp_size: int = 1

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def resolve(self, name: Optional[str]) -> Optional[Axes]:
        """Logical name -> mesh axes tuple (None if unused/unsupported)."""
        if name is None:
            return None
        ax = getattr(self, name)
        return ax if ax else None

    def spec(self, *names: Optional[str]) -> P:
        """PartitionSpec with one logical name (or None) per dimension."""
        return P(*(self.resolve(n) for n in names))

    def shard(self, x: jax.Array, *names: Optional[str]) -> jax.Array:
        """Constrain ``x``'s sharding; identity when no mesh is bound."""
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*names)))


# Single-device default: every logical axis resolves to nothing and
# ``shard`` is the identity. Safe to close over in jit on any backend.
NO_AXES = MeshAxes()
