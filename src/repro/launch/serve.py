"""Serving driver: batched prefill + greedy decode with a mixed-precision
policy active (CPU-runnable demo of the deployment path).

Also demonstrates the int8 execution path: the searched per-layer bits all
land on the int8 grid, so a projection executes as
``quant_matmul(int8, int8) * s_x * s_w`` — bit-exact with the fake-quant
training graph (validated here and in tests/test_kernels.py).

Example:
  python -m repro.launch.serve --arch limpq-demo --batch 4 --prompt-len 32 \
      --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core.policy import MPQPolicy
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="limpq-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--uniform-bits", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    rng = jax.random.PRNGKey(args.seed)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)
    ql = lm.enumerate_qlayers(cfg)
    policy = (MPQPolicy.load(args.policy) if args.policy
              else MPQPolicy.uniform(ql, args.uniform_bits))
    bits = lm.bits_from_policy(cfg, policy, ql)

    data = SyntheticLM(cfg)
    batch = data.batch(0, args.batch, args.prompt_len)
    inputs = {k: jnp.asarray(v) for k, v in batch.items()}
    cap = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, b: lm.apply_prefill(
        p, cfg, b, bits, ctx, NO_AXES, prefill_cap=cap))
    decode = jax.jit(lambda p, t, pos, st: lm.apply_decode(
        p, cfg, t, pos, st, bits, ctx, NO_AXES))

    t0 = time.time()
    logits, state = prefill(params, inputs)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: B={args.batch} S={args.prompt_len} "
          f"{t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    tokens = [jnp.argmax(logits, -1)]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok = tokens[-1][:, None].astype(jnp.int32)
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, state = decode(params, tok, pos, state)
        tokens.append(jnp.argmax(logits, -1))
    jax.block_until_ready(tokens[-1])
    t_dec = time.time() - t0
    out = jnp.stack(tokens, 1)
    print(f"decode: {args.gen - 1} steps {t_dec*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_dec,1e-9):.0f} tok/s)")
    print("generated[0]:", out[0].tolist())

    # --- int8 execution-path equivalence on one projection -----------------
    from repro.core.quantizer import bit_range
    from repro.kernels import ops
    p0 = params["body"]["0"]["wq"]
    w = p0["w"][0] if p0["w"].ndim == 3 else p0["w"]
    s_w = (p0["s_w"][0] if p0["s_w"].ndim == 2 else p0["s_w"])[2]  # 4-bit bank
    qmin, qmax = bit_range(4, True)
    wq = jnp.clip(jnp.round(w / s_w), qmin, qmax).astype(jnp.int8)
    x = jax.random.normal(rng, (8, w.shape[0]), jnp.float32)
    s_x = jnp.float32(0.05)
    xq = jnp.clip(jnp.round(x / s_x), qmin, qmax).astype(jnp.int8)
    fused = ops.quant_matmul(xq, wq, s_x, s_w, blocks=(8, 128, 128))
    ref = (xq.astype(jnp.float32) * s_x) @ (wq.astype(jnp.float32) * s_w)
    err = float(jnp.max(jnp.abs(fused - ref)))
    print(f"int8 quant_matmul vs fake-quant ref: max_err={err:.2e}")


if __name__ == "__main__":
    main()
