"""Serving driver: request-queue front-end over the continuous-batching
decode engine (``repro.launch.engine``), with a mixed-precision policy
active (CPU-runnable demo of the deployment path).

The legacy fixed-batch loop is now one scheduling policy among several
(``--schedule fixed``); the default is continuous batching with
roofline-driven prefill/decode interleave. ``--compare`` (implied by
``--smoke``) runs the same request set under both schedules, checks the
generated tokens are identical, and reports the decode steps saved.

``--policy <searched.json>`` switches to the quantized serving runtime:
the policy compiles into a ``repro.runtime.session.QuantizedSession``
(weights quantized onto the searched per-layer grids, sub-8-bit codes
bit-packed, int8 KV-cache slots, prompt-length bucketing) and serves
through the same engine. With ``--smoke`` that path is gated hard: greedy
tokens must be identical to a reference engine running the fake-quant
training graph, and measured packed HBM bytes must land within 5% of
``MPQPolicy.size_bytes``.

``--mesh <name>`` serves under a real device mesh (``host`` = trivial
(1,); ``host8`` = 2-way data x 4-way tensor parallel over 8 forced host
devices): packed codes/scales shard per-tensor-parallel-shard, the int8
KV slot axis shards over data, and the engine jits with explicit
in/out_shardings. The smoke then adds a per-chip gate: per-shard packed
bytes must not exceed ``policy.size_bytes / tp`` beyond padding, while
greedy tokens stay identical to the single-device reference.

``--decode-attn`` pins how the int8 KV cache is attended
(``runtime.dispatch.resolve_decode_attn``): ``fused`` is the Pallas
kernel reading codes directly (TPU), ``fused-interpret`` runs the same
kernel program through the interpreter (the CI proof that the fused route
stays greedy-token-identical to the reference), ``dequant-fp`` is the
exact fallback, ``auto`` (default) resolves by backend.

``--speculate k`` turns on self-speculative decoding over the ``--policy``
runtime: the session packs a second, uniform low-bit policy
(``--draft-bits``, default int2) over the SAME weights and indicator-bank
scales, the draft proposes k tokens autoregressively, and the searched
target policy verifies all k in one batched multi-token step sharing the
int8 KV cache (draft-written rows past the first rejection are rolled
back). Greedy acceptance keeps the output token-identical to
non-speculative decode; with ``--smoke`` that identity is gated hard.

Examples:
  python -m repro.launch.serve --smoke
  python -m repro.launch.serve --write-demo-policy searched.json
  python -m repro.launch.serve --smoke --policy searched.json
  python -m repro.launch.serve --smoke --policy searched.json \
      --decode-attn fused-interpret
  python -m repro.launch.serve --smoke --policy searched.json \
      --speculate 4 --kv-layout paged --decode-attn fused-interpret
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.serve --smoke --policy searched.json \
      --mesh host8
  python -m repro.launch.serve --arch limpq-demo --requests 8 --slots 4 \
      --prompt-len 32 --gen 16 --stagger --compare
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.policy import MPQPolicy
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.launch.engine import DecodeEngine, EngineConfig
from repro.launch.scheduler import POLICIES, Request
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.runtime import dispatch


@dataclasses.dataclass
class ServeConfig:
    """The serving flag pile as one typed, validated object.

    ``main()`` builds it from argparse (``from_args``); tests, benchmarks
    and ``runtime.sharded_smoke`` build it directly — either way, engine
    construction consumes ``engine_config()`` instead of re-plumbing loose
    knobs, so a new serving option lands in every harness at once.
    Route-shaped fields (``kv_layout``, ``decode_attn``) validate against
    ``runtime.dispatch.ROUTES`` at construction, not deep in the engine.
    """

    arch: str = "limpq-demo"
    requests: int = 8
    slots: int = 4
    prompt_len: int = 32
    gen: int = 16
    cache_len: int = 0          # 0 = prompt + gen
    schedule: str = "continuous"
    stagger: bool = False
    arrive_every: int = 0
    policy_path: Optional[str] = None
    kv: str = "int8"            # int8 | fp: --policy runtime KV storage
    kv_layout: str = "ring"     # ring | paged (dispatch.ROUTES registry)
    page_size: int = 8          # tokens per KV page (paged only)
    decode_attn: str = "auto"   # auto | a dispatch decode_attn route
    mesh: Optional[str] = None
    bucket: bool = True         # prompt-length bucketing (ring only)
    chip_table: Optional[str] = None  # measured device table json (roofline)
    speculate: int = 0          # self-speculative draft length k (0 = off)
    draft_bits: int = 2         # draft policy weight bits (--speculate)
    elastic: bool = False       # admission-time ILP re-solve + hot-swap
    policy_variants: str = "3,4,6"  # avg weight-bit budgets of the bank
    sampling: str = "greedy"    # token selection; only greedy exists today
    seed: int = 0

    def __post_init__(self):
        if self.schedule not in POLICIES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; known: {POLICIES}")
        if self.kv not in ("int8", "fp"):
            raise ValueError(f"kv must be 'int8' or 'fp', got {self.kv!r}")
        dispatch.ROUTES.validate("kv_layout", self.kv_layout)
        if self.decode_attn != "auto":
            dispatch.ROUTES.validate("decode_attn", self.decode_attn)
        if self.kv_layout == "paged":
            if self.kv == "fp":
                raise ValueError(
                    "--kv-layout paged requires --kv int8: pages hold "
                    "int8 codes + scales")
            if self.mesh:
                raise ValueError(
                    "--kv-layout paged is single-device for now: the page "
                    "pool id space is not mesh-sharded")
        if self.speculate < 0:
            raise ValueError(f"--speculate must be >= 0, got {self.speculate}")
        dispatch.ROUTES.validate("spec", "self" if self.speculate else "off")
        if self.speculate:
            # every incompatibility is rejected HERE, with the reason, not
            # deep in the engine as a shape error three jits later
            if self.sampling != "greedy":
                raise ValueError(
                    "--speculate requires greedy sampling: acceptance "
                    "compares the draft token against the target argmax, "
                    "which is only token-identity-preserving when the "
                    "non-speculative path is also argmax")
            if not self.policy_path:
                raise ValueError(
                    "--speculate needs --policy <searched.json>: the draft "
                    "is a low-bit repack of the SAME packed weights "
                    "(runtime.session.SpecSession), so there must be a "
                    "packed target policy to draft for")
            if self.kv == "fp":
                raise ValueError(
                    "--speculate requires --kv int8: draft and verify share "
                    "one int8 KV cache (draft rows are overwritten by the "
                    "verify pass, rolled back past the first rejection)")
            if self.mesh:
                raise ValueError(
                    "--speculate is single-device for now: the fused "
                    "draft-verify round does not shard")
            if not (2 <= self.draft_bits <= 8):
                raise ValueError(
                    f"--draft-bits must be in [2, 8], got {self.draft_bits}; "
                    "it must also be one of the arch's searched bit-widths "
                    "so the draft grid shares the indicator-bank scales "
                    "(checked against the config at session build)")
            if self.kv_layout == "paged":
                # rollback support is a cache-protocol capability, not a
                # given: a paged pool without COW tail truncation would
                # corrupt shared-prefix pages on rejection
                from repro.runtime.kv_cache import PagedKVCache
                if not callable(getattr(PagedKVCache, "rollback", None)):
                    raise ValueError(
                        "--speculate with --kv-layout paged needs "
                        "PagedKVCache.rollback (drop/COW-truncate the tail "
                        "pages past the first rejection); this build's "
                        "paged cache does not support it")
        elif self.sampling != "greedy":
            raise ValueError(
                f"unknown sampling mode {self.sampling!r}; the engine "
                "decodes greedily (argmax)")
        dispatch.ROUTES.validate("elastic", "bank" if self.elastic else "off")
        if self.elastic:
            if not self.policy_path:
                raise ValueError(
                    "--elastic needs --policy <searched.json>: the variant "
                    "bank searches its budgets over the SAME indicator "
                    "banks the base policy was searched from, and the base "
                    "policy anchors that family")
            if self.speculate:
                raise ValueError(
                    "--elastic is incompatible with --speculate: the draft "
                    "pack pairs with ONE target policy and would go stale "
                    "at the first hot-swap")
            if self.mesh:
                raise ValueError(
                    "--elastic is single-device for now: a hot-swap would "
                    "have to re-place every packed shard on the mesh")
            if self.schedule == "fixed":
                raise ValueError(
                    "--elastic needs a continuous schedule: the controller "
                    "re-solves against the live admission stream, which "
                    "the fixed policy drains in whole rounds")
            if self.kv == "fp":
                raise ValueError(
                    "--elastic requires --kv int8: the variant bank is a "
                    "packed-session feature (pre-packed trees to swap)")
            self.variant_budgets  # malformed --policy-variants fails HERE

    @property
    def variant_budgets(self) -> Tuple[float, ...]:
        """``--policy-variants`` parsed to sorted avg weight-bit budgets."""
        try:
            vals = tuple(float(x) for x in self.policy_variants.split(","))
        except ValueError:
            raise ValueError(
                "--policy-variants must be comma-separated average "
                f"weight-bit budgets, got {self.policy_variants!r}")
        if len(vals) < 2 or len(set(vals)) != len(vals):
            raise ValueError(
                "--policy-variants needs >= 2 distinct budgets "
                f"(a one-variant bank cannot degrade), got "
                f"{self.policy_variants!r}")
        return tuple(sorted(vals))

    @property
    def resolved_cache_len(self) -> int:
        return self.cache_len or (self.prompt_len + self.gen)

    @property
    def session_kv(self) -> str:
        """KV storage mode for the packed session (``--kv`` normalized)."""
        return "none" if self.kv == "fp" else "int8"

    @classmethod
    def from_args(cls, args) -> "ServeConfig":
        return cls(
            arch=args.arch, requests=args.requests, slots=args.slots,
            prompt_len=args.prompt_len, gen=args.gen,
            cache_len=args.cache_len, schedule=args.schedule,
            stagger=args.stagger, arrive_every=args.arrive_every,
            policy_path=args.policy, kv=args.kv, kv_layout=args.kv_layout,
            page_size=args.page_size, decode_attn=args.decode_attn,
            mesh=args.mesh, bucket=not args.no_bucket,
            chip_table=args.chip_table, speculate=args.speculate,
            draft_bits=args.draft_bits, elastic=args.elastic,
            policy_variants=args.policy_variants, seed=args.seed)

    @property
    def chip(self):
        """``--chip-table`` resolved to a calibrated ``ChipSpec`` (cached);
        None without a table. Accepts either a bare device-table stanza or
        a whole ``benchmarks/roofline_calibration.py`` bench JSON (the
        ``device_table`` key)."""
        if self.chip_table is None:
            return None
        if not hasattr(self, "_chip"):
            self._chip = load_chip_table(self.chip_table)
        return self._chip

    def engine_config(self, *, kv_quant: Optional[str] = None,
                      schedule: Optional[str] = None,
                      layout: Optional[str] = None,
                      calibrated: bool = True,
                      speculate: int = 0) -> EngineConfig:
        """An ``EngineConfig`` for one engine of this serving run.

        ``kv_quant`` defaults to the packed session's storage mode; a
        non-int8 engine (the fp path, the fake-quant reference) silently
        serves through the ring layout — paged pages hold int8 codes.
        ``calibrated=False`` keeps the default ``ChipSpec`` even when a
        ``--chip-table`` is loaded — reference engines budget with the
        stock envelope, so the smoke's token-identity gate doubles as the
        calibrated-vs-default agreement check. ``speculate`` is opt-in per
        engine (default 0): only the measured spec engine drafts — the
        reference engines it gates against must stay token-at-a-time."""
        kv = self.session_kv if kv_quant is None else kv_quant
        lay = self.kv_layout if layout is None else layout
        if kv != "int8":
            lay = "ring"
        ecfg = EngineConfig(
            slots=self.slots, cache_len=self.resolved_cache_len,
            policy=schedule or self.schedule, kv_quant=kv, kv_layout=lay,
            page_size=self.page_size, bucket_prompts=self.bucket,
            speculate=speculate)
        if calibrated and self.chip is not None:
            ecfg = dataclasses.replace(ecfg, chip=self.chip)
        return ecfg


def build_requests(data, n, prompt_len, gen, *, stagger=False, arrive_every=0,
                   share_prefix=0):
    """A deterministic request set from the synthetic corpus. ``stagger``
    varies prompt/generation lengths across requests (the workload shape
    continuous batching wins on); ``arrive_every`` spaces arrivals out by
    that many engine iterations; ``share_prefix`` overwrites the first that
    many tokens of every prompt with request 0's (the shared-system-prompt
    workload the paged KV layout's prefix reuse wins on)."""
    reqs = []
    base = None
    for i in range(n):
        p = prompt_len
        g = gen
        if stagger:
            p = max(4, prompt_len - 3 * (i % 4))
            g = max(2, gen - 2 * (i % 3))
        toks = data.batch(i, 1, p)["tokens"][0]
        if share_prefix:
            toks = np.asarray(toks).copy()
            if base is None:
                base = toks[:share_prefix].copy()
            k = min(share_prefix, len(toks))
            toks[:k] = base[:k]
        reqs.append(
            Request(rid=i, tokens=toks, max_new=g, arrival=i * arrive_every)
        )
    return reqs


def load_chip_table(path: str):
    """``--chip-table`` loader: a measured device-table json ->
    calibrated ``ChipSpec``. Accepts the bench JSON written by
    ``benchmarks/roofline_calibration.py`` (nested ``device_table`` key)
    or a bare table stanza."""
    import json

    from repro.dist import roofline

    with open(path) as f:
        table = json.load(f)
    if "device_table" in table:
        table = table["device_table"]
    try:
        return roofline.chip_from_table(table)
    except ValueError as e:
        raise SystemExit(f"--chip-table {path}: {e}")


def run_engine(params, cfg, bits, ctx, reqs, *, scfg: ServeConfig, schedule,
               eng=None, axes=NO_AXES, calibrated=True, on_step=None):
    """Run one request set; pass ``eng`` to reuse its compiled functions
    (reset under the new schedule instead of paying a full re-jit)."""
    if eng is None:
        ecfg = scfg.engine_config(kv_quant="none", schedule=schedule,
                                  calibrated=calibrated)
        eng = DecodeEngine(params, cfg, bits, ctx, axes, ecfg)
    else:
        eng.reset(schedule)
    if on_step is not None:
        eng.on_step = on_step
    eng.submit_all(reqs)
    completions = eng.run()
    return eng, completions


def print_stats(label, eng):
    """THE stats report: one table per serving epoch, rendered straight
    from the ``EngineStats.as_dict()`` snapshot (counters, timers and the
    TTFT / inter-token latency percentiles all come from the same metrics
    registry — no ad-hoc side channels)."""
    s = eng.stats
    d = s.as_dict()
    print(
        f"{label}: {s.completed} done | decode {s.decode_steps} steps "
        f"({s.decode_tokens_per_s:.0f} tok/s) | "
        f"prefill chunk {eng.prefill_chunk}"
    )
    width = max(len(k) for k in d)
    for k in sorted(d):
        v = d[k]
        num = f"{v:.3f}" if isinstance(v, float) else str(v)
        print(f"  {k:<{width}}  {num}")
    for a in eng.monitor.alerts:
        print(f"  ALERT[{a.severity}] {a.name}: {a.metric} {a.op} "
              f"{a.threshold:g} (value {a.value:g})")


def export_obs(args, eng):
    """``--trace-out`` / ``--metrics-out`` artifacts from one engine epoch
    (call before a ``reset()`` starts the next epoch)."""
    import os

    def ensure_dir(path):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    if getattr(args, "trace_out", None):
        if eng.trace is None:
            raise SystemExit("--trace-out: engine tracing is disabled")
        ensure_dir(args.trace_out)
        eng.trace.write(args.trace_out)
        print(f"trace: {len(eng.trace.events)} events -> {args.trace_out}")
    if getattr(args, "metrics_out", None):
        import json
        ensure_dir(args.metrics_out)
        with open(args.metrics_out, "w") as f:
            json.dump(eng.metrics.snapshot(), f, indent=1, sort_keys=True)
        print(f"metrics: {len(eng.metrics)} series -> {args.metrics_out}")


def make_streamer(args):
    """``--metrics-stream``: build the JSONL snapshot streamer (or None).
    Hook it onto an engine with ``eng.on_step = streamer.tick`` — the
    engine calls it once per scheduler iteration."""
    path = getattr(args, "metrics_stream", None)
    if not path:
        return None
    import os

    from repro.obs.export import MetricsStreamer

    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    return MetricsStreamer(path,
                           interval_s=float(args.metrics_interval))


def attach_stream(args, eng):
    """Build + hook a streamer on one engine (the --policy path)."""
    streamer = make_streamer(args)
    if streamer is not None:
        eng.on_step = streamer.tick
    return streamer


def finish_stream(args, eng, streamer):
    """Close the JSONL stream (force-emitting a final snapshot, so every
    run yields >= 2 snapshots) and drop a Prometheus text dump of the
    same registry next to it (``<path>.prom``)."""
    if streamer is None:
        return
    from repro.obs.export import write_prometheus

    streamer.close(eng.metrics)
    prom = args.metrics_stream + ".prom"
    text = write_prometheus(eng.metrics, prom)
    print(f"metrics stream: {streamer.seq} snapshots -> "
          f"{args.metrics_stream} | {len(text.splitlines())} prometheus "
          f"lines -> {prom}")


def explain_policy(args, cfg):
    """``--explain-policy``: render the ILP audit trail of ``--policy``
    as a per-layer table (importance, chosen bits, bytes, binding
    constraint) and exit. The report comes from the policy's embedded
    ``SolveReport`` (``core.search.search_policy`` and
    ``demo_mixed_policy`` both embed one; serving bundles carry it in
    ``meta["solve_report"]``); a policy without one gets a descriptive
    report rebuilt from the bit assignment (zero importance, measured
    costs). A PATH argument also writes the report JSON there — the CI
    artifact."""
    from repro.core import ilp

    policy = MPQPolicy.load(args.policy)
    raw = (policy.meta or {}).get("solve_report")
    if raw is not None:
        report = ilp.SolveReport.from_json(raw)
    else:
        ql = lm.enumerate_qlayers(cfg)
        try:
            policy.validate(ql)
        except ValueError as e:
            raise SystemExit(
                f"--explain-policy: {args.policy} has no embedded "
                f"solve_report and does not match arch {cfg.name!r} "
                f"(did you mix --smoke and full variants?): {e}")
        report = ilp.describe_policy_report(
            ql, policy, sorted(int(b) for b in cfg.bits),
            meta={"arch": cfg.name, "policy_path": args.policy})
    print(report.render_table())
    if args.explain_policy != "-":
        import os
        d = os.path.dirname(args.explain_policy)
        if d:
            os.makedirs(d, exist_ok=True)
        report.save(args.explain_policy)
        print(f"solve report -> {args.explain_policy}")
    return report


def check_trace(eng, label):
    """Smoke gate: the recorded lifecycle trace and the stats counters must
    describe the same run (``repro.obs.trace.reconcile``)."""
    from repro.obs import trace as obs_trace
    if eng.trace is None:
        return
    problems = obs_trace.reconcile(eng.trace, eng.stats.as_dict())
    if problems:
        raise SystemExit(f"{label}: trace/stats reconcile failed: "
                         + "; ".join(problems))
    print(f"{label}: trace reconciles with engine stats "
          f"({len(eng.trace.events)} events)")


def calibration_report(eng, cfg, *, gate=False):
    """Replay the epoch's measured phase timings against the roofline
    step-cost model the engine budgeted with (``repro.obs.calibrate``)."""
    from repro.obs import calibrate
    report = calibrate.calibrate(
        cfg, eng.stats.as_dict(), slots=eng.ecfg.slots,
        cache_tokens=eng.ecfg.cache_len, kv_bits=eng.kv_bits,
        kv_attend=eng.kv_attend,
        w_bits_total=getattr(eng.adapter, "w_bits_total", None),
        chip=eng.ecfg.chip)
    print("roofline calibration (measured vs modeled):")
    print(calibrate.render_table(report["rows"]))
    t = report["device_table"]
    print(f"  measured device table: hbm_bytes_s={t['hbm_bytes_s']:.3e} "
          f"peak_flops={t['peak_flops']:.3e} ({t['name']})")
    # publish the worst modeled-vs-measured factor so the drift watcher
    # (obs.monitor.roofline_drift_watcher) can trip on it; the gauge only
    # exists once a calibration ran, so non-calibrating runs never alert
    from repro.obs import health as obs_health
    drift = obs_health.roofline_drift(report["rows"])
    eng.metrics.gauge(
        "roofline.drift_max",
        help="worst modeled-vs-measured phase cost factor").set(drift)
    eng.monitor.check(eng.metrics, eng.trace)
    if gate and not report["finite"]:
        raise SystemExit("roofline calibration produced a non-finite or "
                         f"non-positive ratio: {report['rows']}")
    return report


def demo_mixed_policy(cfg, meta=None):
    """A mixed MPQPolicy cycling the searched widths over the arch's QLayer
    table — a deterministic stand-in for an ILP search result. The serve
    ``--policy`` smoke and ``benchmarks/quant_serve_bench.py`` (whose
    checked-in baseline pins the exact bit assignment) must share this one
    builder."""
    from repro.core import ilp

    ql = lm.enumerate_qlayers(cfg)
    bits = sorted(int(b) for b in cfg.bits)
    n = len(bits)
    policy = MPQPolicy(
        {q.name: bits[i % n] for i, q in enumerate(ql)},
        {q.name: bits[(i + 1) % n] for i, q in enumerate(ql)},
        meta=dict(meta or {}, kind="demo-mixed", arch=cfg.name))
    # embed a descriptive SolveReport (zero importance, real costs) so
    # --write-demo-policy + --explain-policy renders without a search
    report = ilp.describe_policy_report(ql, policy, bits,
                                        meta={"kind": "demo-mixed",
                                              "arch": cfg.name})
    policy.meta["solve_report"] = report.to_json()
    return policy


def write_demo_policy(path, arch="limpq-demo", smoke=True):
    """Write a ``demo_mixed_policy`` json so the ``--policy`` serving path
    can be exercised without running the search."""
    cfg = smoke_config(arch) if smoke else get_config(arch)
    policy = demo_mixed_policy(cfg, meta={"smoke": smoke})
    policy.save(path)
    print(f"wrote demo policy for {cfg.name} ({len(policy.w_bits)} layers) "
          f"-> {path}")
    return policy


def resolve_axes(args, cfg):
    """``--mesh`` -> (MeshAxes, label). NO_AXES when no mesh requested.
    ``shard_seq=False``: serving smokes gate exact token identity against
    the single-device path."""
    if not args.mesh:
        return NO_AXES, None
    from repro.dist import sharding
    from repro.launch.mesh import make_mesh_by_name

    try:
        mesh, label = make_mesh_by_name(args.mesh)
    except ValueError as e:
        raise SystemExit(
            f"--mesh {args.mesh}: {e}. A multi-device host mesh needs "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> set "
            "before jax initializes.")
    return sharding.make_axes_for(cfg, mesh, shard_seq=False), label


def serve_elastic(args, scfg: ServeConfig, cfg, params, ctx, reqs):
    """The ``--elastic`` path: variant bank + admission-time ILP re-solve.

    Builds an ``ElasticSession`` holding one pre-packed tree per
    ``--policy-variants`` budget (all searched over the same indicator
    banks, family-stamped against this checkpoint), hands the engine an
    ``ElasticController``, and serves the request ramp. Under ``--smoke``
    three things are gated hard: (1) the ramp must trigger at least one
    DOWNSHIFT swap (the engine degrades precision instead of queueing),
    (2) every admission-time re-solve must close under 50 ms (the paper's
    ~0.06 s claim, load-bearing on the hot path), and (3) each
    completion's tokens must be bitwise identical to its generating
    variant's offline single-policy reference — a swap may change WHO
    serves the next request, never WHAT an admitted request decodes."""
    from repro.launch import elastic as elastic_mod
    from repro.runtime.session import ElasticSession, bank_fingerprint

    base = MPQPolicy.load(scfg.policy_path)
    ql = lm.enumerate_qlayers(cfg)
    try:
        base.validate(ql, bits=cfg.bits)
        bank = elastic_mod.build_variant_bank(
            ql, cfg.bits, scfg.variant_budgets,
            family=bank_fingerprint(params))
        sess = ElasticSession(cfg, params, bank.policies, ctx,
                              kv_quant=scfg.session_kv, active=bank.full)
    except ValueError as e:
        raise SystemExit(f"--elastic: {e}")
    ctrl = elastic_mod.ElasticController(
        cfg, bank, slots=scfg.slots, cache_len=scfg.resolved_cache_len,
        chip=scfg.chip)
    eng = DecodeEngine(sess.params, cfg, None, ctx, NO_AXES,
                       scfg.engine_config(), adapter=sess, elastic=ctrl)
    streamer = attach_stream(args, eng)
    eng.submit_all(reqs)
    completions = eng.run()
    print_stats(f"elastic/{args.schedule}", eng)
    export_obs(args, eng)
    st = eng.stats
    per_variant = {}
    for c in completions.values():
        per_variant.setdefault(c.policy_id, []).append(c.rid)
    budgets = ",".join(f"{b:g}" for b in scfg.variant_budgets)
    print(f"elastic bank [{budgets}] avg-bit budgets | {st.policy_swaps} "
          f"swap(s), {st.policy_swaps_down} down | {st.ilp_solves} "
          f"admission re-solves, max {ctrl.max_solve_ms:.1f} ms | held "
          f"{st.admissions_deferred_swap} round(s) for drains | final "
          f"variant {st.active_policy}")
    for pid in sorted(per_variant):
        print(f"  {pid}: {len(per_variant[pid])} request(s) "
              f"{sorted(per_variant[pid])}")
    if args.smoke:
        check_trace(eng, "elastic")
        if st.policy_swaps_down < 1:
            raise SystemExit(
                "elastic smoke: the traffic ramp triggered no downshift "
                "swap — the controller never traded precision for load")
        if ctrl.max_solve_ms >= 50.0:
            raise SystemExit(
                f"elastic smoke: admission-time ILP re-solve took "
                f"{ctrl.max_solve_ms:.1f} ms (>= 50 ms budget; the paper's "
                "~0.06 s one-shot search claim is load-bearing here)")
        for pid, rids in sorted(per_variant.items()):
            vbits = lm.bits_from_policy(cfg, bank.policies[pid])
            ref = DecodeEngine(
                params, cfg, vbits, ctx, NO_AXES,
                scfg.engine_config(
                    kv_quant="fake" if scfg.session_kv == "int8" else "none",
                    calibrated=False))
            ref.submit_all([r for r in reqs if r.rid in set(rids)])
            ref_out = ref.run()
            bad = [rid for rid in rids
                   if ref_out[rid].tokens != completions[rid].tokens]
            if bad:
                raise SystemExit(
                    f"elastic variant {pid} diverged from its single-policy "
                    f"reference on rids {bad}")
        print(f"per-variant tokens identical with each generating "
              f"variant's single-policy reference ({len(completions)} "
              f"requests across {len(per_variant)} variant(s))")
    finish_stream(args, eng, streamer)
    return eng, completions


def serve_quantized(args, scfg: ServeConfig, cfg, params, ctx, reqs,
                    axes=NO_AXES):
    """The ``--policy`` path: pack a searched policy into a
    ``QuantizedSession`` and serve it through the engine. With --smoke,
    gate token identity vs the fake-quant reference graph and packed HBM
    bytes vs the policy's accounting — plus, under a tensor-parallel
    ``--mesh``, per-shard packed bytes vs the per-chip budget
    ``policy.size_bytes / tp``. ``--kv-layout paged`` serves the same
    session over pooled KV pages with shared-prefix remapping; the token
    gate then proves the paged layout against the ring reference.
    ``--speculate k`` swaps in a ``SpecSession`` (the same packed weights
    carrying a second, low-bit draft policy) and the engine decodes in
    draft-k/verify-once rounds; the smoke then adds a second token gate
    against the same session decoding token-at-a-time."""
    from repro.runtime.session import (QuantizedSession, SpecSession,
                                       summarize)

    policy = MPQPolicy.load(scfg.policy_path)
    kv = scfg.session_kv
    if scfg.speculate:
        try:
            sess = SpecSession(cfg, params, policy, ctx, axes, mode="packed",
                               kv_quant=kv, draft_w_bits=scfg.draft_bits)
        except ValueError as e:
            raise SystemExit(f"--speculate --draft-bits {scfg.draft_bits}: "
                             f"{e}")
    else:
        sess = QuantizedSession(cfg, params, policy, ctx, axes, mode="packed",
                                kv_quant=kv)
    eng = DecodeEngine(sess.params, cfg, None, ctx, axes,
                       scfg.engine_config(speculate=scfg.speculate),
                       adapter=sess)
    streamer = attach_stream(args, eng)
    eng.submit_all(reqs)
    completions = eng.run()
    # counters (prefill shapes compiled, act quantizes reused, routes, ...)
    # all live in the stats table now — only the HBM accounting, which is
    # session- not engine-scoped, keeps its own line
    print_stats(f"quantized/{args.schedule}", eng)
    export_obs(args, eng)
    if args.smoke:
        check_trace(eng, "quantized")
        calibration_report(eng, cfg, gate=True)
    # close AFTER the calibration gauge lands, so the final snapshot and
    # the prometheus dump carry the full signal plane
    finish_stream(args, eng, streamer)
    s = summarize(sess)
    print(f"packed weights: {s['packed_bytes']} B "
          f"(+{s['scale_bytes']} B scales) vs policy accounting "
          f"{s['policy_bytes']:.0f} B (x{s['packed_vs_policy']:.3f}) | "
          f"{s['compression_vs_fp32']:.2f}x smaller than fp32 | "
          f"kv={s['kv_quant']} layout={eng.ecfg.kv_layout} "
          f"decode-attn={eng.decode_attn_route}")
    if scfg.speculate:
        es = eng.stats
        print(f"speculate k={scfg.speculate} draft_bits={scfg.draft_bits}: "
              f"{es.spec_rounds} rounds | drafted {es.spec_draft_tokens} "
              f"accepted {es.spec_accepted_tokens} "
              f"(accept rate {es.spec_accept_rate:.2f}) | draft pack "
              f"{sess.draft_bytes()} B on top of {s['packed_bytes']} B")
        if args.smoke:
            # the speculative gate proper: the SAME packed session through
            # a token-at-a-time engine — speculation must change nothing
            # but the step count (greedy acceptance is exact by
            # construction; this catches rollback/verify divergence)
            ns = DecodeEngine(sess.params, cfg, None, ctx, axes,
                              scfg.engine_config(), adapter=sess)
            ns.submit_all(reqs)
            ns_out = ns.run()
            bad = [r.rid for r in completions.values()
                   if ns_out[r.rid].tokens != r.tokens]
            if bad:
                raise SystemExit(
                    "speculative decode diverged from non-speculative "
                    f"packed decode: rids {bad}")
            print(f"speculative tokens identical with non-speculative "
                  f"packed decode ({eng.stats.decode_steps} spec rounds vs "
                  f"{ns.stats.decode_steps} decode steps)")
    if eng.ecfg.kv_layout == "paged":
        es = eng.stats
        print(f"paged KV: {eng.pool.n_pages} pages x "
              f"{eng.ecfg.page_size} tokens | prefix hits saved "
              f"{es.prefill_flops_saved:.0f} prefill FLOPs | "
              f"{es.prefill_compiles} prefill compile shape(s)")
    if axes.enabled and axes.tp_size > 1:
        ideal = policy.size_bytes(sess.qlayers, per_shard=axes.tp_size)
        # the gate budget follows the session's actual shard plan: a
        # projection the partition rules legitimately replicate (heads not
        # dividing the axis, etc.) counts in full per chip, so only
        # packing failures — codes replicating where the plan shards —
        # can trip it
        budget = sess.per_shard_policy_bytes()
        print(f"per-shard packed bytes: {s['per_shard_bytes']} B on each of "
              f"{axes.tp_size} tp shards vs per-chip plan budget "
              f"{budget:.0f} B (all-shardable ideal: size_bytes/tp = "
              f"{ideal:.0f} B)")
        if args.smoke and s["per_shard_bytes"] > budget * 1.05:
            raise SystemExit(
                f"per-shard packed bytes {s['per_shard_bytes']} exceed the "
                f"per-chip plan budget {budget:.0f} by more than padding "
                "(5%) — codes are replicating where the shard plan says "
                "they shard")
        if args.smoke:
            # device truth, not pack-time metadata: every codes leaf the
            # plan shards must actually BE sharded on the engine's placed
            # params (catches spec-tree / placement regressions that the
            # byte accounting above cannot see)
            from repro.runtime import packing
            bad = [pl.shape for pl in packing.packed_leaves(eng.params)
                   if pl.shard_count > 1
                   and pl.codes.sharding.is_fully_replicated]
            if bad:
                raise SystemExit(
                    f"codes replicated on-device for plan-sharded "
                    f"projections {bad[:3]} (+{max(len(bad) - 3, 0)} more)")
            print(f"on-device shardings verified: no plan-sharded codes "
                  f"leaf replicates ({len(packing.packed_leaves(eng.params))}"
                  " packed leaves)")

    if args.smoke or args.compare:
        # reference: the fake-quant training graph (scanned body) through
        # the same engine; int8 slots reference as quantize-dequantize fp
        bits = lm.bits_from_policy(cfg, policy)
        # calibrated=False: the reference budgets with the default chip,
        # so this token gate is ALSO the calibrated-vs-default agreement
        # check when a --chip-table is loaded
        ref_ecfg = scfg.engine_config(
            kv_quant="fake" if kv == "int8" else "none", calibrated=False)
        ref = DecodeEngine(params, cfg, bits, ctx, NO_AXES, ref_ecfg)
        ref.submit_all(reqs)
        ref_out = ref.run()
        mismatch = [r.rid for r in completions.values()
                    if ref_out[r.rid].tokens != r.tokens]
        if mismatch:
            raise SystemExit("packed runtime diverged from the fake-quant "
                             f"reference graph: rids {mismatch}")
        print("greedy tokens identical with the fake-quant reference graph "
              f"({len(completions)} requests)")
        if scfg.chip is not None:
            print(f"chip-table {scfg.chip_table}: calibrated prefill chunk "
                  f"{eng.prefill_chunk} vs default {ref.prefill_chunk} — "
                  "tokens identical, only the budget differs")
        ratio = s["packed_vs_policy"]
        if args.smoke and abs(ratio - 1.0) > 0.05:
            raise SystemExit(
                f"packed HBM bytes {s['packed_bytes']} off policy "
                f"accounting {s['policy_bytes']:.0f} by more than 5% "
                f"(x{ratio:.3f})")
        if args.smoke:
            print(f"packed HBM bytes within 5% of MPQPolicy.size_bytes "
                  f"(x{ratio:.3f})")
    return eng, completions


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="limpq-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", "--batch", type=int, default=4, dest="slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0, help="0 = prompt+gen")
    ap.add_argument("--schedule", default="continuous", choices=POLICIES)
    ap.add_argument("--stagger", action="store_true")
    ap.add_argument("--arrive-every", type=int, default=0)
    ap.add_argument("--compare", action="store_true",
                    help="run continuous AND fixed; check token identity")
    ap.add_argument("--policy", default=None,
                    help="MPQPolicy json path: serve it through the packed "
                         "quantized runtime (repro.runtime.session)")
    ap.add_argument("--kv", default="int8", choices=("int8", "fp"),
                    help="KV-cache storage for the --policy runtime")
    ap.add_argument("--kv-layout", default="ring",
                    choices=dispatch.ROUTES.routes("kv_layout"),
                    help="KV-cache layout for the --policy runtime: ring = "
                         "per-slot ring buffers; paged = pooled fixed-size "
                         "pages with COW shared-prefix remapping and "
                         "chunked-append prefill")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (--kv-layout paged)")
    ap.add_argument("--decode-attn", default="auto",
                    choices=("auto",) + dispatch.DECODE_ATTN_ROUTES,
                    help="decode-attention route over the int8 KV cache: "
                         "auto resolves fused on TPU / dequant-fp "
                         "elsewhere; fused-interpret runs the Pallas "
                         "kernel through the interpreter (CI equivalence)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: a low-bit draft repack "
                         "of the same packed weights proposes K tokens per "
                         "round and the searched policy verifies them in "
                         "one batched step (needs --policy; greedy tokens "
                         "stay identical by construction)")
    ap.add_argument("--draft-bits", type=int, default=2,
                    help="draft policy weight bit-width for --speculate; "
                         "must be one of the arch's searched widths so the "
                         "draft grid shares the indicator-bank scales")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic precision serving: pack a bank of policy "
                         "variants (--policy-variants budgets, searched on "
                         "the same indicator banks as --policy), re-solve "
                         "the ILP at admission time against live load, and "
                         "hot-swap the active variant between batches "
                         "(device_put of a pre-packed tree — no repacking)")
    ap.add_argument("--policy-variants", default="3,4,6", metavar="BITS",
                    help="comma-separated average weight-bit budgets of the "
                         "--elastic variant bank; each must lie inside the "
                         "arch's searched bit range")
    ap.add_argument("--mesh", default=None,
                    help="serve under a device mesh: host ((1,)) | host8 "
                         "(2-way data x 4-way tensor parallel; needs "
                         "xla_force_host_platform_device_count=8)")
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable prompt-length bucketing (--policy path)")
    ap.add_argument("--chip-table", default=None, metavar="JSON",
                    help="measured device table (the bench JSON written by "
                         "benchmarks/roofline_calibration.py, or a bare "
                         "device-table stanza): budget the serving engine "
                         "with the calibrated ChipSpec instead of the "
                         "default envelope")
    ap.add_argument("--explain-policy", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="render the --policy's ILP audit trail "
                         "(SolveReport: per-layer importance, chosen bits, "
                         "bytes, binding constraint) as a table and exit; "
                         "a PATH argument also writes the report json")
    ap.add_argument("--metrics-stream", default=None, metavar="PATH",
                    help="append periodic JSONL metric snapshots while "
                         "serving (one {ts, seq, metrics} object per line); "
                         "a Prometheus text dump of the final registry "
                         "lands at PATH.prom")
    ap.add_argument("--metrics-interval", type=float, default=0.5,
                    help="seconds between --metrics-stream snapshots")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the request-lifecycle trace of the measured "
                         "run: .jsonl = one event per line, anything else = "
                         "Chrome trace JSON (chrome://tracing / Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the engine metrics-registry snapshot (json)")
    ap.add_argument("--write-demo-policy", default=None, metavar="PATH",
                    help="write a mixed demo MPQPolicy json and exit")
    ap.add_argument("--uniform-bits", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.write_demo_policy:
        # layer names depend on the config size, so the policy must be
        # written for the same variant (--smoke or full) it will serve
        write_demo_policy(args.write_demo_policy, args.arch,
                          smoke=args.smoke)
        return

    if args.explain_policy is not None:
        if not args.policy:
            raise SystemExit("--explain-policy needs --policy <json> (the "
                             "report explains a concrete bit assignment)")
        cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
        explain_policy(args, cfg)
        return

    if args.smoke:
        if args.schedule == "fixed":
            raise SystemExit("--smoke needs a continuous schedule: its gate "
                             "compares the engine against the fixed path")
        args.compare = True
        args.stagger = True
        # the elastic smoke needs a queue deep enough to overload the
        # slots (that is what triggers a downshift swap), so its cap is
        # looser than the single-policy one
        args.requests = min(args.requests, 12 if args.elastic else 6)
        args.prompt_len = min(args.prompt_len, 16)
        args.gen = min(args.gen, 8)

    try:
        scfg = ServeConfig.from_args(args)
    except ValueError as e:
        raise SystemExit(str(e))

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    rng = jax.random.PRNGKey(scfg.seed)
    params = lm.init_params(rng, cfg)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed,
                            compute_dtype=jnp.float32)

    data = SyntheticLM(cfg)
    # paged serving: share half the shortest prompt across requests so the
    # smoke actually exercises prefix remapping, not just the page pool
    share = (scfg.prompt_len // 2 if scfg.kv_layout == "paged" else 0)
    reqs = build_requests(data, scfg.requests, scfg.prompt_len, scfg.gen,
                          stagger=scfg.stagger,
                          arrive_every=scfg.arrive_every,
                          share_prefix=share)

    axes, mesh_label = resolve_axes(args, cfg)
    if mesh_label:
        print(f"mesh {mesh_label}: dp={axes.dp_size} tp={axes.tp_size}")

    if scfg.policy_path:
        # the force scope must cover engine build AND runs: the route is
        # resolved both at build (roofline accounting) and at trace time
        forced = None if scfg.decode_attn == "auto" else scfg.decode_attn
        with dispatch.force_decode_attn(forced):
            if scfg.elastic:
                serve_elastic(args, scfg, cfg, params, ctx, reqs)
            else:
                serve_quantized(args, scfg, cfg, params, ctx, reqs, axes)
        return

    if axes.enabled and jax.default_backend() != "tpu":
        # fake-quant fp serving has no packed-codes gather, so off-TPU it
        # must not carry model-sharded intermediates either (the packed
        # session demotes internally — see dist.axes.dp_only)
        from repro.dist.axes import dp_only
        had_tp = axes.tp_size > 1
        axes = dp_only(axes)
        if had_tp:
            print("note: off-TPU fp serving keeps only data-parallel "
                  "compute; model-parallel axes demoted")

    ql = lm.enumerate_qlayers(cfg)
    policy = MPQPolicy.uniform(ql, args.uniform_bits)
    bits = lm.bits_from_policy(cfg, policy, ql)

    eng = None
    if args.compare and args.schedule != "fixed":
        # warmup pass: pay the jit compiles up front so both measured runs
        # report steady-state throughput (serve_bench does the same)
        eng, _ = run_engine(params, cfg, bits, ctx, reqs, scfg=scfg,
                            schedule=scfg.schedule, axes=axes)
    streamer = make_streamer(args)
    eng, completions = run_engine(params, cfg, bits, ctx, reqs, scfg=scfg,
                                  schedule=scfg.schedule, eng=eng, axes=axes,
                                  on_step=streamer.tick if streamer else None)
    cont_stats = eng.stats      # reset() below replaces, not mutates, this
    print_stats(args.schedule, eng)
    # obs artifacts + gates come from THIS measured epoch, before the
    # --compare reset below starts a fresh registry/trace
    export_obs(args, eng)
    if args.smoke:
        check_trace(eng, args.schedule)
        calibration_report(eng, cfg, gate=True)
    finish_stream(args, eng, streamer)
    r0 = completions[0]
    print(f"generated[rid=0] ({r0.prompt_len}-token prompt):", r0.tokens)

    if args.compare and args.schedule != "fixed":
        # with a --chip-table loaded, the fixed-path comparison engine is
        # built fresh on the DEFAULT chip (calibrated=False): its token
        # gate then proves the calibrated budget changed only the chunk
        # sizes, never the tokens
        fresh_default = scfg.chip is not None
        fixed, fixed_out = run_engine(params, cfg, bits, ctx, reqs, scfg=scfg,
                                      schedule="fixed",
                                      eng=None if fresh_default else eng,
                                      axes=axes, calibrated=False)
        print_stats("fixed", fixed)
        mismatch = [r.rid for r in completions.values()
                    if fixed_out[r.rid].tokens != r.tokens]
        if mismatch:
            raise SystemExit(f"token mismatch vs fixed batch: rids {mismatch}")
        saved = fixed.stats.decode_steps - cont_stats.decode_steps
        print(f"token-identical with fixed batch; {saved} decode steps saved "
              f"({cont_stats.decode_steps} vs {fixed.stats.decode_steps})")
        if fresh_default:
            print(f"chip-table {scfg.chip_table}: calibrated prefill chunk "
                  f"{eng.prefill_chunk} vs default {fixed.prefill_chunk} — "
                  "tokens identical, only the budget differs")
        if args.smoke and args.stagger and saved <= 0:
            raise SystemExit("continuous batching saved no decode steps on a "
                             "staggered schedule")
    elif args.compare:
        print("note: --compare has no effect with --schedule fixed "
              "(nothing to compare the fixed path against)")

    # --- int8 execution-path equivalence on one projection -----------------
    body0 = params.get("body", {}).get("0", {})
    if "wq" in body0:
        from repro.core.quantizer import bit_range
        from repro.kernels import ops
        p0 = body0["wq"]
        w = p0["w"][0] if p0["w"].ndim == 3 else p0["w"]
        s_w = (p0["s_w"][0] if p0["s_w"].ndim == 2 else p0["s_w"])[2]  # 4-bit
        qmin, qmax = bit_range(4, True)
        wq = jnp.clip(jnp.round(w / s_w), qmin, qmax).astype(jnp.int8)
        x = jax.random.normal(rng, (8, w.shape[0]), jnp.float32)
        s_x = jnp.float32(0.05)
        xq = jnp.clip(jnp.round(x / s_x), qmin, qmax).astype(jnp.int8)
        fused = ops.quant_matmul(xq, wq, s_x, s_w, blocks=(8, 128, 128))
        ref = (xq.astype(jnp.float32) * s_x) @ (wq.astype(jnp.float32) * s_w)
        err = float(jnp.max(jnp.abs(fused - ref)))
        print(f"int8 quant_matmul vs fake-quant ref: max_err={err:.2e}")


if __name__ == "__main__":
    main()
