"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests see 1 device; only dryrun.py sets
``xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = one 256-chip v5e pod; 2x16x16 = two pods (512 chips).

    Axes: ('data', 'model') single-pod; ('pod', 'data', 'model') multi-pod.
    pod x data is pure data-parallel (the gradient all-reduce over the
    combined axes is hierarchical by construction: XLA emits the reduce over
    the product group, intra-pod ICI first, cross-pod DCN once per step);
    'model' is megatron tensor parallel.
    """
    if multi_pod:
        return jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
    return jax.make_mesh((16, 16), ("data", "model"))


def make_mesh_by_name(name: str):
    if name in ("single", "single_pod", "pod", "16x16"):
        return make_production_mesh(multi_pod=False), "16x16"
    if name in ("multi", "multi_pod", "2x16x16"):
        return make_production_mesh(multi_pod=True), "2x16x16"
    if name in ("host", "cpu", "1"):
        return jax.make_mesh((1,), ("data",)), "1"
    if name in ("host8", "2x4"):
        # 8 forced host devices (xla_force_host_platform_device_count=8):
        # 2-way data (engine slot axis) x 4-way megatron tensor parallel —
        # the serve-smoke / multi-device test topology
        return jax.make_mesh((2, 4), ("data", "model")), "2x4"
    raise ValueError(f"unknown mesh {name!r}")
