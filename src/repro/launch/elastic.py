"""Elastic precision serving: the paper's ILP moved inside the serving loop.

The headline result of arXiv:2203.08368 is that mixed-precision search
collapses to a one-shot MCKP the DP solver closes in ~0.06 s. That is
cheap enough to run *per admission round*, not just offline — so the
serving stack can trade model precision against live load:

* ``build_variant_bank`` searches N policy variants at different average
  weight-bit budgets over the SAME trained indicator banks (no extra
  training), stamps each with the bank family fingerprint
  (``runtime.session.bank_fingerprint``), and keeps the dense MCKP grids
  around for admission-time re-solves;
* ``runtime.session.ElasticSession`` packs every variant once at build;
* ``ElasticController.decide`` re-solves the size-budget ILP against live
  engine signals (arrived queue depth, slot occupancy, page-pool
  deferrals, measured KV-cache bytes) and picks the largest pre-packed
  variant that fits the live budget;
* ``launch.engine.DecodeEngine`` drains in-flight slots under the variant
  that admitted them, then hot-swaps ``params`` via ``jax.device_put`` of
  the chosen pre-packed tree (drain-then-swap — see ``_elastic_admission``).

Decisions are DETERMINISTIC given frozen signals: the DP solver has no
tie-breaking randomness and wall-clock only enters the solve-latency
telemetry, never the choice. That is what makes the bench's swap counts
regression-gateable.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import ilp, search
from repro.core.policy import MPQPolicy
from repro.core.qspec import QLayer
from repro.dist import roofline


def variant_id(budget_avg_bits: float) -> str:
    """Canonical variant name for an average weight-bit budget."""
    return f"w{budget_avg_bits:g}"


def demo_indicators(qlayers: Sequence[QLayer],
                    bits: Sequence[int]) -> search.Indicators:
    """Deterministic stand-in for trained importance indicators.

    The demo arch trains no indicator scalars, but the elastic path still
    needs a non-degenerate MCKP: error proxies decay in the bit-width
    (``4^-b`` for weights, ``2^-b`` for activations — so the budget always
    binds), scale with the layer's parameter / MAC share (so layers
    genuinely differ), and carry a small per-layer wobble (so the solver
    produces mixed assignments rather than uniform ties). Deterministic by
    construction — the bench gates swap counts on it.
    """
    bits = [int(b) for b in bits]
    total_w = float(sum(q.w_params for q in qlayers)) or 1.0
    total_m = float(sum(q.macs_per_token * q.n_mats for q in qlayers)) or 1.0
    out: search.Indicators = {}
    for li, q in enumerate(qlayers):
        wobble = 1.0 + 0.25 * math.sin(1.0 + 0.7 * li)
        w_share = q.w_params / total_w
        a_share = (q.macs_per_token * q.n_mats) / total_m
        out[q.name] = {
            "w": np.asarray([wobble * w_share * 4.0 ** -b for b in bits]),
            "a": np.asarray([wobble * a_share * 2.0 ** -b for b in bits]),
        }
    return out


@dataclasses.dataclass
class VariantBank:
    """N searched policy variants plus the MCKP grids they came from.

    ``policies`` maps variant id -> ``MPQPolicy`` in ascending-budget
    order; ``values`` / ``cost_size`` are the shared dense ``(L, n*n)``
    grids from ``search.build_mckp`` that ``ElasticController`` re-solves
    over at admission time; ``size_bits`` is each variant's ACHIEVED
    weight-storage bits (== its policy's ``size_bytes * 8``)."""

    policies: "OrderedDict[str, MPQPolicy]"
    values: np.ndarray
    cost_size: np.ndarray
    size_bits: Dict[str, float]
    layers: Tuple[str, ...]
    bits: Tuple[int, ...]
    family: Optional[str] = None

    @property
    def full(self) -> str:
        """Variant id with the largest achieved size (highest quality)."""
        return max(self.size_bits, key=lambda p: self.size_bits[p])

    @property
    def floor(self) -> str:
        """Variant id with the smallest achieved size (cheapest)."""
        return min(self.size_bits, key=lambda p: self.size_bits[p])


def build_variant_bank(qlayers: Sequence[QLayer], bits: Sequence[int],
                       budgets: Sequence[float], *,
                       indicators: Optional[search.Indicators] = None,
                       family: Optional[str] = None, alpha: float = 1.0,
                       method: str = "dp") -> VariantBank:
    """Search one policy variant per average weight-bit budget.

    All variants come from ONE ``build_mckp`` grid (same indicators, same
    searched bit set) — only the size budget differs, which is the whole
    point: no extra training, and the controller can re-solve the same
    grid live. Each variant is stamped with ``policy_id`` /
    ``avg_bits_budget`` / ``indicator_family`` meta. Budgets that collapse
    to identical assignments fail the build: a bank where two "variants"
    serve the same bits cannot degrade anything.
    """
    budgets = sorted(float(g) for g in budgets)
    if len(budgets) < 2 or len(set(budgets)) != len(budgets):
        raise ValueError(f"need >= 2 distinct avg-bit budgets, got {budgets}")
    lo, hi = min(int(b) for b in bits), max(int(b) for b in bits)
    bad = [g for g in budgets if not lo <= g <= hi]
    if bad:
        raise ValueError(f"budgets {bad} outside the searched bit range "
                         f"[{lo}, {hi}] — no assignment can average there")
    indicators = indicators if indicators is not None \
        else demo_indicators(qlayers, bits)
    values, _, cost_size = search.build_mckp(qlayers, indicators, bits,
                                             alpha, 1)
    total_w = float(sum(q.w_params for q in qlayers))
    policies: "OrderedDict[str, MPQPolicy]" = OrderedDict()
    size_bits: Dict[str, float] = {}
    assignments: Dict[tuple, str] = {}
    for g in budgets:
        pid = variant_id(g)
        res = search.search_policy(qlayers, indicators, bits, alpha=alpha,
                                   size_budget_bytes=g * total_w / 8.0,
                                   method=method)
        pol = res.policy
        pol.meta["policy_id"] = pid
        pol.meta["avg_bits_budget"] = g
        if family is not None:
            pol.meta["indicator_family"] = str(family)
        key = (tuple(sorted(pol.w_bits.items())),
               tuple(sorted(pol.a_bits.items())))
        if key in assignments:
            raise ValueError(
                f"budgets {assignments[key]} and {pid} solve to the same "
                "assignment — widen the bank's budget spread")
        assignments[key] = pid
        policies[pid] = pol
        size_bits[pid] = float(res.size_bytes) * 8.0
    return VariantBank(policies=policies, values=values, cost_size=cost_size,
                       size_bits=size_bits,
                       layers=tuple(q.name for q in qlayers),
                       bits=tuple(int(b) for b in bits), family=family)


@dataclasses.dataclass
class ElasticDecision:
    """One admission-time re-solve: which variant should serve, and why."""

    target: str          # variant id the engine should be serving
    active: str          # variant id it was serving when asked
    budget_bits: float   # live size budget the ILP solved against
    achieved_bits: float  # free-form optimum's size (lower bound audit)
    target_bits: float   # the chosen pre-packed variant's achieved size
    solver: str
    solve_ms: float
    signals: Dict[str, float]
    report: ilp.SolveReport  # the full audit trail (meta carries signals)

    def summary(self) -> Dict[str, Any]:
        """Compact JSON-able view for the ``policy_swap`` trace event."""
        return {"target": self.target, "active": self.active,
                "budget_bits": self.budget_bits,
                "achieved_bits": self.achieved_bits,
                "target_bits": self.target_bits,
                "objective": self.report.objective, "solver": self.solver,
                "optimal": self.report.optimal, "solve_ms": self.solve_ms,
                "signals": dict(self.signals)}


class ElasticController:
    """Admission-time ILP re-solve over a pre-packed variant bank.

    Every admission round with pending work, the engine hands this
    controller its live signals; ``decide`` turns them into a weight-size
    budget, re-solves the bank's MCKP grid against it (the ~tens-of-ms
    claim the obs histogram ``ilp.solve_ms`` now polices), and returns
    the largest pre-packed variant fitting the budget. The free-form
    solution itself is kept as the ``SolveReport`` audit trail — what the
    live-optimal assignment WOULD be if the bank held every policy — but
    only pre-packed variants can actually serve (no repacking on the hot
    path).

    Budget rule: the full variant's size, divided by the overload factor
    ``max(demand / slots, 1)`` where demand = arrived queue + occupied +
    fresh page-pool deferrals, then capped by HBM headroom when
    ``hbm_limit_bytes`` is set (live KV bytes eat into it). The result is
    clamped (with 1% slack for the DP's ceil-rounded cost grid) to the
    floor variant's size so a solve is always feasible. Upshifts are
    hysteretic: precision only recovers once nothing is waiting, so a
    sawtooth queue cannot thrash the bank.
    """

    def __init__(self, cfg: ModelConfig, bank: VariantBank, *, slots: int,
                 cache_len: int, kv_bits: float = 8.0,
                 kv_attend: str = "fused", method: str = "dp",
                 bins: int = 2048, hbm_limit_bytes: Optional[float] = None,
                 chip: Optional[roofline.ChipSpec] = None):
        self.bank = bank
        self.method = method
        # 2048 bins ≈ budget granularity well under one layer's smallest
        # bit step on the demo grids, at a quarter of the default solve
        # cost — this solve runs every admission round, not once
        self.bins = int(bins)
        self.hbm_limit_bytes = hbm_limit_bytes
        # largest -> smallest variant by achieved size
        self.order = sorted(bank.size_bits, key=lambda p: bank.size_bits[p],
                            reverse=True)
        self.full, self.floor = self.order[0], self.order[-1]
        # calibrated roofline step cost per variant: the audit signal
        # saying what each downshift buys per decode step (surfaced in
        # explain(); the decision itself stays a pure budget rule)
        self.step_s = {
            pid: roofline.decode_step_cost(
                cfg, slots, cache_tokens=cache_len, kv_bits=kv_bits,
                kv_attend=kv_attend, w_bits_total=bank.size_bits[pid],
                chip=chip or roofline.DEFAULT_CHIP)["step_s"]
            for pid in self.order}
        self.solves = 0
        self.max_solve_ms = 0.0
        self.last_report: Optional[ilp.SolveReport] = None

    def live_budget_bits(self, *, queue_depth: int, occupied: int,
                         slots: int, deferred: float = 0.0,
                         cache_bytes: float = 0.0) -> float:
        demand = float(queue_depth) + float(occupied) + float(deferred)
        overload = max(demand / max(int(slots), 1), 1.0)
        budget = self.bank.size_bits[self.full] / overload
        if self.hbm_limit_bytes:
            headroom_bits = (float(self.hbm_limit_bytes)
                             - float(cache_bytes)) * 8.0
            budget = min(budget, headroom_bits)
        # 1% slack: solve_dp ceil-rounds each layer cost onto the bin
        # grid, so a budget exactly at the floor assignment's true size
        # could round infeasible
        return max(budget, self.bank.size_bits[self.floor] * 1.01)

    def decide(self, *, active: str, queue_depth: int, occupied: int,
               slots: int, deferred: int = 0, cache_bytes: float = 0.0
               ) -> ElasticDecision:
        signals = {"queue_depth": float(queue_depth),
                   "occupied": float(occupied), "slots": float(slots),
                   "deferred": float(deferred),
                   "cache_bytes": float(cache_bytes)}
        budget = self.live_budget_bits(queue_depth=queue_depth,
                                       occupied=occupied, slots=slots,
                                       deferred=deferred,
                                       cache_bytes=cache_bytes)
        t0 = time.perf_counter()
        sol = ilp.solve_mckp(self.bank.values, self.bank.cost_size, budget,
                             method=self.method, bins=self.bins)
        solve_ms = (time.perf_counter() - t0) * 1e3
        self.solves += 1
        self.max_solve_ms = max(self.max_solve_ms, solve_ms)
        report = ilp.build_solve_report(
            list(self.bank.layers), list(self.bank.bits), sol,
            self.bank.values, {"size_bits": self.bank.cost_size},
            {"size_bits": budget}, elapsed_s=solve_ms / 1e3,
            meta=dict(signals, kind="elastic-resolve"))
        self.last_report = report
        sizes = self.bank.size_bits
        fitting = [p for p in self.order if sizes[p] <= budget * (1 + 1e-9)]
        target = fitting[0] if fitting else self.floor
        # hysteresis: upshift only once nothing is waiting
        if (active in sizes and sizes[target] > sizes[active]
                and queue_depth > 0):
            target = active
        return ElasticDecision(target=target, active=str(active),
                               budget_bits=float(budget),
                               achieved_bits=float(sol.cost),
                               target_bits=float(sizes[target]),
                               solver=sol.method, solve_ms=float(solve_ms),
                               signals=signals, report=report)

    def explain(self) -> str:
        """One line per variant: achieved size and modeled step cost."""
        rows = [f"{pid}: {self.bank.size_bits[pid] / 8e6:.2f} MB, "
                f"{self.step_s[pid] * 1e3:.3f} ms/step (roofline)"
                for pid in self.order]
        return "\n".join(rows)
