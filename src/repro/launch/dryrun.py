import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves the arch's partition rules (divisibility fallbacks included),
  3. lowers the production step — QAT ``train_step`` with a mixed-precision
     policy active for train shapes, ``prefill_step`` / ``serve_step`` for
     inference shapes — against ShapeDtypeStruct inputs (no allocation),
  4. compiles, records ``memory_analysis()`` + ``cost_analysis()`` + the
     trip-count-scaled HLO analysis (repro.dist.hlo), and
  5. writes a JSON artifact to experiments/dryrun/ that §Roofline reads.

The policy baked into the dry-run train step cycles bit-widths across
layers — structurally identical to an ILP-searched policy (static
per-layer bank indices) without requiring full-scale indicator training.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --list
  python -m repro.launch.dryrun --importance-cell        # paper-core step
"""
import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_config, list_archs
from repro.configs.base import SHAPES_BY_NAME, SHAPES, shape_applicable
from repro.core.policy import MPQPolicy
from repro.dist import hlo as hlo_mod
from repro.dist import roofline, sharding
from repro.launch.mesh import make_mesh_by_name
from repro.models import lm
from repro.models.quant_layers import QuantContext
from repro.core import importance as importance_mod

from jax.sharding import PartitionSpec as P


def cyclic_policy(cfg) -> MPQPolicy:
    """Static mixed policy: bits cycle across QLayers (w and a offset)."""
    ql = lm.enumerate_qlayers(cfg)
    bits = cfg.bits
    n = len(bits)
    w = {q.name: int(bits[i % n]) for i, q in enumerate(ql)}
    a = {q.name: int(bits[(i + 2) % n]) for i, q in enumerate(ql)}
    return MPQPolicy(w, a, meta={"kind": "cyclic-dryrun"})


def _named(mesh, spec_tree):
    return sharding.named(mesh, spec_tree)


def build_cell(cfg, shape, mesh, *, step_kind: str, zero_shard: bool = True,
               remat: bool = True, shard_seq="auto"):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    axes = sharding.make_axes_for(cfg, mesh, shard_seq=shard_seq)
    ctx = QuantContext.make(cfg.bits, cfg.quant_act_signed)   # bf16 compute
    rng = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: lm.init_params(k, cfg), rng)
    pspecs = sharding.param_specs(cfg, params_shape, axes)
    inputs = lm.input_specs(cfg, shape)
    bspecs = sharding.batch_specs(cfg, inputs, axes)
    bits = lm.bits_from_policy(cfg, cyclic_policy(cfg))

    if step_kind == "train":
        opt = optim.adamw(optim.cosine_warmup(3e-4, 500, 50_000),
                          weight_decay=2.5e-5, clip_norm=1.0)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        zspecs = (sharding.zero_sharded_specs(cfg, params_shape, axes)
                  if zero_shard else pspecs)
        ospecs = type(opt_shape)(P(), zspecs, zspecs)

        def step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                lm.loss_fn, has_aux=True)(params, cfg, batch, bits, ctx,
                                          axes, remat)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = optim.apply_updates(params, updates)
            return params, opt_state, loss

        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                          _named(mesh, bspecs)),
            out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), None),
            donate_argnums=(0, 1))
        return jitted, (params_shape, opt_shape, inputs)

    if step_kind == "importance":
        opt = importance_mod.importance_optimizer(0.01, freeze_backbone=True)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = type(opt_shape)(P(), pspecs)
        istep = importance_mod.make_importance_step(cfg, ctx, opt, axes,
                                                    remat=remat)
        rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jitted = jax.jit(
            istep,
            in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                          _named(mesh, bspecs), None),
            out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), None),
            donate_argnums=(0, 1))
        return jitted, (params_shape, opt_shape, inputs, rng_spec)

    if step_kind == "prefill":
        if cfg.encoder_only:
            def fwd(params, batch):
                logits, _ = lm.apply_train(params, cfg, batch, bits, ctx,
                                           axes, remat=False)
                return logits
            jitted = jax.jit(fwd,
                             in_shardings=(_named(mesh, pspecs),
                                           _named(mesh, bspecs)),
                             out_shardings=None)
            return jitted, (params_shape, inputs)

        def prefill(params, batch):
            return lm.apply_prefill(params, cfg, batch, bits, ctx, axes,
                                    prefill_cap=shape.seq_len)

        state_shape = jax.eval_shape(
            lambda: lm.init_decode_state(cfg, shape.global_batch,
                                         shape.seq_len))
        sspecs = sharding.decode_state_specs(cfg, state_shape, axes)
        jitted = jax.jit(prefill,
                         in_shardings=(_named(mesh, pspecs),
                                       _named(mesh, bspecs)),
                         out_shardings=(None, _named(mesh, sspecs)))
        return jitted, (params_shape, inputs)

    if step_kind == "decode":
        state_shape = jax.eval_shape(
            lambda: lm.init_decode_state(cfg, shape.global_batch,
                                         shape.seq_len))
        sspecs = sharding.decode_state_specs(cfg, state_shape, axes)

        def serve_step(params, state, token, pos):
            return lm.apply_decode(params, cfg, token, pos, state, bits,
                                   ctx, axes)

        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(serve_step,
                         in_shardings=(_named(mesh, pspecs),
                                       _named(mesh, sspecs),
                                       _named(mesh, sharding.batch_specs(
                                           cfg, tok, axes)), None),
                         out_shardings=(None, _named(mesh, sspecs)),
                         donate_argnums=(1,))
        return jitted, (params_shape, state_shape, tok, pos)

    raise ValueError(step_kind)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             step_kind: str = "auto", out_dir: str = "experiments/dryrun",
             save_hlo: bool = False, **build_kw):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    if step_kind == "auto":
        step_kind = {"train": "train", "prefill": "prefill",
                     "decode": "decode"}[shape.kind]

    mesh, mesh_label = make_mesh_by_name(mesh_name)
    n_chips = mesh.devices.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_label,
           "n_chips": n_chips, "step_kind": step_kind}
    try:
        with mesh:
            jitted, args = build_cell(cfg, shape, mesh, step_kind=step_kind,
                                      **build_kw)
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):     # jax<=0.4.x returns [dict]
            cost = cost[0]
        txt = compiled.as_text()
        costs = hlo_mod.analyze(txt)
        rep = roofline.report(arch, shape, mesh_label, n_chips, costs, cfg)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            "xla_cost_analysis": {"flops": cost.get("flops", 0.0),
                                  "bytes": cost.get("bytes accessed", 0.0)},
            "hlo_analysis": {
                "flops_per_device": costs.flops,
                "dot_flops_per_device": costs.dot_flops,
                "bytes_hbm_per_device": costs.bytes_hbm,
                "wire_bytes_per_device": costs.wire_bytes,
                "n_collectives": costs.n_collectives,
                "by_collective": costs.by_collective,
                "trip_counts": sorted(set(costs.trip_counts)),
            },
            "roofline": {
                "compute_s": rep.compute_s,
                "memory_s": rep.memory_s,
                "collective_s": rep.collective_s,
                "dominant": rep.dominant,
                "model_flops_total": rep.model_flops_total,
                "useful_ratio": rep.useful_ratio,
                "mfu_at_roofline": rep.mfu,
                "step_time_s": rep.step_time_s,
            },
        })
        if save_hlo:
            os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
            with gzip.open(os.path.join(
                    out_dir, "hlo",
                    f"{arch}__{shape_name}__{mesh_label}.txt.gz"), "wt") as f:
                f.write(txt)
    except Exception as e:           # a failing cell is a bug — record it
        rec.update({"status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-4000:]})
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_label}"
    if step_kind == "importance":
        fname += "__importance"
    with open(os.path.join(out_dir, fname + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--importance-cell", action="store_true",
                    help="lower the joint-importance (n+1 pass) step for the "
                         "paper-representative arch")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--no-shard-seq", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline paths: xla_scan flash "
                         "(stored attention residuals), global MoE dispatch, "
                         "no wkv chunk remat")
    args = ap.parse_args()

    if args.baseline:
        from repro.models import attention as _attn
        from repro.models import moe as _moe
        from repro.models import recurrent as _rec
        _attn.FLASH_IMPL = "xla_scan"
        _moe.GROUP_LOCAL_DISPATCH = False
        _rec.WKV_REMAT = False

    archs = list(list_archs()) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    if args.list:
        for a in archs:
            cfg = get_config(a)
            for s in shapes:
                ok, why = shape_applicable(cfg, SHAPES_BY_NAME[s])
                print(f"{a:24s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    build_kw = dict(remat=not args.no_remat, zero_shard=not args.no_zero,
                    shard_seq=False if args.no_shard_seq else "auto")
    if args.importance_cell:
        rec = run_cell("qwen3-0.6b", "train_4k", meshes[0],
                       step_kind="importance", out_dir=args.out,
                       save_hlo=args.save_hlo, **build_kw)
        print(json.dumps(rec, indent=2)[:2000])
        return

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mesh_name, out_dir=args.out,
                               save_hlo=args.save_hlo, **build_kw)
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']:10s} "
                             f"comp={r['compute_s']*1e3:8.2f}ms "
                             f"mem={r['memory_s']*1e3:8.2f}ms "
                             f"coll={r['collective_s']*1e3:8.2f}ms "
                             f"temp={rec['memory']['temp_bytes']/2**30:6.2f}GiB "
                             f"compile={rec['compile_s']:6.1f}s")
                elif status == "error":
                    extra = rec["error"][:160]
                else:
                    extra = rec["reason"]
                print(f"[{status:7s}] {a:24s} {s:12s} {mesh_name:7s} {extra}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
