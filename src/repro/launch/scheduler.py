"""Request queue + admission policies for the continuous-batching engine.

The scheduler owns *which* request enters *which* slot *when*; the engine
(`repro.launch.engine`) owns the device state. Three policies:

* ``continuous`` — FIFO continuous batching: a finished sequence frees its
  slot immediately and the next arrived request is admitted mid-flight,
  subject to a per-iteration prefill-token budget (see below).
* ``continuous-sjf`` — same, but arrived requests admit shortest-prompt
  first (reduces head-of-line blocking under the token budget).
* ``fixed`` — the legacy fixed-batch path expressed as a policy: requests
  are admitted only when every slot is free, and the engine holds all slots
  until the whole round finishes — i.e. everything is padded to the round's
  max generation length.

Prefill/decode interleave
-------------------------
Every engine iteration grants the scheduler ``prefill_chunk`` tokens of
prefill bandwidth (the chunk comes from
``repro.dist.roofline.suggest_prefill_chunk``: the headroom between the
decode step's HBM/ICI ceiling and its compute term, i.e. how many
compute-bound prefill tokens ride along a memory-bound decode step for
free). Credit accrues while work is waiting, and a request is admitted
once its prompt cost is covered — a prompt longer than the chunk therefore
spreads its admission over ``ceil(prompt / chunk)`` iterations, which is
exactly the stall pattern of chunked prefill without needing a separate
multi-token cache-append kernel.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

POLICIES = ("continuous", "continuous-sjf", "fixed")


def bucket_length(n: int, min_bucket: int = 8) -> int:
    """Round a prompt length up to its power-of-two bucket (>= min_bucket).

    The engine pads bucketed prompts to this length so the jitted prefill
    compiles once per bucket instead of once per distinct prompt length —
    the recompile bound that matters once the quantized runtime jits per
    shape. Padding sits at the END of the prompt: causal attention means no
    real token ever attends a pad, logits are read at the true last
    position, and pad KV rows are invalidated
    (``lm.apply_prefill(true_len=...)``).
    """
    b = max(int(min_bucket), 1)
    while b < n:
        b *= 2
    return b


def prefix_chain_keys(tokens: np.ndarray, page_size: int) -> List[bytes]:
    """Page-aligned prefix-chain keys for the paged KV cache's shared-prefix
    registry: key ``j`` (0-based) hashes the first ``(j + 1) * page_size``
    prompt tokens, for every *complete* page the prompt fills. Two prompts
    share key ``j`` iff they agree on that whole page-aligned prefix, so
    the longest key hit names exactly the physical pages that can be
    re-mapped instead of re-prefilled (``kv_cache.PagePool``)."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    keys: List[bytes] = []
    for j in range(1, len(toks) // int(page_size) + 1):
        keys.append(hashlib.sha1(toks[: j * page_size].tobytes()).digest())
    return keys


class Request(NamedTuple):
    """One serving request: a prompt and a generation budget."""

    rid: int
    tokens: np.ndarray  # (P,) int32 prompt token ids
    max_new: int  # generation budget (>= 1; the prefill emits token 1)
    arrival: int = 0  # engine iteration at which the request becomes visible
    extra_inputs: Optional[Dict[str, Any]] = None  # e.g. VLM image features

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))


@dataclasses.dataclass
class Completion:
    """Engine output for one request."""

    rid: int
    prompt_len: int
    tokens: List[int]  # generated ids, length <= max_new
    admitted_at: int  # engine iteration of admission (prefill)
    finished_at: int  # engine iteration after which the sequence was done
    # self-speculative decoding bookkeeping (zero when speculate=0): how
    # many tokens the low-bit draft proposed while this request held its
    # slot, and how many of those the target policy confirmed — the
    # per-request acceptance rate the aggregate EngineStats.spec_* counters
    # cannot attribute
    spec_drafted: int = 0
    spec_accepted: int = 0
    # elastic serving: id of the packed policy variant that generated
    # every token of this request ("" when the engine serves one fixed
    # policy). Drain-then-swap means a single variant per request — the
    # attribution key for per-variant reference checks
    policy_id: str = ""


class Scheduler:
    """Admission policy over a request queue (see module docstring)."""

    def __init__(
        self,
        policy: str = "continuous",
        prefill_chunk: int = 128,
        metrics=None,
    ):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.policy = policy
        self.prefill_chunk = int(prefill_chunk)
        self.pending: List[Request] = []
        self._credit = 0
        # optional repro.obs.metrics.MetricsRegistry shared with the engine
        # (queue depth / banked prefill credit gauges, admission counter)
        self.metrics = metrics

    def _observe(self) -> None:
        if self.metrics is not None:
            self.metrics.gauge("scheduler.queue_depth").set(len(self.pending))
            self.metrics.gauge("scheduler.prefill_credit").set(self._credit)

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if not self.pending:
            # a fresh wave after the queue drained must not inherit credit
            # banked by the previous wave (admit() is only called while work
            # is pending, so it cannot clear this itself)
            self._credit = 0
        self.pending.append(req)
        self._observe()

    def has_pending(self) -> bool:
        return bool(self.pending)

    def _arrived(self, now: int) -> List[Request]:
        arrived = [r for r in self.pending if r.arrival <= now]
        if self.policy == "continuous-sjf":
            arrived.sort(key=lambda r: (r.prompt_len, r.rid))
        return arrived

    # -- policy -------------------------------------------------------------
    @property
    def hold_round(self) -> bool:
        """Fixed-batch semantics: slots stay occupied until the whole round
        is done (the engine pads every sequence to the round max)."""
        return self.policy == "fixed"

    def admit(
        self,
        now: int,
        free_slots: List[int],
        occupied: int,
        page_budget: Optional[int] = None,
        page_need: int = 0,
        hold: bool = False,
    ) -> List[Tuple[Request, int]]:
        """Return [(request, slot)] to admit at iteration ``now``.

        ``page_budget``/``page_need`` are the paged-KV pressure check:
        the engine passes the pool's worst-case obtainable pages
        (``PagePool.available_count``, free + LRU-evictable) and one
        admission's worst-case page need. Continuous policies stop
        admitting once the next admission could exhaust the pool —
        deferring FIFO order rather than skipping ahead — and count each
        deferral round in ``scheduler.admissions_deferred_pool``. The
        fixed policy admits whole rounds into a pool sized for all
        slots, so it ignores the budget.

        ``hold=True`` is the elastic engine's drain-then-swap gate: a
        pending policy hot-swap admits nothing this round (in-flight
        slots must drain under the variant that admitted them). Prefill
        credit still accrues while work waits, and each held round is
        counted in ``scheduler.admissions_deferred_swap`` so the stats
        show what the swap cost in admission latency.
        """
        if hold:
            if self._arrived(now):
                self._credit += self.prefill_chunk
                if self.metrics is not None:
                    self.metrics.counter(
                        "scheduler.admissions_deferred_swap",
                        help="admission rounds held while a policy swap "
                        "drains",
                    ).inc()
            self._observe()
            return []
        if self.policy == "fixed":
            if occupied:
                return []
            picks = self._arrived(now)[: len(free_slots)]
            self._drop(picks)
            if self.metrics is not None and picks:
                self.metrics.counter("scheduler.admitted").inc(len(picks))
            self._observe()
            return list(zip(picks, free_slots))

        # continuous: accrue prefill credit only while work is waiting
        arrived = self._arrived(now)
        if arrived:
            self._credit += self.prefill_chunk
        else:
            self._credit = 0
        out: List[Tuple[Request, int]] = []
        free = list(free_slots)
        budget = page_budget
        for r in arrived:
            if not free or self._credit < r.prompt_len:
                break
            if budget is not None and page_need > budget:
                if self.metrics is not None:
                    self.metrics.counter(
                        "scheduler.admissions_deferred_pool",
                        help="admission rounds deferred on page-pool "
                        "pressure",
                    ).inc()
                break
            if budget is not None:
                budget -= page_need
            self._credit -= r.prompt_len
            out.append((r, free.pop(0)))
        self._drop([r for r, _ in out])
        if self.metrics is not None and out:
            self.metrics.counter("scheduler.admitted").inc(len(out))
        self._observe()
        return out

    def _drop(self, picks: List[Request]) -> None:
        # removal by identity: list.remove would compare Request tuples,
        # and equality on the np.ndarray tokens field raises/ambiguates
        taken = {id(r) for r in picks}
        self.pending = [p for p in self.pending if id(p) not in taken]
