"""Training driver (CPU-runnable end to end; mesh-ready by construction).

Three modes mirroring the paper's pipeline (§4.1):

  importance  — joint n+1-pass indicator training (paper §3.4)
  qat         — finetune with a searched policy active (or uniform bits)
  fp          — full-precision baseline

Fault tolerance: atomic async checkpoints every --ckpt-every steps,
auto-resume from the latest step, straggler watchdog, deterministic
skip-to-step data (no replay needed after restart).

Example:
  python -m repro.launch.train --arch limpq-demo --mode importance --steps 50
  python -m repro.launch.train --arch limpq-demo --mode qat \
      --policy experiments/policy.json --steps 200
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import optim, training
from repro.checkpoint import CheckpointManager, StepWatchdog
from repro.configs import get_config, smoke_config
from repro.core import importance as imp
from repro.core.policy import MPQPolicy
from repro.data import SyntheticLM
from repro.dist.axes import NO_AXES
from repro.models import lm
from repro.models.quant_layers import QuantContext, fp_context


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="limpq-demo")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config of --arch")
    ap.add_argument("--mode", default="qat",
                    choices=["importance", "qat", "fp"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--policy", default=None,
                    help="MPQPolicy json for qat mode (default: uniform 4b)")
    ap.add_argument("--uniform-bits", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-freeze-backbone", action="store_true")
    ap.add_argument("--save-indicators", default=None)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = lm.init_params(rng, cfg)
    data = SyntheticLM(cfg)
    ctx = (fp_context(jnp.float32) if args.mode == "fp"
           else QuantContext.make(cfg.bits, cfg.quant_act_signed,
                                  compute_dtype=jnp.float32))

    # ---- bits -------------------------------------------------------------
    bits = None
    if args.mode == "qat":
        ql = lm.enumerate_qlayers(cfg)
        if args.policy:
            policy = MPQPolicy.load(args.policy)
        else:
            policy = MPQPolicy.uniform(ql, args.uniform_bits)
        bits = lm.bits_from_policy(cfg, policy, ql)

    # ---- optimizer + step ---------------------------------------------------
    if args.mode == "importance":
        lr = args.lr if args.lr is not None else 0.01
        opt = imp.importance_optimizer(
            lr, freeze_backbone=not args.no_freeze_backbone)
        step_fn = jax.jit(imp.make_importance_step(cfg, ctx, opt, NO_AXES,
                                                   remat=False))
    else:
        lr = args.lr if args.lr is not None else 3e-3
        opt = optim.adamw(optim.cosine_warmup(lr, args.steps // 20 + 1,
                                              args.steps),
                          weight_decay=2.5e-5, clip_norm=1.0)
        step_fn = jax.jit(training.make_train_step(cfg, ctx, opt, bits,
                                                   NO_AXES, remat=False))
    opt_state = opt.init(params)

    # ---- checkpoint / resume -----------------------------------------------
    mgr = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_n=3)
        latest = mgr.latest_step()
        if latest is not None:
            params = mgr.restore(latest, params)
            opt_state = mgr.restore_opt(latest, opt_state) \
                if hasattr(mgr, "restore_opt") else opt_state
            start = latest + 1
            print(f"resumed from step {latest}")

    wd = StepWatchdog()
    srng = jax.random.PRNGKey(args.seed + 1)
    t_start = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(step, args.batch, args.seq).items()}
        t0 = time.time()
        if args.mode == "importance":
            srng, sub = jax.random.split(srng)
            params, opt_state, m = step_fn(params, opt_state, batch, sub)
            loss = float(jnp.mean(m["loss_uniform"]))
        else:
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
        dt = time.time() - t0
        if wd.observe(dt):
            print(f"[watchdog] step {step} straggled: {dt:.2f}s")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  {dt*1e3:7.1f} ms")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step, params, meta={"arch": cfg.name, "mode": args.mode})
    if mgr:
        mgr.save(args.steps - 1, params,
                 meta={"arch": cfg.name, "mode": args.mode}, blocking=True)

    if args.mode == "importance" and args.save_indicators:
        ql = lm.enumerate_qlayers(cfg)
        ind = imp.extract_indicators(params, cfg, ql)
        with open(args.save_indicators, "w") as f:
            json.dump({k: {"w": v["w"].tolist(), "a": v["a"].tolist()}
                       for k, v in ind.items()}, f, indent=1)
        print(f"indicators -> {args.save_indicators}")
    print(f"total {time.time()-t_start:.1f}s")
    return params


if __name__ == "__main__":
    main()
