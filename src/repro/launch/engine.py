"""Continuous-batching decode engine (ROADMAP item 3).

The engine turns a model's prefill/decode passes into a servable system:
``slots`` concurrent sequences share one jitted decode step over per-slot
KV caches (``init_decode_state(per_slot=True)`` — the slot axis is what
``dist.sharding.decode_state_specs`` shards over ``dp``), and a
``repro.launch.scheduler.Scheduler`` decides admission. A finished
sequence frees its slot mid-flight, so a staggered workload completes in
strictly fewer decode steps than padding everything to the max length.

The model behind the engine is pluggable: a *model adapter* supplies
``prefill`` / ``decode`` / ``init_state`` / ``state_per_slot``. The default
``LMAdapter`` is the fake-quant ``repro.models.lm`` graph; the quantized
serving runtime (``repro.runtime.session.QuantizedSession``) is the
packed-weights implementation of the same interface, which is how
``serve --policy`` runs a searched ``MPQPolicy`` through this engine
unchanged.

Execution model (host loop, three jitted device functions):

* ``prefill``  — one request at a time, whole prompt, ``prefill_cap`` sized
  to the slot's cache. Recompiles per distinct prompt length (the jit cache
  keys on shape); ``EngineConfig.bucket_prompts`` rounds prompts up to
  power-of-two buckets (``scheduler.bucket_length``) so at most
  ``log2(cache_len)`` shapes ever compile — pad tokens sit after the
  prompt, logits read at the true last position, pad KV rows invalidated.
* ``insert``  — writes the prefilled per-layer state into slot row ``i``
  (``dynamic_update_slice`` on the slot axis; axis 1 for body-stacked
  segments, axis 0 elsewhere).
* ``decode``  — one token for all slots at once with a per-slot position
  vector. Free slots ride along at position -1: their row writes land with
  position -1 (never valid to attend), so an evicted slot can never leak KV
  entries into a later occupant — admission overwrites the whole row anyway.

``EngineConfig.kv_quant`` flips the per-slot KV caches to int8 codes with
per-head write-time scales (``repro.runtime.kv_cache``), halving decode
HBM traffic per cache element. How the cache is *attended* routes through
``runtime.dispatch.resolve_decode_attn`` (fused Pallas kernel on codes vs
the dequant-fp fallback); the engine resolves the route once at build
(``stats.decode_attn_route``) and the roofline-driven prefill budget
charges the matching bytes through ``decode_step_cost(kv_bits=8,
kv_attend=...)`` — "int8 stored but fp-attended" costs more than "int8
attended" and the budget reflects which one this process actually runs.

Mesh execution: when ``axes`` carries a real mesh (``dist.sharding
.make_axes_for``), the engine resolves partition specs once at build —
params through the adapter's ``param_specs()`` hook (``packed_specs`` for
a quantized session: sub-byte ``codes`` shard over ``tp`` instead of
replicating) falling back to ``dist.sharding.param_specs``, and the
per-slot decode state (fp or int8 KV) through ``decode_state_specs`` —
``device_put``s both onto the mesh, and jits prefill/insert/decode/evict
with explicit ``in_shardings``/``out_shardings``. Under ``NO_AXES`` (or a
trivial host ``(1,)`` mesh) the same code path degenerates to the
single-device behavior bit-exactly.

Inactive slots still occupy compute (the decode batch is static — standard
for continuous-batching engines); the win is scheduling, measured by
``EngineStats.decode_steps`` / ``slot_steps``.

Observability (``repro.obs``): every engine owns a
``MetricsRegistry`` (``engine.metrics``) and a ``TraceRecorder``
(``engine.trace``). Counters/gauges/histograms are the source of truth —
``engine.stats`` is a *snapshot* property that renders the registry into
an ``EngineStats`` (so a captured ``stats`` object stays frozen across
``reset()``), and ``as_dict()`` carries the TTFT / inter-token-latency
percentiles the histograms accumulate. Each request traces its lifecycle
(``admit`` → ``prefill`` span → ``first_token`` → per-decode-tick
``token`` instants → ``complete``/``evict``); phase timers use
``time.perf_counter`` and stamp only after ``jax.block_until_ready`` on
the FULL output tree (logits *and* the new cache state), so async cache
writes can never leak into the next phase's timing. ``serve
--trace-out`` exports the trace as JSONL or Chrome-trace/Perfetto.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist import roofline
from repro.dist.axes import NO_AXES, MeshAxes
from repro.launch.scheduler import (
    Completion,
    Request,
    Scheduler,
    bucket_length,
    prefix_chain_keys,
)
from repro.models import attention as attn
from repro.models import lm
from repro.runtime import kv_cache as qkv
from repro.obs import health as obs_health
from repro.obs import metrics as obs_metrics
from repro.obs import monitor as obs_monitor
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class EngineConfig:
    """Engine knobs (see README "Serving" for the full story)."""

    slots: int = 4  # concurrent sequences
    cache_len: int = 64  # per-slot KV capacity (prompt + generation)
    prefill_chunk: int = 0  # prefill tokens per iteration; 0 = roofline auto
    policy: str = "continuous"  # continuous | continuous-sjf | fixed
    eos_id: Optional[int] = None  # optional early-stop token id
    state_dtype: Any = jnp.float32
    max_iters: int = 100_000  # hard stop for the host loop
    chip: roofline.ChipSpec = roofline.DEFAULT_CHIP
    kv_quant: str = "none"  # "none" | "int8" | "fake" (reference numerics)
    kv_layout: str = "ring"  # "ring" | "paged" (pooled pages + prefix reuse)
    page_size: int = 8  # tokens per KV page (paged layout only)
    bucket_prompts: bool = False  # pow-2 prompt padding to bound re-jits
    bucket_min: int = 8  # smallest prompt bucket
    trace: bool = True  # record the per-request lifecycle event trace
    health_every: int = 4  # KV-scale drift sample stride (decode steps; 0 off)
    speculate: int = 0  # self-speculative draft length k (0 = off)


@dataclasses.dataclass
class EngineStats:
    """A frozen-on-read snapshot of the engine's metrics registry.

    The engine never mutates an ``EngineStats`` — instrumented call sites
    write ``engine.metrics`` counters/gauges/histograms and the ``stats``
    property renders this view on access. ``latency`` carries the
    percentile summary of the TTFT / inter-token / per-phase histograms
    and is flattened into ``as_dict()``.
    """

    iterations: int = 0  # scheduler ticks (admission and/or decode)
    decode_steps: int = 0  # jitted decode launches
    slot_steps: int = 0  # sum over decode steps of slots emitting a token
    padded_slot_steps: int = 0  # sum of *occupied* slots (fixed pads to max)
    prefill_calls: int = 0
    prefill_tokens: int = 0
    prefill_compiles: int = 0  # distinct prompt shapes fed to the jit cache
    act_quant_reused: int = 0  # activation quantize ops elided per compile
    decode_attn_route: str = "fp"  # fused | fused-interpret | dequant-fp | fp
    admitted: int = 0
    completed: int = 0
    tokens_generated: int = 0
    prefill_flops_saved: float = 0.0  # MACs*2 skipped via shared-prefix pages
    prefix_hit_tokens: int = 0  # prompt tokens served by page-table remaps
    kv_unique_pages: int = 0  # paged layout: distinct physical pages mapped
    admissions_deferred_pool: int = 0  # admit rounds held on page pressure
    alerts_fired: int = 0  # monitor threshold trips this epoch
    spec_rounds: int = 0  # draft+verify rounds (speculate > 0)
    spec_draft_tokens: int = 0  # tokens the low-bit draft policy proposed
    spec_accepted_tokens: int = 0  # proposals the target policy confirmed
    policy_swaps: int = 0  # elastic variant hot-swaps applied this epoch
    policy_swaps_down: int = 0  # swaps that lowered the served avg bits
    ilp_solves: int = 0  # admission-time MCKP re-solves (elastic)
    admissions_deferred_swap: int = 0  # admit rounds held for a swap drain
    active_policy: str = ""  # serving variant id ("" = single-policy)
    t_prefill_s: float = 0.0
    t_decode_s: float = 0.0
    latency: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def decode_tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.t_decode_s, 1e-9)

    @property
    def total_tokens_per_s(self) -> float:
        total = self.tokens_generated + self.prefill_tokens
        return total / max(self.t_decode_s + self.t_prefill_s, 1e-9)

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of drafted tokens the target verified (greedy match)."""
        return self.spec_accepted_tokens / max(self.spec_draft_tokens, 1)

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(d.pop("latency"))
        d["decode_tokens_per_s"] = self.decode_tokens_per_s
        d["total_tokens_per_s"] = self.total_tokens_per_s
        d["spec_accept_rate"] = self.spec_accept_rate
        return d


class LMAdapter:
    """Default model adapter: the fake-quant ``repro.models.lm`` graph.

    Anything exposing this interface (plus the optional ``kv_quant`` /
    ``w_bits_total`` accounting attributes) can serve through the engine —
    see ``repro.runtime.session.QuantizedSession`` for the packed
    mixed-precision implementation.

    Elastic serving (``DecodeEngine(elastic=...)``) needs the optional
    variant-bank extension of this seam — ``active_policy`` naming the
    serving variant plus ``set_active(pid)`` / ``params_for(pid)``
    returning pre-packed trees (``runtime.session.ElasticSession``). The
    default single-policy adapters leave ``active_policy`` empty and
    carry no bank.
    """

    active_policy = ""  # single policy per process: nothing to attribute

    def __init__(self, cfg: ModelConfig, bits, ctx, axes: MeshAxes = NO_AXES):
        self.cfg = cfg
        self.bits = bits
        self.ctx = ctx
        self.axes = axes

    @property
    def kv_quant(self) -> str:
        return self.ctx.kv_quant

    @property
    def w_bits_total(self) -> Optional[float]:
        return None  # fp/fake-quant weights: roofline uses avg_weight_bits

    def prefill(self, params, inputs, *, prefill_cap, true_len=None):
        return lm.apply_prefill(
            params,
            self.cfg,
            inputs,
            self.bits,
            self.ctx,
            self.axes,
            prefill_cap=prefill_cap,
            true_len=true_len,
        )

    def decode(self, params, tok, pos, state):
        return lm.apply_decode(
            params, self.cfg, tok, pos, state, self.bits, self.ctx, self.axes
        )

    def init_state(self, batch, capacity, dtype, per_slot=True):
        return lm.init_decode_state(
            self.cfg,
            batch,
            capacity,
            dtype=dtype,
            per_slot=per_slot,
            kv_quant="int8" if self.ctx.kv_quant == "int8" else "none",
        )

    def state_per_slot(self, row):
        return lm.decode_state_per_slot(row)


class _Slot:
    """Host-side bookkeeping for one engine slot."""

    __slots__ = (
        "req",
        "next_tok",
        "next_pos",
        "gen",
        "done",
        "admitted_at",
        "ts_admit",
        "ts_last_token",
        "spec_drafted",
        "spec_accepted",
        "policy_id",
    )

    def __init__(
        self,
        req: Request,
        first_tok: int,
        now: int,
        ts_admit: float = 0.0,
        ts_last_token: float = 0.0,
        policy_id: str = "",
    ):
        self.req = req
        self.next_tok = first_tok
        self.next_pos = req.prompt_len
        self.gen: List[int] = [first_tok]
        self.done = False
        self.admitted_at = now
        self.ts_admit = ts_admit  # trace-clock stamp of the admit event
        self.ts_last_token = ts_last_token  # last emitted token (ITL base)
        self.spec_drafted = 0  # draft proposals made for this slot
        self.spec_accepted = 0  # proposals the target policy confirmed
        # elastic serving: the variant that admitted this request keeps
        # serving it to completion (drain-then-swap), so one id covers
        # every token
        self.policy_id = policy_id


class DecodeEngine:
    """Slot-based continuous-batching decode engine over a quantized LM."""

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        bits,
        ctx,
        axes: MeshAxes = NO_AXES,
        ecfg: Optional[EngineConfig] = None,
        scheduler: Optional[Scheduler] = None,
        adapter=None,
        elastic=None,
    ):
        if cfg.encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg or EngineConfig()
        if adapter is None:
            if self.ecfg.kv_quant != "none" and ctx.kv_quant == "none":
                ctx = dataclasses.replace(ctx, kv_quant=self.ecfg.kv_quant)
            adapter = LMAdapter(cfg, bits, ctx, axes)
        self.adapter = adapter

        kv_mode = getattr(adapter, "kv_quant", self.ecfg.kv_quant)
        from repro.runtime import dispatch as _dispatch

        _dispatch.ROUTES.validate("kv_layout", self.ecfg.kv_layout)
        self._paged = self.ecfg.kv_layout == "paged"
        self.layout: Optional[qkv.KVCacheLayout] = None
        self.pool: Optional[qkv.PagePool] = None
        if self._paged:
            # the paged layout is the packed int8 serving path: pooled int8
            # pages, a slot -> page-list table, and chunked append prefill
            if kv_mode != "int8":
                raise ValueError(
                    f"kv_layout='paged' requires int8 KV (got {kv_mode!r}):"
                    " pages hold codes + scales"
                )
            if not hasattr(adapter, "append"):
                raise ValueError(
                    "kv_layout='paged' needs an append-capable adapter "
                    "(QuantizedSession); the fake-quant LMAdapter serves "
                    "through the ring layout"
                )
            if cfg.sliding_window or cfg.local_window:
                raise ValueError(
                    "kv_layout='paged' does not support sliding-window "
                    "archs: a window evicts mid-page, breaking page sharing"
                )
            if axes.enabled:
                raise ValueError(
                    "kv_layout='paged' is single-device for now: the page "
                    "pool id space is not mesh-sharded"
                )
            self.layout = qkv.KVCacheLayout(
                kind="paged", quant="int8", page_size=self.ecfg.page_size
            )
            self._pages_per_slot = self.layout.pages_per_slot(
                self.ecfg.cache_len
            )
            # FLOPs one prompt token costs across every quantized matmul —
            # what a shared-prefix page-table hit avoids recomputing
            self._flops_per_token = 2.0 * sum(
                q.macs_per_token * q.n_mats for q in lm.enumerate_qlayers(cfg)
            )
        self._spec_k = int(self.ecfg.speculate or 0)
        self.draft_params = getattr(adapter, "draft_params", None)
        if self._spec_k:
            # self-speculative decoding: the adapter must carry the dual
            # pack (runtime.session.SpecSession) and the schedule must be
            # rollback-safe — rejecting a draft token rewinds KV rows by
            # position, which only attention caches support
            if not hasattr(adapter, "verify") or self.draft_params is None:
                raise ValueError(
                    "speculate > 0 needs a dual-policy adapter "
                    "(runtime.session.SpecSession): a draft_params tree to "
                    "propose tokens and a verify() pass to confirm them"
                )
            if axes.enabled:
                raise ValueError(
                    "speculate > 0 is single-device for now: the draft/"
                    "verify interleave donates one state across two jits"
                )
            bad = {s.kind for s in lm.iter_sites(cfg)} - {"attn", "dense", "moe"}
            if bad:
                raise ValueError(
                    f"speculate > 0 requires an attention-only schedule: "
                    f"{sorted(bad)} state is sequential and cannot roll "
                    "back past a rejected draft token"
                )
            if cfg.sliding_window or cfg.local_window:
                raise ValueError(
                    "speculate > 0 does not support sliding-window archs: "
                    "the ring window overwrites rows a rollback would need"
                )
        # elastic serving: an ElasticController re-solves the ILP at
        # admission time and this engine hot-swaps the active pre-packed
        # variant between batches (drain-then-swap; _elastic_admission)
        self.elastic = elastic
        self._active_policy = str(getattr(adapter, "active_policy", "") or "")
        self._swap_decision = None
        self._deferred_seen = 0
        if elastic is not None:
            _dispatch.ROUTES.validate("elastic", "bank")
            if not (
                hasattr(adapter, "set_active") and hasattr(adapter, "params_for")
            ):
                raise ValueError(
                    "elastic serving needs a variant-bank adapter "
                    "(runtime.session.ElasticSession): set_active()/"
                    "params_for() hand back pre-packed policy variants; a "
                    "single-policy adapter has nothing to hot-swap"
                )
            if axes.enabled:
                raise ValueError(
                    "elastic serving is single-device for now: a swap would "
                    "have to re-place every packed shard on the mesh"
                )
            if self._spec_k:
                raise ValueError(
                    "elastic + speculate is unsupported: the draft pack is "
                    "derived from ONE target policy and would go stale at "
                    "the first swap"
                )
        kv_bits = (
            8.0
            if kv_mode == "int8"
            else 8.0 * np.dtype(self.ecfg.state_dtype).itemsize
        )
        # which route decode attention takes over the int8 cache: resolved
        # once here for the roofline budget and the stats/bench trail (the
        # jitted decode resolves the same dispatch at trace time, so a
        # force_decode_attn scope must wrap build AND first run)
        if kv_mode == "int8":
            from repro.runtime import dispatch as _dispatch

            self.decode_attn_route = _dispatch.resolve_decode_attn()
        else:
            self.decode_attn_route = "fp"
        kv_attend = (
            "fused" if self.decode_attn_route.startswith("fused") else "dequant"
        )
        # the roofline budget shape, kept for obs.calibrate to replay the
        # measured timings against the same model the engine planned with
        self.kv_bits = float(kv_bits)
        self.kv_attend = kv_attend
        chunk = self.ecfg.prefill_chunk or roofline.suggest_prefill_chunk(
            cfg,
            self.ecfg.slots,
            cache_tokens=self.ecfg.cache_len,
            kv_bits=kv_bits,
            kv_attend=kv_attend,
            w_bits_total=getattr(adapter, "w_bits_total", None),
            # a speculating engine's iteration is a whole draft+verify
            # round, so the per-iteration prefill headroom must be
            # budgeted against the round cost, not a single-token step
            spec_k=self._spec_k,
            draft_w_bits=float(getattr(adapter, "draft_w_bits", 2.0)),
            chip=self.ecfg.chip,
        )
        self.prefill_chunk = int(chunk)
        self._init_obs()
        self.scheduler = scheduler or Scheduler(
            self.ecfg.policy, self.prefill_chunk, metrics=self.metrics
        )
        # the adapter's reuse counter is lifetime-cumulative across every
        # trace it ever ran; stats report the delta since this engine's
        # build (reset() re-snapshots), i.e. ops elided by THIS engine's
        # compiles
        self._act_reuse_base = getattr(adapter, "act_quant_reused", 0)
        self.slots: List[Optional[_Slot]] = [None] * self.ecfg.slots
        self.completions: Dict[int, Completion] = {}
        self.axes = axes
        self._mesh = axes.mesh if axes.enabled else None
        self._param_shardings = None
        self._state_shardings = None
        if self._mesh is not None:
            from repro.dist import sharding as shd

            spec_fn = getattr(adapter, "param_specs", None)
            pspecs = spec_fn() if spec_fn else shd.param_specs(cfg, self.params, axes)
            self._param_shardings = shd.named(self._mesh, pspecs)
            # named once at build: packed codes/scales land on their tp
            # shards, everything else on its megatron home, before any jit
            self.params = jax.device_put(self.params, self._param_shardings)
        self.state = self._fresh_state()
        self._set_cache_gauges()

        # prompt-length bucketing bounds prefill recompiles, but padded
        # prompt tokens would perturb recurrent state (rwkv/rec scans run
        # over them) and sliding-window caches (pads evict real rows), so
        # it only engages for full-attention schedules
        self._bucket = bool(self.ecfg.bucket_prompts)
        if self._paged:
            # chunked-append prefill already bounds compiles to ONE chunk
            # shape — bucketing would only pad for no benefit
            self._bucket = False
        if self._bucket:
            kinds = {s.kind for s in lm.iter_sites(cfg)}
            windowed = bool(cfg.sliding_window or cfg.local_window)
            if (kinds & {"rwkv", "rec"}) or windowed:
                self._bucket = False
        self._prefill_shapes: set = set()

        cache_len = self.ecfg.cache_len

        if self._bucket:

            def prefill(p, inputs, true_len):
                return adapter.prefill(
                    p, inputs, prefill_cap=cache_len, true_len=true_len
                )

        else:

            def prefill(p, inputs):
                return adapter.prefill(p, inputs, prefill_cap=cache_len)

        def decode(p, tok, pos, state):
            return adapter.decode(p, tok, pos, state)

        def insert(full, row, slot):
            def one(path, f, r):
                seg = str(getattr(path[0], "key", path[0]))
                axis = 1 if seg == "body" else 0
                return jax.lax.dynamic_update_slice_in_dim(
                    f, r.astype(f.dtype), slot, axis=axis
                )

            return jax.tree_util.tree_map_with_path(one, full, row)

        def evict(state, slot):
            def one(c):
                if isinstance(c, qkv.PagedKVCache):
                    return c.evict(slot)  # unmap the table row; the pool
                    # frees + pos-clears the physical pages host-side
                if not isinstance(c, attn.CACHE_TYPES):
                    return c
                axis = c.pos.ndim - 2  # slot axis: 0 plain, 1 body-stacked
                empty_shape = list(c.pos.shape)
                empty_shape[axis] = 1
                empty = jnp.full(empty_shape, -1, jnp.int32)
                pos = jax.lax.dynamic_update_slice_in_dim(
                    c.pos, empty, slot, axis=axis
                )
                return c._replace(pos=pos)

            return jax.tree.map(
                one, state, is_leaf=lambda x: isinstance(x, attn.CACHE_TYPES)
            )

        def _paged_only(fn):
            def apply(state, *args):
                return jax.tree.map(
                    lambda c: fn(c, *args)
                    if isinstance(c, qkv.PagedKVCache)
                    else c,
                    state,
                    is_leaf=lambda x: isinstance(x, attn.CACHE_TYPES),
                )

            return apply

        map_slot = _paged_only(lambda c, slot, row: c.map_slot(slot, row))
        free_pages = _paged_only(lambda c, ids: c.free_pages(ids))

        def append(p, tok, qpos, slot, last_idx, state):
            return adapter.append(p, tok, qpos, slot, last_idx, state)

        if self._mesh is None:
            self._prefill = jax.jit(prefill)
            self._decode = jax.jit(decode, donate_argnums=(3,))
            self._insert = jax.jit(insert, donate_argnums=(0,))
            self._evict = jax.jit(evict, donate_argnums=(0,))
            self._map_slot = jax.jit(map_slot, donate_argnums=(0,))
            self._free_pages = jax.jit(free_pages, donate_argnums=(0,))
            self._append = (
                jax.jit(append, donate_argnums=(5,)) if self._paged else None
            )
            self._spec_verify = jax.jit(
                self._spec_verify_fn, donate_argnums=(5,)
            )
            self._spec_draft_jits: Dict[int, Any] = {}
            self._spec_fused_jits: Dict[int, Any] = {}
        else:
            # explicit shardings end-to-end: params enter on their specs,
            # the decode state's slot axis stays pinned over dp across the
            # donate chain, and decode logits come back replicated for the
            # host-side argmax
            from jax.sharding import NamedSharding, PartitionSpec as P

            ps, ss = self._param_shardings, self._state_shardings
            rep = NamedSharding(self._mesh, P())
            pre_in = (ps, None, None) if self._bucket else (ps, None)
            self._prefill = jax.jit(prefill, in_shardings=pre_in)
            self._decode = jax.jit(
                decode,
                donate_argnums=(3,),
                in_shardings=(ps, None, None, ss),
                out_shardings=(rep, ss),
            )
            self._insert = jax.jit(
                insert,
                donate_argnums=(0,),
                in_shardings=(ss, None, None),
                out_shardings=ss,
            )
            self._evict = jax.jit(
                evict,
                donate_argnums=(0,),
                in_shardings=(ss, None),
                out_shardings=ss,
            )
            self._map_slot = self._free_pages = self._append = None
            self._spec_verify = None
            self._spec_draft_jits = {}
            self._spec_fused_jits = {}

    # -- observability -------------------------------------------------------
    def _init_obs(self) -> None:
        """Fresh metrics registry + trace recorder for one serving epoch.

        Counters are monotonic *within* an epoch; ``reset()`` starts a new
        epoch with a new registry, so any previously captured
        ``EngineStats`` snapshot (and the old registry itself) stays
        frozen instead of being rewound.
        """
        self.metrics = obs_metrics.MetricsRegistry()
        self.trace = obs_trace.TraceRecorder() if self.ecfg.trace else None
        m = self.metrics
        m.gauge(
            "engine.slots", help="configured concurrent-sequence capacity"
        ).set(self.ecfg.slots)
        m.gauge("engine.prefill_chunk").set(self.prefill_chunk)
        if self.ecfg.speculate:
            m.gauge(
                "engine.speculate", help="self-speculative draft length k"
            ).set(self.ecfg.speculate)
        # registry-side route record; the string itself stays on
        # self.decode_attn_route / EngineStats.decode_attn_route
        m.counter(f"engine.decode_attn_route.{self.decode_attn_route}").inc()
        # the adapter shares the registry so runtime.dispatch can count
        # routes chosen / activation-reuse hits at trace time
        if hasattr(self.adapter, "metrics"):
            self.adapter.metrics = self.metrics
        if hasattr(self.adapter, "packed_bytes"):
            m.gauge(
                "engine.packed_bytes", help="resident packed weight codes"
            ).set(self.adapter.packed_bytes())
        if hasattr(self.adapter, "scale_bytes"):
            m.gauge("engine.scale_bytes").set(self.adapter.scale_bytes())
        # pack-time quantization health (QuantizedSession computes it once
        # at build from the materialized weights; publishing per epoch keeps
        # every registry self-contained for snapshots/streaming)
        pack_health = getattr(self.adapter, "pack_health", None)
        if pack_health:
            obs_health.publish_pack_health(m, pack_health)
        self._kv_drift = obs_health.KVScaleDrift()
        # threshold watchers: alerts land in this registry (alerts.fired)
        # and, as `alert` instants, in the trace. The pool watcher reads
        # available pages (free + LRU-evictable) — free_count alone would
        # cry wolf whenever the prefix registry is merely full, while an
        # admission could still evict its way to a full slot's pages.
        self.monitor = obs_monitor.default_monitor(
            pool_min_free=(self._pages_per_slot - 1) if self._paged else None
        )
        # elastic epoch state: a pending (unapplied) swap decision and the
        # page-pool deferral watermark the controller diffs against
        self._swap_decision = None
        self._deferred_seen = 0
        if self.elastic is not None:
            m.gauge(
                "engine.policy_variants",
                help="pre-packed policy variants resident in the bank",
            ).set(len(self.adapter.variants))
            self._observe_active_policy()
            if self.trace is not None:
                # seed the swap-epoch timeline: reconcile validates every
                # policy-stamped token against the epoch active at its ts,
                # so epoch zero needs an explicit marker
                self.trace.instant(
                    "policy_swap",
                    to=self._active_policy,
                    initial=True,
                    iteration=-1,
                )
        # optional per-iteration callback (serve --metrics-stream); survives
        # reset() so a streamer set up once covers every epoch
        self.on_step = getattr(self, "on_step", None)

    def _set_cache_gauges(self) -> None:
        """Resident KV-cache inventory gauges (int8 caches; fp caches have
        no quantized inventory to itemize)."""
        inv = qkv.tree_inventory(self.state)
        m = self.metrics
        m.gauge(
            "engine.kv_cache_bytes", help="codes + scales + pos, all quantized caches"
        ).set(sum(inv.values()))
        for part, nbytes in inv.items():
            m.gauge(f"engine.kv_{part}_bytes").set(nbytes)
        if self._paged:
            m.gauge(
                "engine.kv_unique_pages",
                help="distinct physical pages currently referenced",
            ).set(self.pool.unique_pages_in_use)
            self._set_pool_gauges()

    def _set_pool_gauges(self) -> None:
        m = self.metrics
        m.gauge(
            "engine.kv_pool_free_pages", help="PagePool free-list length"
        ).set(self.pool.free_count)
        m.gauge(
            "engine.kv_pool_available_pages",
            help="free + LRU-evictable pages (admission headroom)",
        ).set(self.pool.available_count)

    # -- elastic precision serving ------------------------------------------
    def _observe_active_policy(self) -> None:
        m = self.metrics
        avg_w, _ = self.adapter.policy.avg_bits()
        m.gauge(
            "engine.active_policy_avg_bits",
            help="mean weight bits of the serving variant",
        ).set(avg_w)
        m.counter(f"engine.policy_active.{self._active_policy}").inc()
        # packed_bytes follows the active variant (ElasticSession accounting
        # swaps with set_active); refresh so the gauge tracks what serves
        m.gauge("engine.packed_bytes").set(self.adapter.packed_bytes())

    def _elastic_admission(self, now: int) -> None:
        """Consult the controller before admitting (drain-then-swap).

        Re-solves EVERY admission round with pending work — the decision
        self-corrects while slots drain, and the per-solve cost is the
        tens-of-ms the ``ilp.solve_ms`` histogram polices. A decision for
        a different variant swaps immediately if the slots are empty;
        otherwise it parks in ``_swap_decision``, which holds admission
        (``Scheduler.admit(hold=True)``) until the in-flight requests
        finish under the variant that admitted them. Decode itself never
        pauses, so the drain cannot deadlock."""
        m = self.metrics
        deferred_now = int(m.value("scheduler.admissions_deferred_pool"))
        arrived = sum(1 for r in self.scheduler.pending if r.arrival <= now)
        decision = self.elastic.decide(
            active=self._active_policy,
            queue_depth=arrived,
            occupied=len(self._occupied()),
            slots=self.ecfg.slots,
            deferred=max(deferred_now - self._deferred_seen, 0),
            cache_bytes=float(sum(qkv.tree_inventory(self.state).values())),
        )
        self._deferred_seen = deferred_now
        m.histogram(
            "ilp.solve_ms", help="admission-time MCKP re-solve wall time"
        ).observe(decision.solve_ms)
        m.counter("engine.ilp_solves").inc()
        if decision.target == self._active_policy:
            self._swap_decision = None
            return
        self._swap_decision = decision
        if not self._occupied():
            self._apply_swap(decision, now)

    def _apply_swap(self, decision, now: int) -> None:
        """Hot-swap the serving variant: ``device_put`` of the adapter's
        PRE-PACKED tree — never a repack. Runs only on drained slots, so
        every request's tokens come from exactly one variant."""
        assert not self._occupied(), "policy swap with occupied slots"
        t0 = time.perf_counter()
        self.params = jax.device_put(self.adapter.set_active(decision.target))
        jax.block_until_ready(self.params)
        dt = time.perf_counter() - t0
        prev, self._active_policy = self._active_policy, decision.target
        self._swap_decision = None
        m = self.metrics
        if self._paged:
            # registered prefix pages hold KV computed under the previous
            # variant's weights; a post-swap prefix hit would splice stale
            # numerics into a request that must match its own variant's
            # single-policy reference bit-for-bit
            self._clear_freed(self.pool.flush_prefixes())
            m.gauge("engine.kv_unique_pages").set(self.pool.unique_pages_in_use)
            self._set_pool_gauges()
        pols = self.adapter.variant_policies
        down = pols[decision.target].avg_bits()[0] < pols[prev].avg_bits()[0]
        m.counter("engine.policy_swaps").inc()
        m.counter(
            "engine.policy_swaps_down" if down else "engine.policy_swaps_up"
        ).inc()
        m.histogram("engine.swap_ms").observe(dt * 1e3)
        self._observe_active_policy()
        if self.trace is not None:
            self.trace.instant(
                "policy_swap",
                ts=self.trace.now(),
                to=decision.target,
                from_policy=prev,
                budget_bits=decision.budget_bits,
                solver=decision.solver,
                solve_ms=decision.solve_ms,
                report=decision.summary(),
                iteration=now,
            )

    @property
    def stats(self) -> EngineStats:
        """Render the metrics registry into a frozen ``EngineStats``
        snapshot (see the dataclass docstring)."""
        m = self.metrics

        def c(name: str) -> int:
            return int(m.value(f"engine.{name}"))

        lat: Dict[str, float] = {}
        for key in ("ttft", "itl", "decode_step", "prefill"):
            h = m.get(f"engine.{key}_ms")
            if isinstance(h, obs_metrics.Histogram) and h.count:
                lat[f"{key}_p50_ms"] = h.percentile(0.50)
                lat[f"{key}_p95_ms"] = h.percentile(0.95)
        solve = m.get("ilp.solve_ms")
        if isinstance(solve, obs_metrics.Histogram) and solve.count:
            lat["ilp_solve_p50_ms"] = solve.percentile(0.50)
            # percentile() clamps to the observed extremes, so 1.0 is the
            # exact max — the number the < 50 ms paper-claim gate reads
            lat["ilp_solve_max_ms"] = solve.percentile(1.0)
        return EngineStats(
            iterations=c("iterations"),
            decode_steps=c("decode_steps"),
            slot_steps=c("slot_steps"),
            padded_slot_steps=c("padded_slot_steps"),
            prefill_calls=c("prefill_calls"),
            prefill_tokens=c("prefill_tokens"),
            prefill_compiles=c("prefill_compiles"),
            act_quant_reused=c("act_quant_reused"),
            decode_attn_route=self.decode_attn_route,
            admitted=c("admitted"),
            completed=c("completed"),
            tokens_generated=c("tokens_generated"),
            prefill_flops_saved=m.value("engine.prefill_flops_saved"),
            prefix_hit_tokens=c("prefix_hit_tokens"),
            kv_unique_pages=c("kv_unique_pages"),
            admissions_deferred_pool=int(
                m.value("scheduler.admissions_deferred_pool")
            ),
            alerts_fired=int(m.value(obs_monitor.ALERTS_FIRED)),
            spec_rounds=int(m.value("spec.rounds")),
            spec_draft_tokens=int(m.value("spec.draft_tokens")),
            spec_accepted_tokens=int(m.value("spec.accepted_tokens")),
            policy_swaps=c("policy_swaps"),
            policy_swaps_down=c("policy_swaps_down"),
            ilp_solves=c("ilp_solves"),
            admissions_deferred_swap=int(
                m.value("scheduler.admissions_deferred_swap")
            ),
            active_policy=self._active_policy,
            t_prefill_s=m.value("engine.t_prefill_s"),
            t_decode_s=m.value("engine.t_decode_s"),
            latency=lat,
        )

    def _fresh_state(self):
        """Allocate the per-slot decode state and, under a mesh, place it
        on its resolved shardings (computed once, then reused by reset).
        The paged layout also rebuilds its host-side page pool here: pool
        and device state are one consistent unit (empty table, all free)."""
        self._slot_pages: List[Optional[List[int]]] = [None] * self.ecfg.slots
        kw = {}
        if self._paged:
            self.pool = qkv.PagePool(
                self.layout.pool_pages(self.ecfg.slots, self.ecfg.cache_len),
                self.ecfg.page_size,
            )
            kw["layout"] = self.layout
        state = self.adapter.init_state(
            self.ecfg.slots,
            self.ecfg.cache_len,
            dtype=self.ecfg.state_dtype,
            per_slot=True,
            **kw,
        )
        if self._mesh is not None:
            if self._state_shardings is None:
                from repro.dist import sharding as shd

                specs = shd.decode_state_specs(self.cfg, state, self.axes)
                self._state_shardings = shd.named(self._mesh, specs)
            state = jax.device_put(state, self._state_shardings)
        return state

    def reset(self, policy: Optional[str] = None) -> None:
        """Clear queue, slots, metrics/trace epoch, and decode state — but
        keep the jitted prefill/decode/insert/evict functions, so an engine
        can serve many request sets without recompiling. Previously
        captured ``stats`` snapshots (and the old registry/trace objects)
        stay frozen; the engine starts a fresh observability epoch."""
        self._init_obs()
        self.scheduler = Scheduler(
            policy or self.scheduler.policy,
            self.prefill_chunk,
            metrics=self.metrics,
        )
        self.slots = [None] * self.ecfg.slots
        self.completions = {}
        self._act_reuse_base = getattr(self.adapter, "act_quant_reused", 0)
        self.state = self._fresh_state()
        self._set_cache_gauges()

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Validate and enqueue a request."""
        if req.prompt_len < 1 or req.max_new < 1:
            raise ValueError(f"request {req.rid}: empty prompt or max_new < 1")
        in_flight = {s.req.rid for s in self.slots if s is not None}
        taken = in_flight | set(self.completions)
        taken.update(r.rid for r in self.scheduler.pending)
        if req.rid in taken:
            raise ValueError(
                f"request id {req.rid} already queued, running, or completed"
            )
        windowed = bool(self.cfg.sliding_window or self.cfg.local_window)
        if not windowed and req.prompt_len + req.max_new > self.ecfg.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + max_new "
                f"{req.max_new} exceeds cache_len {self.ecfg.cache_len} "
                "(full-attention arch cannot ring-wrap without changing "
                "results)"
            )
        self.scheduler.submit(req)

    def submit_all(self, reqs) -> None:
        for r in reqs:
            self.submit(r)

    # -- internals ----------------------------------------------------------
    def _clear_freed(self, freed: List[int]) -> None:
        """Clear device ``pos`` rows of pages whose refcount hit zero.
        Load-bearing: a recycled page keeping a previous occupant's ``pos``
        rows would be wrongly attendable the moment it is remapped. Ids are
        padded to a fixed (n_pages,) shape so this compiles once."""
        if not freed:
            return
        ids = np.full((self.pool.n_pages,), -1, np.int32)
        ids[: len(freed)] = freed
        self.state = self._free_pages(self.state, jnp.asarray(ids))

    def _matmul_route(self) -> str:
        """The packed-matmul impl serving this engine's traces, for
        latency attribution (dispatch counts routes at trace time; the
        executed graph runs the dominant one)."""
        from repro.runtime import dispatch as _dispatch

        return _dispatch.dominant_route(self.metrics)

    def _occupied(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _free(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _finish(self, idx: int, now: int) -> None:
        slot = self.slots[idx]
        assert slot is not None
        rid = slot.req.rid
        self.completions[rid] = Completion(
            rid=rid,
            prompt_len=slot.req.prompt_len,
            tokens=slot.gen[: slot.req.max_new],
            admitted_at=slot.admitted_at,
            finished_at=now,
            spec_drafted=slot.spec_drafted,
            spec_accepted=slot.spec_accepted,
            policy_id=slot.policy_id,
        )
        m = self.metrics
        m.counter("engine.completed").inc()
        m.counter("engine.tokens_generated").inc(len(slot.gen[: slot.req.max_new]))
        self.slots[idx] = None
        m.gauge("engine.slot_occupancy").set(len(self._occupied()))
        self.state = self._evict(self.state, jnp.asarray(idx, jnp.int32))
        if self._paged:
            pages = self._slot_pages[idx]
            self._slot_pages[idx] = None
            if pages:
                # drop this slot's references; registry pins keep shared
                # prefix pages alive for future remaps
                self._clear_freed(self.pool.release(pages))
            m.gauge("engine.kv_unique_pages").set(
                self.pool.unique_pages_in_use
            )
            self._set_pool_gauges()
        if self.trace is not None:
            ts = self.trace.now()
            track = obs_trace.req_track(rid)
            self.trace.instant(
                "complete",
                track=track,
                ts=ts,
                rid=rid,
                tokens=len(slot.gen),
                iteration=now,
            )
            self.trace.span(
                "request",
                slot.ts_admit,
                ts,
                track=track,
                rid=rid,
                prompt_len=slot.req.prompt_len,
                tokens=len(slot.gen),
                slot=idx,
            )
            self.trace.instant("evict", track=track, rid=rid, slot=idx)

    def _mark_done(self, idx: int, now: int) -> None:
        """Sequence finished: free immediately (continuous) or hold the slot
        until the whole round drains (fixed-batch padding semantics)."""
        slot = self.slots[idx]
        assert slot is not None
        slot.done = True
        if not self.scheduler.hold_round:
            self._finish(idx, now)

    def _admit_paged(self, req: Request, idx: int, now: int) -> None:
        """Paged admission: longest registered page-aligned prefix becomes
        a page-table remap (no recompute, attended via COW-refcounted
        shared pages); only the unshared suffix runs through chunked-append
        prefill (fixed chunk shape — one compile, no prompt bucketing)."""
        toks = np.asarray(req.tokens, np.int32)
        plen = req.prompt_len
        ps = self.ecfg.page_size
        pool = self.pool
        chain = prefix_chain_keys(toks, ps)
        # cap the hit one page short of covering the whole prompt: at least
        # one suffix token must run to produce the first token's logits
        shared = list(pool.lookup_prefix(chain[: (plen - 1) // ps]))
        hit_tokens = len(shared) * ps
        fresh, freed = pool.alloc_with_freed(self._pages_per_slot - len(shared))
        pool.ref(shared)  # this slot's reference on the donor's pages
        self._clear_freed(freed)
        table_row = shared + fresh
        ts_admit = (
            self.trace.now() if self.trace is not None else time.perf_counter()
        )
        t0 = time.perf_counter()
        self.state = self._map_slot(
            self.state,
            jnp.asarray(idx, jnp.int32),
            jnp.asarray(np.asarray(table_row, np.int32)),
        )
        chunk_len = max(ps, self.prefill_chunk // ps * ps)
        first_arr = None
        for start in range(hit_tokens, plen, chunk_len):
            n = min(chunk_len, plen - start)
            chunk = np.zeros((1, chunk_len), np.int32)
            chunk[0, :n] = toks[start : start + n]
            qpos = np.full((chunk_len,), -1, np.int32)
            qpos[:n] = np.arange(start, start + n, dtype=np.int32)
            logits, self.state = self._append(
                self.params,
                jnp.asarray(chunk),
                jnp.asarray(qpos),
                jnp.asarray(idx, jnp.int32),
                jnp.asarray(n - 1, jnp.int32),
                self.state,
            )
            first_arr = jnp.argmax(logits[0], -1)
        self._prefill_shapes.add(chunk_len)
        jax.block_until_ready((first_arr, self.state))
        dt = time.perf_counter() - t0
        first = int(first_arr)
        # register this prompt's own complete-page chains: the next prompt
        # sharing them prefills only its suffix
        k_full = plen // ps
        pool.register_prefix(chain[:k_full], table_row[:k_full])
        self._slot_pages[idx] = table_row
        m = self.metrics
        m.counter("engine.t_prefill_s").inc(dt)
        m.counter("engine.prefill_calls").inc()
        m.counter("engine.prefill_tokens").inc(plen - hit_tokens)
        m.counter("engine.admitted").inc()
        if hit_tokens:
            m.counter("engine.prefix_hit_tokens").inc(hit_tokens)
            m.counter("engine.prefill_flops_saved").inc(
                hit_tokens * self._flops_per_token
            )
        m.gauge("engine.prefill_compiles").set(len(self._prefill_shapes))
        m.gauge("engine.kv_unique_pages").set(pool.unique_pages_in_use)
        self._set_pool_gauges()
        m.gauge("engine.act_quant_reused").set(
            getattr(self.adapter, "act_quant_reused", 0) - self._act_reuse_base
        )
        m.histogram("engine.prefill_ms").observe(dt * 1e3)
        m.histogram("engine.ttft_ms").observe(dt * 1e3)
        obs_health.attribute_latency(m, "matmul", self._matmul_route(), dt)
        self.slots[idx] = _Slot(
            req, first, now, ts_admit, ts_admit + dt, self._active_policy
        )
        m.gauge("engine.slot_occupancy").set(len(self._occupied()))
        if self.trace is not None:
            stamp = (
                {"policy": self._active_policy} if self._active_policy else {}
            )
            track = obs_trace.req_track(req.rid)
            self.trace.instant(
                "admit",
                track=track,
                ts=ts_admit,
                rid=req.rid,
                slot=idx,
                prompt_len=plen,
                prefix_hit_tokens=hit_tokens,
                iteration=now,
            )
            if hit_tokens:
                # a remap is NOT a prefill: the explicit event carries what
                # the page-table hit skipped so reconcile can tell a shared
                # prefix from a suspiciously fast prefill span
                self.trace.instant(
                    "prefix_hit",
                    track=track,
                    ts=ts_admit,
                    rid=req.rid,
                    pages_reused=len(shared),
                    tokens=hit_tokens,
                    flops_saved=hit_tokens * self._flops_per_token,
                )
            self.trace.span(
                "prefill",
                ts_admit,
                ts_admit + dt,
                track=track,
                rid=req.rid,
                tokens=plen - hit_tokens,
            )
            self.trace.instant(
                "first_token",
                track=track,
                ts=ts_admit + dt,
                rid=req.rid,
                token=first,
                **stamp,
            )
        if req.max_new == 1 or first == self.ecfg.eos_id:
            self._mark_done(idx, now)

    def _admit(self, req: Request, idx: int, now: int) -> None:
        if self._paged:
            return self._admit_paged(req, idx, now)
        toks = np.asarray(req.tokens, np.int32)
        plen = req.prompt_len
        if self._bucket:
            blen = min(
                bucket_length(plen, self.ecfg.bucket_min), self.ecfg.cache_len
            )
            if blen > plen:
                toks = np.pad(toks, (0, blen - plen))
        inputs = {"tokens": jnp.asarray(toks)[None, :]}
        if req.extra_inputs:
            inputs.update(
                {k: jnp.asarray(v)[None] for k, v in req.extra_inputs.items()}
            )
        ts_admit = self.trace.now() if self.trace is not None else time.perf_counter()
        t0 = time.perf_counter()
        if self._bucket:
            logits, row = self._prefill(
                self.params, inputs, jnp.asarray(plen, jnp.int32)
            )
        else:
            logits, row = self._prefill(self.params, inputs)
        self._prefill_shapes.add(int(toks.shape[-1]))
        row = self.adapter.state_per_slot(row)
        self.state = self._insert(self.state, row, jnp.asarray(idx, jnp.int32))
        first_arr = jnp.argmax(logits[0], -1)
        # fence the FULL output tree (sampled token AND the inserted cache
        # state), so the stamp covers device work, not dispatch latency
        jax.block_until_ready((first_arr, self.state))
        dt = time.perf_counter() - t0
        first = int(first_arr)
        m = self.metrics
        m.counter("engine.t_prefill_s").inc(dt)
        m.counter("engine.prefill_calls").inc()
        m.counter("engine.prefill_tokens").inc(plen)
        m.counter("engine.admitted").inc()
        m.gauge("engine.prefill_compiles").set(len(self._prefill_shapes))
        m.gauge("engine.act_quant_reused").set(
            getattr(self.adapter, "act_quant_reused", 0) - self._act_reuse_base
        )
        m.histogram("engine.prefill_ms").observe(dt * 1e3)
        # the first token is sampled from the prefill logits, so TTFT for an
        # admitted request IS the fenced prefill duration (queue wait is the
        # scheduler's ledger, not the engine's)
        m.histogram("engine.ttft_ms").observe(dt * 1e3)
        obs_health.attribute_latency(m, "matmul", self._matmul_route(), dt)
        self.slots[idx] = _Slot(
            req, first, now, ts_admit, ts_admit + dt, self._active_policy
        )
        m.gauge("engine.slot_occupancy").set(len(self._occupied()))
        if self.trace is not None:
            stamp = (
                {"policy": self._active_policy} if self._active_policy else {}
            )
            track = obs_trace.req_track(req.rid)
            self.trace.instant(
                "admit",
                track=track,
                ts=ts_admit,
                rid=req.rid,
                slot=idx,
                prompt_len=plen,
                iteration=now,
            )
            self.trace.span(
                "prefill",
                ts_admit,
                ts_admit + dt,
                track=track,
                rid=req.rid,
                tokens=int(toks.shape[-1]),
            )
            self.trace.instant(
                "first_token",
                track=track,
                ts=ts_admit + dt,
                rid=req.rid,
                token=first,
                **stamp,
            )
        if req.max_new == 1 or first == self.ecfg.eos_id:
            self._mark_done(idx, now)

    def _decode_step(self, now: int) -> None:
        n = self.ecfg.slots
        toks = np.zeros((n, 1), np.int32)
        pos = np.full((n,), -1, np.int32)
        live: List[int] = []
        for i, s in enumerate(self.slots):
            if s is not None and not s.done:
                toks[i, 0] = s.next_tok
                pos[i] = s.next_pos
                live.append(i)
        t0 = time.perf_counter()
        logits, self.state = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(pos), self.state
        )
        nxt_arr = jnp.argmax(logits, -1)
        # fence the FULL output tree (next tokens AND the appended cache
        # state), so the stamp covers device work, not dispatch latency
        jax.block_until_ready((nxt_arr, self.state))
        dt = time.perf_counter() - t0
        nxt = np.asarray(nxt_arr)
        m = self.metrics
        m.counter("engine.t_decode_s").inc(dt)
        m.counter("engine.decode_steps").inc()
        m.counter("engine.slot_steps").inc(len(live))
        m.counter("engine.padded_slot_steps").inc(len(self._occupied()))
        m.gauge("engine.act_quant_reused").set(
            getattr(self.adapter, "act_quant_reused", 0) - self._act_reuse_base
        )
        m.histogram("engine.decode_step_ms").observe(dt * 1e3)
        obs_health.attribute_latency(m, "decode_attn", self.decode_attn_route, dt)
        # KV-scale drift: sampled host-side from the already-fenced state
        # (materialized write-time scales), so the jitted graph never sees it
        he = self.ecfg.health_every
        if he and int(m.value("engine.decode_steps")) % he == 0:
            self._kv_drift.publish(m, self._kv_drift.update(self.state))
        ts1 = self.trace.now() if self.trace is not None else time.perf_counter()
        if self.trace is not None:
            self.trace.span(
                "decode_step", ts1 - dt, ts1, slots=len(live), iteration=now
            )
        itl = m.histogram("engine.itl_ms")
        for i in live:
            s = self.slots[i]
            s.gen.append(int(nxt[i]))
            s.next_tok = int(nxt[i])
            s.next_pos += 1
            itl.observe((ts1 - s.ts_last_token) * 1e3)
            s.ts_last_token = ts1
            if self.trace is not None:
                self.trace.instant(
                    "token",
                    track=obs_trace.req_track(s.req.rid),
                    ts=ts1,
                    rid=s.req.rid,
                    token=int(nxt[i]),
                    iteration=now,
                    **({"policy": s.policy_id} if s.policy_id else {}),
                )
            if len(s.gen) >= s.req.max_new or nxt[i] == self.ecfg.eos_id:
                self._mark_done(i, now)

    # -- self-speculative decode --------------------------------------------
    def _spec_draft_body(self, steps: int, p, tok, pos, state):
        """``steps`` single-token draft-policy decodes inside one
        ``lax.scan`` (argmax stays in-graph), writing draft KV rows at
        p..p+steps-1. Returns (drafts (n, steps), state)."""

        def body(carry, _):
            tok, pos, st = carry
            logits, st = self.adapter.decode(p, tok, pos, st)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (nxt[:, None], jnp.where(pos < 0, pos, pos + 1), st), nxt

        (_, _, st), drafts = jax.lax.scan(
            body, (tok, pos, state), None, length=steps
        )
        return drafts.T, st

    def _spec_verify_fn(self, p, tok, drafts, pos, remaining, state):
        """Multi-token TARGET pass over [cur, d1..dk] at positions p..p+k
        (overwriting every draft KV row with target-computed rows and
        writing row p+k), then — still in-graph — the greedy acceptance
        walk, emission truncation (max_new remaining first, then first
        EOS: the exact order a token-at-a-time engine stops in), and the
        KV rollback past each slot's last fed row. Free slots (pos -1)
        ride along at sentinel positions and an untouchable rollback cut.
        Returns (targets (n, k+1), accept_len (n,), emit_count (n,),
        state)."""
        k = drafts.shape[1]
        vtok = jnp.concatenate([tok, drafts], axis=1)
        off = jnp.arange(k + 1, dtype=jnp.int32)
        vpos = jnp.where(pos[:, None] < 0, -1, pos[:, None] + off[None])
        logits, st = self.adapter.verify(p, vtok, vpos, state)
        targets = jnp.argmax(logits, -1).astype(jnp.int32)  # (n, k+1)
        accept = jnp.cumprod(
            (drafts == targets[:, :k]).astype(jnp.int32), axis=1
        )
        a = accept.sum(axis=1)  # accepted draft prefix length
        emit = jnp.minimum(a + 1, remaining)
        eos_id = self.ecfg.eos_id
        if eos_id is not None:
            hits = (targets == eos_id) & (off[None] < emit[:, None])
            first = jnp.argmax(hits, axis=1).astype(jnp.int32)
            emit = jnp.where(hits.any(axis=1), first + 1, emit)
        cut = jnp.where(pos < 0, jnp.int32(2**30), pos + emit)
        st = lm.rollback_decode_state(st, cut)
        return targets, a, emit, st

    def _spec_draft(self, steps: int):
        """Jitted draft pass for one round length — one dispatch instead
        of k. Distinct ``steps`` values compile separately; the host clamp
        in ``_spec_round`` keeps that set tiny (k plus end-of-sequence
        remainders)."""
        fn = self._spec_draft_jits.get(steps)
        if fn is None:
            fn = jax.jit(
                lambda p, tok, pos, state: self._spec_draft_body(
                    steps, p, tok, pos, state
                ),
                donate_argnums=(3,),
            )
            self._spec_draft_jits[steps] = fn
        return fn

    def _spec_fused(self, steps: int):
        """The traceless fast path: draft scan + verify + acceptance +
        rollback as ONE jitted launch — a whole speculative round costs a
        single dispatch and a single fence. Used when ``trace`` is off
        (the bench's measured configuration); with tracing on, the round
        splits into draft/verify launches so the phase spans are honest
        fenced timings rather than estimates."""
        fn = self._spec_fused_jits.get(steps)
        if fn is None:

            def round_fn(tp, dp, tok, pos, remaining, state):
                drafts, state = self._spec_draft_body(
                    steps, dp, tok, pos, state
                )
                return self._spec_verify_fn(
                    tp, tok, drafts, pos, remaining, state
                )

            fn = jax.jit(round_fn, donate_argnums=(5,))
            self._spec_fused_jits[steps] = fn
        return fn

    def _spec_round(self, now: int) -> None:
        """One speculative round over all live slots: the low-bit DRAFT
        policy proposes k tokens (one scan launch, writing draft KV rows
        at p..p+k-1), the searched TARGET policy verifies [cur, d1..dk]
        in one multi-token pass (overwriting every draft row with
        target-computed KV and writing row p+k), greedy acceptance walks
        the longest matching prefix, and rows past each slot's last fed
        token are rolled back. Emits 1..k+1 tokens per slot, all of them
        the target policy's own greedy chain — token- and KV-bitwise
        identical to ``_decode_step`` by construction; speculation only
        changes how many launches that chain costs."""
        live = [
            i for i, s in enumerate(self.slots) if s is not None and not s.done
        ]
        k = min(
            self._spec_k,
            min(self.slots[i].req.max_new - len(self.slots[i].gen) for i in live),
        )
        if k < 1:
            return self._decode_step(now)
        n = self.ecfg.slots
        toks = np.zeros((n, 1), np.int32)
        pos = np.full((n,), -1, np.int32)
        remaining = np.zeros((n,), np.int32)
        for i in live:
            s = self.slots[i]
            toks[i, 0] = s.next_tok
            pos[i] = s.next_pos
            remaining[i] = s.req.max_new - len(s.gen)
        m = self.metrics
        t0 = time.perf_counter()
        if self.trace is not None:
            # two launches, fenced between, so the draft/verify phase
            # spans carry measured durations; acceptance, truncation and
            # rollback still run inside the verify launch
            drafts, self.state = self._spec_draft(k)(
                self.draft_params,
                jnp.asarray(toks),
                jnp.asarray(pos),
                self.state,
            )
            jax.block_until_ready((drafts, self.state))
            t_draft = time.perf_counter() - t0
            targets, acc_arr, emit_arr, self.state = self._spec_verify(
                self.params,
                jnp.asarray(toks),
                drafts,
                jnp.asarray(pos),
                jnp.asarray(remaining),
                self.state,
            )
        else:
            # traceless fast path: the whole round is ONE dispatch
            t_draft = 0.0
            targets, acc_arr, emit_arr, self.state = self._spec_fused(k)(
                self.params,
                self.draft_params,
                jnp.asarray(toks),
                jnp.asarray(pos),
                jnp.asarray(remaining),
                self.state,
            )
        jax.block_until_ready((targets, acc_arr, emit_arr, self.state))
        dt = time.perf_counter() - t0
        t_np = np.asarray(targets)
        a_np = np.asarray(acc_arr)
        e_np = np.asarray(emit_arr)
        emits: Dict[int, List[int]] = {}
        accepted_total = 0
        for i in live:
            s = self.slots[i]
            accepted_total += int(a_np[i])
            s.spec_drafted += k
            s.spec_accepted += int(a_np[i])
            m.histogram("spec.accept_len").observe(float(a_np[i]))
            emit = [int(x) for x in t_np[i, : e_np[i]]]
            emits[i] = emit
            s.gen.extend(emit)
            s.next_tok = emit[-1]
            s.next_pos += len(emit)
        m.counter("engine.t_decode_s").inc(dt)
        m.counter("engine.decode_steps").inc()
        m.counter("engine.slot_steps").inc(len(live))
        m.counter("engine.padded_slot_steps").inc(len(self._occupied()))
        m.counter("spec.rounds").inc()
        m.counter("spec.draft_tokens").inc(k * len(live))
        m.counter("spec.accepted_tokens").inc(accepted_total)
        m.gauge("engine.act_quant_reused").set(
            getattr(self.adapter, "act_quant_reused", 0) - self._act_reuse_base
        )
        m.histogram("engine.decode_step_ms").observe(dt * 1e3)
        obs_health.attribute_latency(m, "decode_attn", self.decode_attn_route, dt)
        he = self.ecfg.health_every
        if he and int(m.value("engine.decode_steps")) % he == 0:
            self._kv_drift.publish(m, self._kv_drift.update(self.state))
        ts1 = self.trace.now() if self.trace is not None else time.perf_counter()
        if self.trace is not None:
            self.trace.span(
                "decode_step", ts1 - dt, ts1, slots=len(live), iteration=now
            )
            self.trace.span(
                "spec_draft",
                ts1 - dt,
                ts1 - dt + t_draft,
                slots=len(live),
                k=k,
                iteration=now,
            )
            self.trace.span(
                "spec_verify_phase",
                ts1 - dt + t_draft,
                ts1,
                slots=len(live),
                iteration=now,
            )
            self.trace.instant(
                "spec_verify",
                ts=ts1,
                drafted=k * len(live),
                accepted=accepted_total,
                emitted=sum(len(e) for e in emits.values()),
                iteration=now,
            )
        itl = m.histogram("engine.itl_ms")
        for i in live:
            s = self.slots[i]
            itl.observe((ts1 - s.ts_last_token) * 1e3)
            s.ts_last_token = ts1
            if self.trace is not None:
                for tkn in emits[i]:
                    self.trace.instant(
                        "token",
                        track=obs_trace.req_track(s.req.rid),
                        ts=ts1,
                        rid=s.req.rid,
                        token=tkn,
                        iteration=now,
                        **({"policy": s.policy_id} if s.policy_id else {}),
                    )
            if (
                len(s.gen) >= s.req.max_new
                or s.next_tok == self.ecfg.eos_id
            ):
                self._mark_done(i, now)

    # -- main loop ----------------------------------------------------------
    def step(self, now: int) -> bool:
        """One engine iteration: release a drained round (fixed policy),
        admit per policy, then decode. Returns False when there is nothing
        left to do."""
        if self.scheduler.hold_round:
            occ = self._occupied()
            if occ and all(self.slots[i].done for i in occ):
                for i in occ:
                    self._finish(i, now)
        if self.scheduler.has_pending():
            if self.elastic is not None:
                self._elastic_admission(now)
            # paged KV: hand the scheduler the pool's worst-case obtainable
            # pages so it defers (FIFO) rather than letting an admission
            # race the pool into exhaustion mid-prefill
            picks = self.scheduler.admit(
                now,
                self._free(),
                len(self._occupied()),
                page_budget=self.pool.available_count if self._paged else None,
                page_need=self._pages_per_slot if self._paged else 0,
                hold=self._swap_decision is not None,
            )
            for req, idx in picks:
                self._admit(req, idx, now)
        if any(s is not None and not s.done for s in self.slots):
            if self._spec_k:
                self._spec_round(now)
            else:
                self._decode_step(now)
        elif self._occupied():
            pass  # held round finished at admission: released next tick
        elif not self.scheduler.has_pending():
            return False
        self.metrics.counter("engine.iterations").inc()
        self.monitor.check(self.metrics, self.trace)
        if self.on_step is not None:
            self.on_step(self.metrics)
        return True

    def run(self) -> Dict[int, Completion]:
        """Drain the queue; returns {rid: Completion}."""
        now = 0
        while self.step(now):
            now += 1
            if now >= self.ecfg.max_iters:
                raise RuntimeError(
                    f"engine exceeded max_iters={self.ecfg.max_iters} "
                    f"(pending={len(self.scheduler.pending)}, "
                    f"occupied={len(self._occupied())})"
                )
        assert not self._occupied(), "slot leak: occupied slots after drain"
        return self.completions
