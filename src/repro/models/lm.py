"""Config-driven LM covering all 10 assigned architectures.

Layer plumbing
--------------
A config expands into a *schedule*: ``prefix`` layers (unrolled, e.g.
deepseek-moe's first dense layer), a repeating ``pattern`` scanned
``repeats`` times with stacked params (keeps the HLO one-body-per-pattern —
essential for compile time at 52 layers), and ``suffix`` layers (unrolled
remainder, e.g. recurrentgemma's trailing rec-rec).

Every searchable projection is a QLayer (repro.core.qspec) whose per-bit
indicator banks live next to the weight. Bit selection arrives as a
``bits`` pytree that mirrors the param tree: scalars for unrolled layers,
(repeats,)-arrays for scanned ones, so one code path serves
  * full-precision baselines          (bits=None)
  * uniform-bit joint-training passes (bits_uniform)
  * the random communication pass     (bits_random)
  * ILP-searched policies             (bits_from_policy)

Modes: ``train`` (full-seq logits), ``prefill`` (logits at last position +
decode state), ``decode`` (one token with state). Encoder-only archs have
no prefill/decode (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.qspec import QLayer
from repro.core.policy import MPQPolicy
from repro.dist.axes import NO_AXES, MeshAxes
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.runtime import kv_cache as qkv
from repro.models import recurrent as rec_mod
from repro.models.common import activation, apply_norm, embed_init, norm_init
from repro.models.quant_layers import (
    QuantContext, embed_lookup_pinned, qdense_init, qeinsum, qeinsum_pinned,
    pinned_init,
)

Array = jax.Array

FRONTEND_DIMS = {"audio_stub": 512, "vision_stub": 1280, "none": 0}
MOE_AUX_COEF = 0.01


# ===========================================================================
# schedule
# ===========================================================================
class Schedule(NamedTuple):
    prefix: Tuple[str, ...]
    pattern: Tuple[str, ...]
    repeats: int
    suffix: Tuple[str, ...]

    @property
    def n_sites(self) -> int:
        return len(self.prefix) + self.repeats * len(self.pattern) + len(self.suffix)


class LayerSite(NamedTuple):
    kind: str          # attn | dense | moe | cross | rwkv | rec
    segment: str       # "prefix.0" | "body.2" | "suffix.1"
    unit: int          # repeat index within body, else 0
    gidx: int          # global execution index


def build_schedule(cfg: ModelConfig) -> Schedule:
    L = cfg.n_layers
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        return Schedule(("dense",) * fd, ("moe",), L - fd, ())
    if cfg.family == "vlm":
        cae = cfg.cross_attn_every
        pattern = ("attn",) * cae + ("cross",)
        return Schedule((), pattern, L // cae, ("attn",) * (L % cae))
    if cfg.family == "hybrid":
        bp = tuple(cfg.block_pattern)
        return Schedule((), bp, L // len(bp), bp[: L % len(bp)])
    if cfg.family == "ssm":
        return Schedule((), ("rwkv",), L, ())
    return Schedule((), ("attn",), L, ())    # dense / audio / vlm-less


def iter_sites(cfg: ModelConfig) -> List[LayerSite]:
    s = build_schedule(cfg)
    sites, g = [], 0
    for i, kind in enumerate(s.prefix):
        sites.append(LayerSite(kind, f"prefix.{i}", 0, g))
        g += 1
    for u in range(s.repeats):
        for p, kind in enumerate(s.pattern):
            sites.append(LayerSite(kind, f"body.{p}", u, g))
            g += 1
    for i, kind in enumerate(s.suffix):
        sites.append(LayerSite(kind, f"suffix.{i}", 0, g))
        g += 1
    return sites


def _layer_ff(cfg: ModelConfig, kind: str) -> int:
    if kind == "dense" and cfg.moe and cfg.moe.dense_d_ff:
        return cfg.moe.dense_d_ff
    return cfg.d_ff


# ===========================================================================
# per-kind init
# ===========================================================================
def _mlp_init(rng, cfg: ModelConfig, ff: int, *, stacked=()):
    ks = jax.random.split(rng, 3)
    p = {
        "mlp_wi": qdense_init(ks[0], cfg.d_model, ff, cfg.bits, stacked=stacked),
        "mlp_wo": qdense_init(ks[1], ff, cfg.d_model, cfg.bits, stacked=stacked),
    }
    if cfg.mlp_gated:
        p["mlp_wg"] = qdense_init(ks[2], cfg.d_model, ff, cfg.bits, stacked=stacked)
    return p


def _attn_core_init(rng, cfg: ModelConfig, *, stacked=()):
    ks = jax.random.split(rng, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": qdense_init(ks[0], d, qd, cfg.bits, stacked=stacked),
        "wk": qdense_init(ks[1], d, kvd, cfg.bits, stacked=stacked),
        "wv": qdense_init(ks[2], d, kvd, cfg.bits, stacked=stacked),
        "wo": qdense_init(ks[3], qd, d, cfg.bits, stacked=stacked),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones(stacked + (cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones(stacked + (cfg.hd,), jnp.float32)
    return p


def _layer_init(rng, cfg: ModelConfig, kind: str, *, stacked=()):
    ks = jax.random.split(rng, 4)
    d = cfg.d_model
    nrm = lambda: jax.tree.map(
        lambda a: jnp.broadcast_to(a, stacked + a.shape) if stacked else a,
        norm_init(d, cfg.norm_type))
    if kind in ("attn", "dense", "moe", "cross"):
        p = {"norm1": nrm(), "norm2": nrm()}
        p.update(_attn_core_init(ks[0], cfg, stacked=stacked))
        if kind == "moe":
            p["moe"] = moe_mod.moe_init(ks[1], d, cfg.moe, cfg.bits,
                                        cfg.mlp_gated, stacked=stacked)
        else:
            p.update(_mlp_init(ks[1], cfg, _layer_ff(cfg, kind), stacked=stacked))
        if kind == "cross":
            p["gate_attn"] = jnp.zeros(stacked, jnp.float32)
            p["gate_mlp"] = jnp.zeros(stacked, jnp.float32)
        return p
    if kind == "rwkv":
        p = {"norm1": nrm(), "norm2": nrm()}
        p.update(rec_mod.rwkv_init(ks[0], d, cfg.n_heads, cfg.rwkv_head_dim,
                                   cfg.d_ff, cfg.bits, stacked=stacked))
        return p
    if kind == "rec":
        p = {"norm1": nrm(), "norm2": nrm(),
             "rg": rec_mod.rglru_init(ks[0], d, cfg.lru_width, cfg.n_heads,
                                      cfg.conv1d_width, cfg.bits,
                                      stacked=stacked)}
        p.update(_mlp_init(ks[1], cfg, cfg.d_ff, stacked=stacked))
        return p
    raise ValueError(f"unknown layer kind {kind!r}")


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    sched = build_schedule(cfg)
    ks = iter(jax.random.split(rng, 8 + sched.n_sites))
    params: Dict[str, Any] = {}

    # --- input embedding / frontend ---------------------------------------
    if cfg.frontend == "audio_stub":
        params["embed"] = pinned_init(next(ks), FRONTEND_DIMS["audio_stub"],
                                      cfg.d_model)
    else:
        params["embed"] = {"w": embed_init(next(ks), cfg.vocab, cfg.d_model)}
        from repro.core.quantizer import bit_range, init_scale_from_stats
        params["embed"]["s_w8"] = init_scale_from_stats(
            params["embed"]["w"], bit_range(8, True)[1])
    if cfg.family == "vlm":
        params["img_proj"] = pinned_init(next(ks), FRONTEND_DIMS["vision_stub"],
                                         cfg.d_model)

    # --- layers ------------------------------------------------------------
    params["prefix"] = {str(i): _layer_init(next(ks), cfg, kind)
                        for i, kind in enumerate(sched.prefix)}
    params["body"] = {str(p): _layer_init(next(ks), cfg, kind,
                                          stacked=(sched.repeats,))
                      for p, kind in enumerate(sched.pattern)} \
        if sched.repeats else {}
    params["suffix"] = {str(i): _layer_init(next(ks), cfg, kind)
                        for i, kind in enumerate(sched.suffix)}

    # --- output ------------------------------------------------------------
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm_type)
    if not cfg.tie_embeddings:
        params["head"] = pinned_init(next(ks), cfg.d_model, cfg.vocab)
    else:
        params["head"] = {"s_a8": jnp.asarray(0.1 / 8, jnp.float32)}
    return params


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


# ===========================================================================
# QLayer enumeration (must mirror init_params exactly)
# ===========================================================================
def _kind_qdefs(cfg: ModelConfig, kind: str):
    """[(path, in, out, n_mats, macs_per_token, w_params, qkind)]"""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    if kind in ("attn", "dense", "moe", "cross"):
        qkind = "cross" if kind == "cross" else "attn"
        defs = [
            (("wq",), d, qd, 1, d * qd, d * qd, qkind),
            (("wk",), d, kvd, 1, d * kvd, d * kvd, qkind),
            (("wv",), d, kvd, 1, d * kvd, d * kvd, qkind),
            (("wo",), qd, d, 1, qd * d, qd * d, qkind),
        ]
        if kind == "moe":
            defs += [(("moe",) + path, i, o, n, macs, w, "moe")
                     for path, i, o, n, macs, w, _k
                     in moe_mod.moe_qlayer_defs(d, cfg.moe, cfg.mlp_gated)]
        else:
            ff = _layer_ff(cfg, kind)
            defs += [
                (("mlp_wi",), d, ff, 1, d * ff, d * ff, "mlp"),
                (("mlp_wo",), ff, d, 1, ff * d, ff * d, "mlp"),
            ]
            if cfg.mlp_gated:
                defs.append((("mlp_wg",), d, ff, 1, d * ff, d * ff, "mlp"))
        return defs
    if kind == "rwkv":
        ff = cfg.d_ff
        return [
            (("wr",), d, d, 1, d * d, d * d, "rwkv"),
            (("wk",), d, d, 1, d * d, d * d, "rwkv"),
            (("wv",), d, d, 1, d * d, d * d, "rwkv"),
            (("wg",), d, d, 1, d * d, d * d, "rwkv"),
            (("wo",), d, d, 1, d * d, d * d, "rwkv"),
            (("cm_wk",), d, ff, 1, d * ff, d * ff, "rwkv"),
            (("cm_wv",), ff, d, 1, ff * d, ff * d, "rwkv"),
            (("cm_wr",), d, d, 1, d * d, d * d, "rwkv"),
        ]
    if kind == "rec":
        W = cfg.lru_width or d
        ff = cfg.d_ff
        defs = [
            (("rg", "wx"), d, W, 1, d * W, d * W, "rec"),
            (("rg", "wgate"), d, W, 1, d * W, d * W, "rec"),
            (("rg", "wo"), W, d, 1, W * d, W * d, "rec"),
            (("mlp_wi",), d, ff, 1, d * ff, d * ff, "mlp"),
            (("mlp_wo",), ff, d, 1, ff * d, ff * d, "mlp"),
        ]
        if cfg.mlp_gated:
            defs.append((("mlp_wg",), d, ff, 1, d * ff, d * ff, "mlp"))
        return defs
    raise ValueError(kind)


def enumerate_qlayers(cfg: ModelConfig) -> List[QLayer]:
    out = []
    for site in iter_sites(cfg):
        for path, i, o, n, macs, w, qk in _kind_qdefs(cfg, site.kind):
            out.append(QLayer(
                name=f"L{site.gidx:03d}.{'.'.join(path)}",
                segment=site.segment, unit=site.unit, path=path,
                in_dim=i, out_dim=o, n_mats=n,
                macs_per_token=float(macs), w_params=int(w), kind=qk))
    return out


# ===========================================================================
# bit-assignment pytrees
# ===========================================================================
def _site_bit_template(cfg: ModelConfig, kind: str) -> List[Tuple[str, ...]]:
    return [path for path, *_ in _kind_qdefs(cfg, kind)]


def _nest(dst: dict, path: Tuple[str, ...], leaf):
    for k in path[:-1]:
        dst = dst.setdefault(k, {})
    dst[path[-1]] = leaf


def bits_uniform(cfg: ModelConfig, k) -> Dict[str, Any]:
    """Same bank index `k` (python int or traced scalar) for every QLayer."""
    sched = build_schedule(cfg)
    k = jnp.asarray(k, jnp.int32)
    bits: Dict[str, Any] = {"prefix": {}, "body": {}, "suffix": {}}
    for i, kind in enumerate(sched.prefix):
        d: dict = {}
        for path in _site_bit_template(cfg, kind):
            _nest(d, path, {"w": k, "a": k})
        bits["prefix"][str(i)] = d
    for p, kind in enumerate(sched.pattern):
        if not sched.repeats:
            break
        d = {}
        arr = jnp.broadcast_to(k, (sched.repeats,))
        for path in _site_bit_template(cfg, kind):
            _nest(d, path, {"w": arr, "a": arr})
        bits["body"][str(p)] = d
    for i, kind in enumerate(sched.suffix):
        d = {}
        for path in _site_bit_template(cfg, kind):
            _nest(d, path, {"w": k, "a": k})
        bits["suffix"][str(i)] = d
    return bits


def bits_random(cfg: ModelConfig, rng) -> Dict[str, Any]:
    """Independent random bank index per (QLayer, w/a) — the paper's
    communication pass (§3.4)."""
    sched = build_schedule(cfg)
    n = cfg.n_bits
    bits: Dict[str, Any] = {"prefix": {}, "body": {}, "suffix": {}}

    def draw(shape=()):
        nonlocal rng
        rng, k = jax.random.split(rng)
        return jax.random.randint(k, shape, 0, n, jnp.int32)

    for seg, kinds, shape in (
            ("prefix", sched.prefix, ()),
            ("body", sched.pattern if sched.repeats else (), (sched.repeats,)),
            ("suffix", sched.suffix, ())):
        for i, kind in enumerate(kinds):
            d: dict = {}
            for path in _site_bit_template(cfg, kind):
                _nest(d, path, {"w": draw(shape), "a": draw(shape)})
            bits[seg][str(i)] = d
    return bits


def bits_from_policy(cfg: ModelConfig, policy: MPQPolicy,
                     qlayers: Optional[Sequence[QLayer]] = None) -> Dict[str, Any]:
    """Static per-layer bank indices from an ILP-searched MPQPolicy."""
    qlayers = qlayers if qlayers is not None else enumerate_qlayers(cfg)
    policy.validate(qlayers, bits=cfg.bits)   # stale files fail loudly
    lut = {int(b): i for i, b in enumerate(cfg.bits)}
    per_seg: Dict[str, Dict[Tuple[str, ...], List[Tuple[int, int, int]]]] = {}
    for q in qlayers:
        per_seg.setdefault(q.segment, {}).setdefault(q.path, []).append(
            (q.unit, lut[policy.w_bits[q.name]], lut[policy.a_bits[q.name]]))

    bits: Dict[str, Any] = {"prefix": {}, "body": {}, "suffix": {}}
    for segment, paths in per_seg.items():
        seg, idx = segment.split(".")
        d = bits[seg].setdefault(idx, {})
        for path, triples in paths.items():
            triples.sort()
            w = np.asarray([t[1] for t in triples], np.int32)
            a = np.asarray([t[2] for t in triples], np.int32)
            if seg in ("prefix", "suffix"):
                _nest(d, path, {"w": jnp.asarray(w[0]), "a": jnp.asarray(a[0])})
            else:
                _nest(d, path, {"w": jnp.asarray(w), "a": jnp.asarray(a)})
    return bits


# ===========================================================================
# forward
# ===========================================================================
def _sinusoid_pos(S: int, d: int, dtype) -> Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)[None]


def embed_inputs(params, cfg: ModelConfig, inputs: Dict[str, Array],
                 ctx: QuantContext, axes: MeshAxes) -> Tuple[Array, Optional[Array]]:
    """Returns (x (B,S,D), img_x (B,N,D) or None)."""
    if cfg.frontend == "audio_stub":
        x = qeinsum_pinned("bsf,fd->bsd", inputs["feats"].astype(ctx.compute_dtype),
                           params["embed"], ctx)
        x = x + _sinusoid_pos(x.shape[1], cfg.d_model, x.dtype)
    else:
        x = embed_lookup_pinned(inputs["tokens"], params["embed"], ctx)
        if cfg.family == "hybrid":          # gemma-style embed scaling
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    img_x = None
    if cfg.family == "vlm" and "img" in inputs:
        img_x = qeinsum_pinned("bnf,fd->bnd",
                               inputs["img"].astype(ctx.compute_dtype),
                               params["img_proj"], ctx)
    x = axes.shard(x, "dp", "sp", None)
    return x, img_x


def _rope_cos_sin(cfg: ModelConfig, positions: Array):
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (
        jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    freqs = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(freqs), jnp.sin(freqs)


def _qk_rms(x: Array, scale: Array, eps: float) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _bget(bits, *path):
    if bits is None:
        return None
    for k in path:
        bits = bits[k]
    return bits


def _attn_window(cfg: ModelConfig, kind: str) -> Optional[int]:
    if cfg.family == "hybrid":
        return cfg.local_window or None
    return cfg.sliding_window


def _attn_sublayer(x, p, bits, cfg: ModelConfig, ctx, axes: MeshAxes, kind: str,
                   mode: str, state, pos, img_x, prefill_cap=None, slot=None):
    """Self- or cross-attention residual sub-block. Returns (x, new_state).

    Modes: ``train`` (no state), ``prefill`` (build a fresh decode cache),
    ``decode`` (one token per batch row), ``append`` (chunked prefill: a
    multi-token chunk for ONE paged slot — ``pos`` is the chunk's absolute
    position vector, ``slot`` the engine slot index), ``verify``
    (speculative multi-token verify: S tokens per slot at per-slot
    absolute positions ``pos (B, S)``, attended per query through the
    single-token decode route — ``attention.verify_attention``)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    is_cross = kind == "cross"
    h = apply_norm(x, p["norm1"], cfg.norm_type, cfg.norm_eps)
    h = axes.shard(h, "dp", "sp", None)

    q = qeinsum("bsd,de->bse", h, p["wq"], _bget(bits, "wq"), ctx)
    q = q.reshape(B, S, H, hd)

    if is_cross:
        if mode == "decode":
            k, v = state                               # cached image k/v
            new_state = state
        else:
            hk = img_x
            k = qeinsum("bnd,de->bne", hk, p["wk"], _bget(bits, "wk"), ctx)
            v = qeinsum("bnd,de->bne", hk, p["wv"], _bget(bits, "wv"), ctx)
            k = k.reshape(B, -1, KV, hd)
            v = v.reshape(B, -1, KV, hd)
            k = axes.shard(k, "dp", None, "th", None)
            v = axes.shard(v, "dp", None, "th", None)
            if cfg.qk_norm:
                k = _qk_rms(k, p["k_norm"], cfg.norm_eps)
            new_state = (k, v) if mode == "prefill" else None
        if cfg.qk_norm:
            q = _qk_rms(q, p["q_norm"], cfg.norm_eps)
        out = attn.cross_attention(q, k, v)
    else:
        k = qeinsum("bsd,de->bse", h, p["wk"], _bget(bits, "wk"), ctx)
        v = qeinsum("bsd,de->bse", h, p["wv"], _bget(bits, "wv"), ctx)
        k = k.reshape(B, S, KV, hd)
        v = v.reshape(B, S, KV, hd).astype(ctx.compute_dtype)
        # pin the post-reshape layout to a per-dim spec: the projection
        # output arrives sharded on the merged KV*hd dim, and when KV
        # doesn't divide the axis the reshape leaves a multi-dim tiling
        # that downstream slice/concat (rope) must not consume — shard by
        # KV head when it divides, else replicate (megatron keeps KV heads
        # whole per shard)
        q = axes.shard(q, "dp", None, "th", None)
        k = axes.shard(k, "dp", None, "th", None)
        v = axes.shard(v, "dp", None, "th", None)
        if cfg.qk_norm:
            q = _qk_rms(q, p["q_norm"], cfg.norm_eps)
            k = _qk_rms(k, p["k_norm"], cfg.norm_eps)
        if cfg.family != "audio":                      # audio: sinusoid, no rope
            per_slot = mode == "decode" and jnp.ndim(pos) == 1
            if mode == "decode":
                p_ = jnp.asarray(pos, jnp.int32)
                positions = jnp.maximum(p_, 0) if per_slot else p_[None]
            elif mode == "verify":
                # (B, S) per-slot absolute positions (speculative verify);
                # sentinel rows (-1) take angle 0 — masked everywhere
                positions = jnp.maximum(jnp.asarray(pos, jnp.int32),
                                        0).reshape(-1)
            elif mode == "append":
                # chunk of S absolute positions (pad rows carry -1; their
                # rope angle is irrelevant — the cache write drops them)
                positions = jnp.maximum(jnp.asarray(pos, jnp.int32), 0)
            else:
                positions = jnp.arange(S)
            cos, sin = _rope_cos_sin(cfg, positions)
            if per_slot:            # (B, hd/2) -> (B, 1, 1, hd/2): one angle
                cos = cos[:, None, None]    # per slot, broadcast over S and H
                sin = sin[:, None, None]
            elif mode == "verify":  # (B*S, hd/2) -> one angle per (slot,
                cos = cos.reshape(B, S, 1, -1)          # token), broadcast
                sin = sin.reshape(B, S, 1, -1)          # over heads
            from repro.models.common import apply_rope
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        q = axes.shard(q, "dp", None, "th", None)
        k = k.astype(ctx.compute_dtype)
        window = _attn_window(cfg, kind)
        if mode == "decode":
            if ctx.kv_quant == "fake":
                # reference view of an int8 slot: the new row is stored
                # (and attended) quantize-dequantized, in an fp cache
                k = qkv.fake_quant_kv(k)
                v = qkv.fake_quant_kv(v)
            out, new_state = attn.decode_attention(q, state, k, v, pos,
                                                   window=window)
        elif mode == "verify":
            if ctx.kv_quant == "fake":
                k = qkv.fake_quant_kv(k)
                v = qkv.fake_quant_kv(v)
            out, new_state = attn.verify_attention(
                q, state, k, v, jnp.asarray(pos, jnp.int32), window=window)
        elif mode == "append":
            out, new_state = attn.append_attention(
                q, state, k, v, jnp.asarray(pos, jnp.int32), slot,
                window=window)
        else:
            kq = ksc = vq = vsc = None
            if ctx.kv_quant != "none":
                # quantize ONCE and attend over the dequantized view: the
                # prefill attend then sees exactly the rows a later reader
                # of the cache (decode, or a paged shared-prefix re-prefill
                # that only has the codes) reconstructs. Re-quantizing the
                # dequantized values would round-trip the codes but may
                # perturb the scales by an ulp, so the codes+scales
                # computed here are the ones stored.
                kq, ksc = qkv.quantize_rows(k)
                vq, vsc = qkv.quantize_rows(v)
                k = qkv.dequantize(kq, ksc, k.dtype)
                v = qkv.dequantize(vq, vsc, v.dtype)
            out = attn.self_attention(q.astype(ctx.compute_dtype), k, v,
                                      causal=cfg.causal, window=window)
            if mode == "prefill":
                cap_total = prefill_cap or S
                cap = min(cap_total, window) if window else cap_total
                if ctx.kv_quant == "int8":
                    new_state = attn.build_prefill_cache_from_codes(
                        kq, ksc, vq, vsc, S, cap)
                else:
                    # "fake": k/v already hold the quantize-dequantized
                    # values, so an fp cache of them IS the reference view
                    new_state = attn.build_prefill_cache(k, v, S, cap,
                                                         kv_quant="none")
            else:
                new_state = None
        out = axes.shard(out, "dp", None, "th", None)

    out = out.reshape(B, S, H * hd)
    out = qeinsum("bse,ed->bsd", out, p["wo"], _bget(bits, "wo"), ctx)
    if is_cross:
        out = out * jnp.tanh(p["gate_attn"]).astype(out.dtype)
    return x + out, new_state


def _mlp_sublayer(x, p, bits, cfg: ModelConfig, ctx, axes: MeshAxes,
                  gate_key: Optional[str] = None):
    h = apply_norm(x, p["norm2"], cfg.norm_type, cfg.norm_eps)
    h = axes.shard(h, "dp", "sp", None)
    hi = qeinsum("bsd,df->bsf", h, p["mlp_wi"], _bget(bits, "mlp_wi"), ctx)
    if cfg.mlp_gated:
        hg = qeinsum("bsd,df->bsf", h, p["mlp_wg"], _bget(bits, "mlp_wg"), ctx)
        hi = activation(cfg.act)(hg) * hi
    else:
        hi = activation(cfg.act)(hi)
    hi = axes.shard(hi, "dp", None, "tp")
    out = qeinsum("bsf,fd->bsd", hi, p["mlp_wo"], _bget(bits, "mlp_wo"), ctx)
    if gate_key is not None:
        out = out * jnp.tanh(p[gate_key]).astype(out.dtype)
    return x + out


def apply_layer(kind: str, x: Array, p, bits, cfg: ModelConfig,
                ctx: QuantContext, axes: MeshAxes, *, mode: str = "train",
                state=None, pos=None, img_x=None, prefill_cap=None,
                slot=None):
    """One residual layer. Returns (x, new_state, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if kind in ("attn", "dense", "cross"):
        st = state
        x, new_st = _attn_sublayer(x, p, bits, cfg, ctx, axes, kind, mode,
                                   st, pos, img_x, prefill_cap, slot)
        x = _mlp_sublayer(x, p, bits, cfg, ctx, axes,
                          gate_key="gate_mlp" if kind == "cross" else None)
        return x, new_st, zero
    if kind == "moe":
        x, new_st = _attn_sublayer(x, p, bits, cfg, ctx, axes, kind, mode,
                                   state, pos, img_x, prefill_cap, slot)
        h = apply_norm(x, p["norm2"], cfg.norm_type, cfg.norm_eps)
        out, aux = moe_mod.moe_ffn(h, p["moe"], cfg.moe, _bget(bits, "moe"),
                                   ctx, cfg.act, cfg.mlp_gated, axes)
        return x + out, new_st, aux
    if kind == "rwkv":
        st = state or (None, None, None)
        h = apply_norm(x, p["norm1"], cfg.norm_type, cfg.norm_eps)
        tm_state = None if st[0] is None else (st[0], st[1])
        out, (xp_tm, wkv) = rec_mod.rwkv_time_mix(
            h, p, bits, ctx, cfg.n_heads, cfg.rwkv_head_dim, state=tm_state)
        x = x + out
        h2 = apply_norm(x, p["norm2"], cfg.norm_type, cfg.norm_eps)
        out2, xp_cm = rec_mod.rwkv_channel_mix(h2, p, bits, ctx, state=st[2])
        new_st = ((xp_tm, wkv, xp_cm) if mode != "train" else None)
        return x + out2, new_st, zero
    if kind == "rec":
        h = apply_norm(x, p["norm1"], cfg.norm_type, cfg.norm_eps)
        out, rg_state = rec_mod.rglru_block(h, p["rg"], _bget(bits, "rg"),
                                            ctx, cfg.n_heads, state=state)
        x = x + out
        x = _mlp_sublayer(x, p, bits, cfg, ctx, axes)
        return x, rg_state if mode != "train" else None, zero
    raise ValueError(kind)


def _seg_bits(bits, seg: str, idx: str):
    if bits is None:
        return None
    return bits[seg][idx]


def run_layers(x: Array, params, bits, cfg: ModelConfig, ctx: QuantContext,
               axes: MeshAxes, *, mode: str = "train", states=None, pos=None,
               img_x=None, remat: bool = True, prefill_cap=None):
    """Run the full layer stack. Returns (x, new_states, aux)."""
    sched = build_schedule(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_states = {"prefix": {}, "body": {}, "suffix": {}} \
        if mode != "train" else None

    def site_state(seg, idx):
        if states is None:
            return None
        return states[seg].get(idx)

    for i, kind in enumerate(sched.prefix):
        x, st, a = apply_layer(kind, x, params["prefix"][str(i)],
                               _seg_bits(bits, "prefix", str(i)), cfg, ctx,
                               axes, mode=mode, state=site_state("prefix", str(i)),
                               pos=pos, img_x=img_x, prefill_cap=prefill_cap)
        aux += a
        if new_states is not None:
            new_states["prefix"][str(i)] = st

    if sched.repeats:
        body_bits = None if bits is None else bits["body"]
        body_states = None if states is None else states["body"]

        def step(carry, xs):
            x, aux = carry
            pp, bb, ss = xs
            sts = {}
            for p_i, kind in enumerate(sched.pattern):
                x, st, a = apply_layer(
                    kind, x, pp[str(p_i)],
                    None if bb is None else bb[str(p_i)], cfg, ctx, axes,
                    mode=mode, state=None if ss is None else ss[str(p_i)],
                    pos=pos, img_x=img_x, prefill_cap=prefill_cap)
                aux += a
                if mode != "train":
                    sts[str(p_i)] = st
            x = axes.shard(x, "dp", "sp", None)
            return (x, aux), (sts if mode != "train" else 0)

        f = jax.checkpoint(step, prevent_cse=False) \
            if (remat and mode == "train") else step
        (x, aux), body_out = jax.lax.scan(
            f, (x, aux), (params["body"], body_bits, body_states))
        if new_states is not None:
            new_states["body"] = body_out

    for i, kind in enumerate(sched.suffix):
        x, st, a = apply_layer(kind, x, params["suffix"][str(i)],
                               _seg_bits(bits, "suffix", str(i)), cfg, ctx,
                               axes, mode=mode, state=site_state("suffix", str(i)),
                               pos=pos, img_x=img_x, prefill_cap=prefill_cap)
        aux += a
        if new_states is not None:
            new_states["suffix"][str(i)] = st

    return x, new_states, aux


def lm_head(x: Array, params, cfg: ModelConfig, ctx: QuantContext,
            axes: MeshAxes) -> Array:
    x = apply_norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["w"]
        from repro.core.quantizer import bit_range, fake_quant, lsq_grad_scale_factor
        if ctx.enabled:
            qmin, qmax = bit_range(8, True)
            g = lsq_grad_scale_factor(w.size, qmax)
            w = fake_quant(w.astype(jnp.float32), params["embed"]["s_w8"],
                           qmin, qmax, grad_scale_factor=g)
        logits = jnp.einsum("bsd,vd->bsv", x.astype(ctx.compute_dtype),
                            w.astype(ctx.compute_dtype))
    else:
        logits = qeinsum_pinned("bsd,dv->bsv", x, params["head"], ctx)
    return axes.shard(logits.astype(jnp.float32), "dp", None, "tv")


# ===========================================================================
# top-level passes
# ===========================================================================
def apply_train(params, cfg: ModelConfig, inputs, bits, ctx: QuantContext,
                axes: MeshAxes = NO_AXES, remat: bool = True):
    """Full-sequence logits. Returns (logits (B,S,V) f32, aux)."""
    x, img_x = embed_inputs(params, cfg, inputs, ctx, axes)
    x, _, aux = run_layers(x, params, bits, cfg, ctx, axes, mode="train",
                           img_x=img_x, remat=remat)
    return lm_head(x, params, cfg, ctx, axes), aux


def loss_fn(params, cfg: ModelConfig, inputs, bits, ctx: QuantContext,
            axes: MeshAxes = NO_AXES, remat: bool = True):
    """Task loss (CE) + MoE aux. Returns (loss, metrics dict)."""
    logits, aux = apply_train(params, cfg, inputs, bits, ctx, axes, remat=remat)
    if cfg.encoder_only:
        labels = inputs["labels"]
        lg, tg = logits, labels
    else:
        lg, tg = logits[:, :-1], inputs["tokens"][:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - picked)
    loss = ce + MOE_AUX_COEF * aux
    return loss, {"ce": ce, "moe_aux": aux, "loss": loss}


def trim_decode_state(states, true_len):
    """Invalidate KV rows at positions >= ``true_len`` (fp and int8 caches
    alike). Used by bucketed prefill: a prompt padded at the end to a
    power-of-two length leaves pad-token rows in the cache whose positions
    would otherwise look valid to future decode steps. Non-cache state
    (recurrent, cross-attn image KV) passes through — bucketed prefill is
    gated to attention-only schedules upstream."""
    tl = jnp.asarray(true_len, jnp.int32)

    def one(c):
        if isinstance(c, attn.CACHE_TYPES):
            return c._replace(pos=jnp.where(c.pos < tl, c.pos, -1))
        return c

    return jax.tree.map(one, states,
                        is_leaf=lambda x: isinstance(x, attn.CACHE_TYPES))


def rollback_decode_state(states, cut):
    """Invalidate KV rows at positions >= per-slot ``cut`` ((B,) int32) in
    every cache of a per-slot decode state. This is the speculative-decode
    rollback: draft-written rows past the first rejection are rewound (ring:
    pos sentinel; paged: pos sentinel via the page table) so the cache is
    bitwise identical — pos exactly, codes/scales on all valid rows — to a
    non-speculative engine that decoded only the accepted tokens.
    Non-cache state (recurrent, cross-attn image KV) has no positional
    rows to rewind; speculation is gated to attention-only schedules
    upstream (ServeConfig validation)."""
    cut = jnp.asarray(cut, jnp.int32)

    def one(c):
        if isinstance(c, attn.CACHE_TYPES):
            return c.rollback(cut)
        return c

    return jax.tree.map(one, states,
                        is_leaf=lambda x: isinstance(x, attn.CACHE_TYPES))


def finish_prefill(x, states, params, cfg: ModelConfig, ctx: QuantContext,
                   axes: MeshAxes, true_len=None):
    """Shared prefill epilogue (the bucketing contract lives HERE, for both
    the fake-quant graph and the packed runtime session): read logits at
    the true last position and, for a padded (bucketed) prompt, invalidate
    the cache rows holding pad tokens. Returns (logits (B,V), states)."""
    if true_len is None:
        x_last = x[:, -1:]
    else:
        tl = jnp.asarray(true_len, jnp.int32)
        x_last = jax.lax.dynamic_slice_in_dim(x, tl - 1, 1, axis=1)
        states = trim_decode_state(states, tl)
    logits = lm_head(x_last, params, cfg, ctx, axes)
    return logits[:, 0], states


def apply_prefill(params, cfg: ModelConfig, inputs, bits, ctx: QuantContext,
                  axes: MeshAxes = NO_AXES, prefill_cap=None, true_len=None):
    """Prompt pass. Returns (last-position logits (B,V), decode state).
    `prefill_cap` sizes the KV cache (prompt + generation headroom).

    ``true_len`` (traced scalar) marks the real prompt length inside a
    padded (bucketed) input: logits are read at position ``true_len - 1``
    and cache rows holding pad tokens are invalidated, so one compiled
    prefill serves every prompt length in its bucket."""
    x, img_x = embed_inputs(params, cfg, inputs, ctx, axes)
    x, states, _ = run_layers(x, params, bits, cfg, ctx, axes, mode="prefill",
                              img_x=img_x, remat=False, prefill_cap=prefill_cap)
    return finish_prefill(x, states, params, cfg, ctx, axes, true_len)


def apply_decode(params, cfg: ModelConfig, token: Array, pos, states, bits,
                 ctx: QuantContext, axes: MeshAxes = NO_AXES):
    """One decode step. token (B,1) int32.

    ``pos`` is either a scalar int32 (fixed-batch serving: every row sits at
    the same position, KV caches carry shared ``pos (Sc,)``) or a (B,)
    vector (slot-indexed serving: row b is an independent engine slot at its
    own position, caches carry per-slot ``pos (B, Sc)`` — see
    ``init_decode_state(per_slot=True)``). Per-slot rows mask their own
    cache by position/length, so inactive or shorter slots never see another
    row's KV entries. Returns (logits (B,V), new states)."""
    x, _ = embed_inputs(params, cfg, {"tokens": token}, ctx, axes)
    x, new_states, _ = run_layers(x, params, bits, cfg, ctx, axes,
                                  mode="decode", states=states, pos=pos,
                                  remat=False)
    logits = lm_head(x, params, cfg, ctx, axes)
    return logits[:, 0], new_states


def apply_verify(params, cfg: ModelConfig, tokens: Array, pos, states, bits,
                 ctx: QuantContext, axes: MeshAxes = NO_AXES):
    """Speculative multi-token verify: ``tokens (B, S)`` int32 at per-slot
    absolute positions ``pos (B, S)`` (-1 sentinel rows for inactive
    slots).  One launch computes logits at every position and overwrites
    the S cached KV rows per slot with rows computed under THESE params
    (``attention.verify_attention`` batched append) — for the
    self-speculative engine that is what replaces the draft policy's rows
    with the target policy's, so the surviving cache is bitwise the
    non-speculative one.  Returns (logits (B, S, V) f32, new states)."""
    x, _ = embed_inputs(params, cfg, {"tokens": tokens}, ctx, axes)
    x, new_states, _ = run_layers(x, params, bits, cfg, ctx, axes,
                                  mode="verify", states=states, pos=pos,
                                  remat=False)
    return lm_head(x, params, cfg, ctx, axes), new_states


# ===========================================================================
# decode-state + input specs (ShapeDtypeStruct stand-ins for the dry-run)
# ===========================================================================
def init_site_state(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                    dtype=jnp.bfloat16, per_slot: bool = False,
                    kv_quant: str = "none", layout=None):
    """Fresh decode state for ONE layer site of the given kind.

    ``kv_quant="int8"`` (or "fake" — same fp layout, quantized values)
    selects the int8 KV layout for self-attention sites; recurrent /
    cross-attention state is unaffected. ``layout`` (a
    ``runtime.kv_cache.KVCacheLayout``) overrides the kind/quant flags for
    self-attention sites — it's how the paged pool layout is selected."""
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    W = cfg.lru_width or cfg.d_model
    if kind in ("attn", "dense", "moe"):
        window = _attn_window(cfg, kind)
        cap = min(capacity, window) if window else capacity
        return attn.init_kv_cache(batch, cap, KV, hd, dtype,
                                  per_slot=per_slot,
                                  quant=kv_quant == "int8",
                                  layout=layout)
    if kind == "cross":
        n = cfg.n_image_tokens
        return (jnp.zeros((batch, n, KV, hd), dtype),
                jnp.zeros((batch, n, KV, hd), dtype))
    if kind == "rwkv":
        hdr = cfg.rwkv_head_dim
        return (jnp.zeros((batch, 1, cfg.d_model), dtype),
                jnp.zeros((batch, H, hdr, hdr), jnp.float32),
                jnp.zeros((batch, 1, cfg.d_model), dtype))
    if kind == "rec":
        return (jnp.zeros((batch, cfg.conv1d_width - 1, W), dtype),
                jnp.zeros((batch, W), jnp.float32))
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int,
                      dtype=jnp.bfloat16, per_slot: bool = False,
                      kv_quant: str = "none"):
    """Allocate decode state for a context of `capacity` tokens.

    ``per_slot=True`` lays the KV caches out for the continuous-batching
    engine: the batch dim becomes a slot axis and every cache carries its
    own (batch, cap) position row, so sequences at different positions can
    share one decode step (``apply_decode`` with a (B,) pos vector).
    ``kv_quant="int8"`` stores self-attention KV as int8 codes + per-head
    scales (``runtime.kv_cache.QuantKVCache``)."""
    sched = build_schedule(cfg)

    def site_state(kind):
        return init_site_state(cfg, kind, batch, capacity, dtype=dtype,
                               per_slot=per_slot, kv_quant=kv_quant)

    states = {"prefix": {}, "body": {}, "suffix": {}}
    for i, kind in enumerate(sched.prefix):
        states["prefix"][str(i)] = site_state(kind)
    for p, kind in enumerate(sched.pattern):
        if not sched.repeats:
            break
        states["body"][str(p)] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (sched.repeats,) + a.shape),
            site_state(kind))
    for i, kind in enumerate(sched.suffix):
        states["suffix"][str(i)] = site_state(kind)
    return states


def decode_state_per_slot(states):
    """Widen a prefill-produced decode state to the per-slot layout: every
    KV cache's shared position vector is broadcast to one row per batch
    entry. Non-cache leaves (recurrent states, cross-attn image KV) already
    carry the batch dim and pass through unchanged."""
    return jax.tree.map(attn.cache_per_slot, states,
                        is_leaf=lambda x: isinstance(x, attn.CACHE_TYPES))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        d = {"tokens": sds((B, 1), jnp.int32)}
    elif cfg.frontend == "audio_stub":
        d = {"feats": sds((B, S, FRONTEND_DIMS["audio_stub"]), jnp.float32),
             "labels": sds((B, S), jnp.int32)}
    else:
        d = {"tokens": sds((B, S), jnp.int32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        d["img"] = sds((B, cfg.n_image_tokens, FRONTEND_DIMS["vision_stub"]),
                       jnp.float32)
    return d
