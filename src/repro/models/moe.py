"""Mixture-of-Experts FFN with capacity-bounded gather dispatch.

TPU-idiomatic dispatch (DESIGN.md §3): token-choice top-k routing with
*per-expert top-C token selection* for capacity enforcement — no sort, no
giant one-hot dispatch tensors. Each expert gathers its C highest-gate
tokens into an (E, C, D) buffer (E shards over the model axis for
fine-grained MoE, C over the data axes), runs dense 128-aligned matmuls,
and scatter-adds results back. Overflow tokens are dropped exactly like
capacity-factor dispatch in Mesh-TF/MaxText.

Routing (router logits, softmax, top-k) stays in f32 and is NOT quantized
(DESIGN.md §5 — precision-critical and tiny). Expert matmuls are QLayers:
one (E, ...) stacked tensor per projection with a shared per-tensor
indicator bank, activated-MAC BitOps accounting.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.dist.axes import MeshAxes
from repro.models.common import activation, dense_init
from repro.models.quant_layers import QuantContext, qdense_init, qeinsum

Array = jax.Array


# Perf switch (EXPERIMENTS.md §Perf): True = shard-local routing; False =
# the paper-faithful-baseline global top-C dispatch (G=1).
GROUP_LOCAL_DISPATCH = True


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def capacity(n_tokens: int, moe: MoEConfig, factor: float = 1.25,
             align: int = 128) -> int:
    c = int(n_tokens * moe.top_k / moe.n_experts * factor)
    c = min(round_up(c, align), n_tokens)          # top_k needs C <= n_tokens
    return max(min(align, n_tokens), c)


def moe_init(rng, d_model: int, moe: MoEConfig, bits, gated: bool,
             *, stacked=()):
    ks = jax.random.split(rng, 8)
    E, Fe = moe.n_experts, moe.d_ff
    p = {
        "router": {"w": dense_init(ks[0], d_model, E, stacked=stacked)},
        "wi": qdense_init(ks[1], d_model, Fe, bits, stacked=stacked + (E,)),
        "wo": qdense_init(ks[2], Fe, d_model, bits, stacked=stacked + (E,)),
    }
    if gated:
        p["wg"] = qdense_init(ks[3], d_model, Fe, bits, stacked=stacked + (E,))
    if moe.n_shared:
        Fs = moe.n_shared * Fe
        p["shared_wi"] = qdense_init(ks[4], d_model, Fs, bits, stacked=stacked)
        p["shared_wo"] = qdense_init(ks[5], Fs, d_model, bits, stacked=stacked)
        if gated:
            p["shared_wg"] = qdense_init(ks[6], d_model, Fs, bits, stacked=stacked)
    return p


def moe_ffn(x: Array, p, moe: MoEConfig, bits: Optional[Dict], ctx: QuantContext,
            act: str, gated: bool, axes: MeshAxes,
            capacity_factor: float = 1.25):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar f32).

    Dispatch is GROUP-LOCAL: tokens are split into `dp_size` groups aligned
    with the data shards and each group routes to per-group expert capacity
    C/G. Routing then never crosses data shards — the baseline (global
    top-C) all-gathered the full (T, D) token stream per MoE layer, the
    single largest collective in the roofline table (EXPERIMENTS.md §Perf).
    Per-shard capacity is the standard Mesh-TF/MaxText semantics.
    """
    B, S, D = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    act_fn = activation(act)
    # Group-local routing pays when experts are replicated/ffn-sharded
    # (mixtral: -82% wire bytes). Under expert parallelism the tokens must
    # cross to the expert shards anyway and per-group routing only
    # fragments that transfer (deepseek: +2.2x wire, measured) — keep the
    # global dispatch there. EXPERIMENTS.md §Perf iteration 4.
    G = axes.dp_size if (GROUP_LOCAL_DISPATCH and axes.enabled
                         and not axes.ep
                         and T % max(axes.dp_size, 1) == 0) else 1
    Tg = T // G
    # sharding a size-1 group axis would make SPMD pad the tensor dp_size-x
    # (measured: 4x step blowup) — target the token axis when ungrouped
    gdim, tdim = ("dp", None) if G > 1 else (None, "dp")
    xf = x.reshape(G, Tg, D)
    xf = axes.shard(xf, gdim, tdim, None)

    # ---- routing (f32, unquantized), per group -----------------------------
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, Tg, E)
    top_w, top_i = jax.lax.top_k(probs, K)                      # (G, Tg, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    gates = jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32)
                    * top_w[..., None], axis=2)                 # (G, Tg, E)

    # load-balance aux loss (Switch-style), averaged over groups
    frac_tokens = jnp.mean((gates > 0).astype(jnp.float32), axis=1)
    frac_probs = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # ---- capacity-bounded dispatch: per-(group, expert) top-C tokens ------
    C = capacity(Tg, moe, capacity_factor)
    gv, gi = jax.lax.top_k(gates.transpose(0, 2, 1), C)         # (G, E, C)
    keep = (gv > 0.0).astype(jnp.float32)
    bidx = jnp.arange(G)[:, None, None]
    if G == 1:
        # flat gather — the batched advanced-indexing form lowers to a
        # far worse scatter/gather under SPMD (measured: 4x bytes)
        xg = jnp.take(xf[0], gi[0].reshape(-1), axis=0).reshape(1, E, C, D)
    else:
        xg = xf[bidx, gi]                                       # (G, E, C, D)
    # fold groups into the capacity axis: C' = G*C, group-major, so the
    # dp sharding of C' lands each group on its own data shard.
    xg = xg.transpose(1, 0, 2, 3).reshape(E, G * C, D)
    xg = axes.shard(xg, "ep", "dp", None)

    # ---- expert matmuls (quantized) ---------------------------------------
    def b(name):
        return None if bits is None else bits[name]
    h = qeinsum("ecd,edf->ecf", xg, p["wi"], b("wi"), ctx)
    if gated:
        g = qeinsum("ecd,edf->ecf", xg, p["wg"], b("wg"), ctx)
        h = act_fn(g) * h
    else:
        h = act_fn(h)
    h = axes.shard(h, "ep", "dp", "mtp")
    y = qeinsum("ecf,efd->ecd", h, p["wo"], b("wo"), ctx)       # (E, G*C, D)
    y = y.reshape(E, G, C, D).transpose(1, 0, 2, 3)             # (G, E, C, D)
    y = y * (gv * keep)[..., None].astype(y.dtype)

    # ---- combine: scatter-add back to tokens, per group --------------------
    if G == 1:
        out = jnp.zeros((Tg, D), y.dtype).at[gi.reshape(-1)].add(
            y.reshape(E * C, D), mode="drop")[None]
    else:
        out = jnp.zeros((G, Tg, D), y.dtype).at[bidx, gi].add(y, mode="drop")
    out = axes.shard(out, gdim, tdim, None)
    out = out.reshape(T, D)
    xf = xf.reshape(T, D)

    # ---- shared experts (always-on) ---------------------------------------
    if moe.n_shared:
        hs = qeinsum("td,df->tf", xf, p["shared_wi"], b("shared_wi"), ctx)
        if gated:
            gs = qeinsum("td,df->tf", xf, p["shared_wg"], b("shared_wg"), ctx)
            hs = act_fn(gs) * hs
        else:
            hs = act_fn(hs)
        out = out + qeinsum("tf,fd->td", hs, p["shared_wo"], b("shared_wo"), ctx)

    return out.reshape(B, S, D), aux.astype(jnp.float32)


def moe_qlayer_defs(d_model: int, moe: MoEConfig, gated: bool):
    """(path, in, out, n_mats, macs_per_token, params, kind) tuples."""
    E, K, Fe = moe.n_experts, moe.top_k, moe.d_ff
    defs = [
        (("wi",), d_model, Fe, E, K * d_model * Fe, E * d_model * Fe, "moe"),
        (("wo",), Fe, d_model, E, K * Fe * d_model, E * Fe * d_model, "moe"),
    ]
    if gated:
        defs.append((("wg",), d_model, Fe, E, K * d_model * Fe,
                     E * d_model * Fe, "moe"))
    if moe.n_shared:
        Fs = moe.n_shared * Fe
        defs += [
            (("shared_wi",), d_model, Fs, 1, d_model * Fs, d_model * Fs, "moe"),
            (("shared_wo",), Fs, d_model, 1, Fs * d_model, Fs * d_model, "moe"),
        ]
        if gated:
            defs.append((("shared_wg",), d_model, Fs, 1, d_model * Fs,
                         d_model * Fs, "moe"))
    return defs
