"""Config-driven model zoo: one LM engine (lm.py) + building blocks."""
