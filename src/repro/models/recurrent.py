"""Attention-free mixers: RWKV6 'Finch' time/channel-mix and the Griffin
RG-LRU recurrent block (recurrentgemma).

Both are written so the *projections* (the FLOP carriers) are QLayers with
per-bit indicator banks, while the recurrence control parameters (decay
loras, RG-LRU gates, conv1d) stay full-precision — the LM analog of the
paper keeping BN/elementwise ops unquantized (DESIGN.md §5).

Sequence processing:

* RWKV6 wkv uses a *chunked* formulation (GLA-style): within a chunk the
  pairwise per-channel decay tensor has exponents `L_t - L_{tau+1} <= 0`
  for every causal pair, so everything is computed with exp() of
  non-positive numbers — unconditionally stable, no secondary chunking.
  A step-by-step `wkv_scan_ref` oracle cross-checks it in tests, and the
  Pallas kernel (`repro.kernels.rwkv_scan`) implements the same math with
  VMEM tiles for TPU.
* RG-LRU uses `jax.lax.associative_scan` (O(log S) depth) — decays are
  sigmoids so `a_t <= 1` and the scan is stable by construction.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.quant_layers import QuantContext, qdense_init, qeinsum

Array = jax.Array

RWKV_LORA_R = 32       # ddlerp low-rank
RWKV_DECAY_R = 64      # decay low-rank
RGLRU_C = 8.0          # Griffin's fixed temperature on the recurrent gate
MIN_LOG_W = -8.0       # clamp: per-step decay w >= e^-8 (numerical floor)
WKV_REMAT = True       # perf switch: recompute chunk tensors in backward


def token_shift(x: Array, x_prev: Optional[Array]) -> Array:
    """RWKV token shift: value of the *previous* timestep (zeros / carried)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


# ===========================================================================
# RWKV6 time-mix + channel-mix
# ===========================================================================
def rwkv_init(rng, d_model: int, n_heads: int, head_dim: int, d_ff: int,
              bits, *, stacked=()):
    D, H, hd = d_model, n_heads, head_dim
    assert H * hd == D, (H, hd, D)
    ks = jax.random.split(rng, 16)
    z = lambda *s: jnp.zeros(stacked + s, jnp.float32)

    p = {
        # ddlerp mixing (fp)
        "mu_x": z(D),
        "mu": z(5, D),                                      # w,k,v,r,g
        "lora_A": dense_init(ks[0], D, 5 * RWKV_LORA_R, stacked=stacked) * 0.1,
        "lora_B": jnp.zeros(stacked + (5, RWKV_LORA_R, D), jnp.float32),
        # data-dependent decay (fp)
        "w0": z(D) - 4.0,                                   # init: slowish decay
        "wd1": dense_init(ks[1], D, RWKV_DECAY_R, stacked=stacked) * 0.1,
        "wd2": jnp.zeros(stacked + (RWKV_DECAY_R, D), jnp.float32),
        "u": z(H, hd) + 0.5,                                # bonus
        # head group-norm (fp)
        "ln_x_scale": z(D) + 1.0,
        "ln_x_bias": z(D),
        # projections (QLayers)
        "wr": qdense_init(ks[2], D, D, bits, stacked=stacked),
        "wk": qdense_init(ks[3], D, D, bits, stacked=stacked),
        "wv": qdense_init(ks[4], D, D, bits, stacked=stacked),
        "wg": qdense_init(ks[5], D, D, bits, stacked=stacked),
        "wo": qdense_init(ks[6], D, D, bits, stacked=stacked),
        # channel-mix
        "mu_ck": z(D),
        "mu_cr": z(D),
        "cm_wk": qdense_init(ks[7], D, d_ff, bits, stacked=stacked),
        "cm_wv": qdense_init(ks[8], d_ff, D, bits, stacked=stacked),
        "cm_wr": qdense_init(ks[9], D, D, bits, stacked=stacked),
    }
    return p


RWKV_QLAYER_PATHS = ("wr", "wk", "wv", "wg", "wo", "cm_wk", "cm_wv", "cm_wr")


def _ddlerp(x: Array, xs: Array, p) -> Tuple[Array, ...]:
    """RWKV6 data-dependent lerp -> the 5 mixed inputs (w, k, v, r, g)."""
    sx = xs - x
    xxx = x + sx * p["mu_x"].astype(x.dtype)
    B, S, D = x.shape
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["lora_A"].astype(x.dtype)))
    lo = lo.reshape(B, S, 5, RWKV_LORA_R)
    lo = jnp.einsum("bsfr,frd->bsfd", lo, p["lora_B"].astype(x.dtype))
    mixed = []
    for i in range(5):
        m = p["mu"][i].astype(x.dtype) + lo[:, :, i]
        mixed.append(x + sx * m)
    return tuple(mixed)   # x_w, x_k, x_v, x_r, x_g


def _decay_log(x_w: Array, p) -> Array:
    """log w_t in (-inf, 0): w = exp(-exp(w0 + tanh(x_w wd1) wd2)), clamped."""
    d = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", x_w.astype(jnp.float32),
                            p["wd1"].astype(jnp.float32))),
        p["wd2"].astype(jnp.float32))
    return jnp.clip(-jnp.exp(d), MIN_LOG_W, -1e-6)


def wkv_scan_ref(r: Array, k: Array, v: Array, log_w: Array, u: Array,
                 state: Array) -> Tuple[Array, Array]:
    """Step-by-step wkv oracle. r/k/v/log_w: (B, S, H, hd); state (B, H, hd, hd).

    y_t = r_t . (S_t + (u*k_t) v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    """
    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp      # (B, H, hd)
        w_t = jnp.exp(lw_t)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S) \
            + jnp.einsum("bhi,bhi,bhj->bhj", r_t, u * k_t, v_t)
        S = w_t[..., None] * S + jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, log_w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r: Array, k: Array, v: Array, log_w: Array, u: Array,
                state: Array, chunk: int = 32,
                remat: bool = True) -> Tuple[Array, Array]:
    """Chunked wkv. Shapes as in `wkv_scan_ref`; S % chunk == 0.

    Within a chunk, every causal pair (t > tau) uses decay
    exp(L_t - L_{tau+1}) with L the inclusive-exclusive cumulative log-decay;
    all exponents are <= 0 so exp() never overflows.

    `remat=True` recomputes the per-chunk (B,H,T,T,hd) decay tensor in the
    backward instead of stashing it per scan step — the baseline roofline
    showed those residuals dominating rwkv6 train HBM traffic
    (EXPERIMENTS.md §Perf).
    """
    B, S, H, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    T = chunk

    def reshape(a):
        return a.reshape(B, n_chunks, T, H, hd).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(reshape, (r, k, v, log_w))   # (N, B, H, T, hd)
    uu = u[None]                                       # (1, H, hd)

    tri_strict = jnp.tril(jnp.ones((T, T), jnp.float32), -1)

    def chunk_step(S0, inp):
        rt, kt, vt, lwt = (a.astype(jnp.float32) for a in inp)  # (B,H,T,hd)
        L = jnp.cumsum(lwt, axis=2)                  # L_t = sum_{tau<=t} lw
        Lx = L - lwt                                 # exclusive: sum_{tau<t}
        # inter-chunk: y_t += (r_t * e^{Lx_t}) . S0
        r_in = rt * jnp.exp(Lx)
        y = jnp.einsum("bhti,bhij->bhtj", r_in, S0)
        # intra-chunk strict-causal pairs: decay exponent Lx_t - L_tau <= 0
        expo = Lx[:, :, :, None, :] - L[:, :, None, :, :]   # (B,H,t,tau,hd)
        dec = jnp.exp(jnp.minimum(expo, 0.0)) * tri_strict[None, None, :, :, None]
        A = jnp.einsum("bhti,bhtsi,bhsi->bhts", rt, dec, kt)
        y += jnp.einsum("bhts,bhsj->bhtj", A, vt)
        # diagonal (bonus) term
        y += jnp.einsum("bhti,bhti,bhtj->bhtj", rt, uu[..., None, :] * kt, vt)
        # state update: S' = e^{L_T} S0 + sum_tau e^{L_T - L_tau} k_tau v_tau^T
        LT = L[:, :, -1:, :]                          # (B,H,1,hd)
        k_dec = kt * jnp.exp(LT - L)
        S1 = jnp.exp(LT[:, :, 0, :, None]) * S0 \
            + jnp.einsum("bhti,bhtj->bhij", k_dec, vt)
        return S1, y

    step = jax.checkpoint(chunk_step, prevent_cse=False) if remat \
        else chunk_step
    state, ys = jax.lax.scan(step, state.astype(jnp.float32),
                             (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return y.astype(r.dtype), state


def _head_groupnorm(y: Array, scale: Array, bias: Array, eps: float = 64e-5) -> Array:
    """RWKV ln_x: GroupNorm with one group per head, affine over D."""
    B, S, H, hd = y.shape
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    yn = (y32 - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(B, S, H * hd)
    return (yn * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(y.dtype)


def rwkv_time_mix(x: Array, p, bits: Optional[Dict], ctx: QuantContext,
                  n_heads: int, head_dim: int,
                  state: Optional[Tuple[Array, Array]] = None,
                  chunk: int = 32, use_chunked: bool = True):
    """x: (B, S, D). state = (x_prev (B,1,D), wkv (B,H,hd,hd)) or None.

    Returns (out, new_state).
    """
    B, S, D = x.shape
    H, hd = n_heads, head_dim
    x_prev = None if state is None else state[0]
    wkv0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
            else state[1])

    xs = token_shift(x, x_prev)
    x_w, x_k, x_v, x_r, x_g = _ddlerp(x, xs, p)
    log_w = _decay_log(x_w, p).reshape(B, S, H, hd)

    def b(name):
        return None if bits is None else bits[name]
    r = qeinsum("bsd,de->bse", x_r, p["wr"], b("wr"), ctx).reshape(B, S, H, hd)
    k = qeinsum("bsd,de->bse", x_k, p["wk"], b("wk"), ctx).reshape(B, S, H, hd)
    v = qeinsum("bsd,de->bse", x_v, p["wv"], b("wv"), ctx).reshape(B, S, H, hd)
    g = jax.nn.silu(qeinsum("bsd,de->bse", x_g, p["wg"], b("wg"), ctx))

    u = p["u"].astype(jnp.float32)
    if use_chunked and S % chunk == 0 and S > 1:
        y, wkv1 = wkv_chunked(r, k, v, log_w, u, wkv0, chunk=chunk,
                              remat=WKV_REMAT)
    else:
        y, wkv1 = wkv_scan_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), log_w, u, wkv0)
        y = y.astype(x.dtype)

    y = _head_groupnorm(y, p["ln_x_scale"], p["ln_x_bias"])
    y = y * g
    out = qeinsum("bsd,de->bse", y, p["wo"], b("wo"), ctx)
    new_state = (x[:, -1:], wkv1)
    return out, new_state


def rwkv_channel_mix(x: Array, p, bits: Optional[Dict], ctx: QuantContext,
                     state: Optional[Array] = None):
    """x: (B, S, D). state = x_prev (B, 1, D) or None. Returns (out, state)."""
    xs = token_shift(x, state)
    xk = x + (xs - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xs - x) * p["mu_cr"].astype(x.dtype)

    def b(name):
        return None if bits is None else bits[name]
    k = qeinsum("bsd,df->bsf", xk, p["cm_wk"], b("cm_wk"), ctx)
    k = jnp.square(jax.nn.relu(k))
    kv = qeinsum("bsf,fd->bsd", k, p["cm_wv"], b("cm_wv"), ctx)
    rgate = jax.nn.sigmoid(qeinsum("bsd,de->bse", xr, p["cm_wr"], b("cm_wr"), ctx))
    return rgate * kv, x[:, -1:]


# ===========================================================================
# RG-LRU recurrent block (Griffin / recurrentgemma)
# ===========================================================================
def rglru_init(rng, d_model: int, lru_width: int, n_heads: int,
               conv_width: int, bits, *, stacked=()):
    W = lru_width or d_model
    ks = jax.random.split(rng, 8)
    bw = W // n_heads     # block-diagonal gate width
    z = lambda *s: jnp.zeros(stacked + s, jnp.float32)
    # Lambda init so a = sigmoid(lam)^c spreads over (0.9, 0.999) — Griffin A.2
    lam = jnp.linspace(2.2, 6.0, W, dtype=jnp.float32)
    lam = jnp.broadcast_to(lam, stacked + (W,))
    return {
        "wx": qdense_init(ks[0], d_model, W, bits, stacked=stacked),
        "wgate": qdense_init(ks[1], d_model, W, bits, stacked=stacked),
        "wo": qdense_init(ks[2], W, d_model, bits, stacked=stacked),
        "conv_w": dense_init(ks[3], conv_width, 1, stacked=stacked)[..., 0]
        [..., None] * jnp.ones(stacked + (conv_width, W)),
        "conv_b": z(W),
        # block-diagonal gates (fp): (n_heads, bw, bw)
        "gate_a_w": dense_init(ks[4], bw, bw, stacked=stacked + (n_heads,)),
        "gate_a_b": z(n_heads, bw),
        "gate_x_w": dense_init(ks[5], bw, bw, stacked=stacked + (n_heads,)),
        "gate_x_b": z(n_heads, bw),
        "lam": lam,
    }


RGLRU_QLAYER_PATHS = ("wx", "wgate", "wo")


def _causal_conv1d(u: Array, w: Array, b: Array,
                   state: Optional[Array]) -> Tuple[Array, Array]:
    """Depthwise causal conv. u: (B, S, W); w: (cw, W); state (B, cw-1, W)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)   # (B, S+cw-1, W)
    S = u.shape[1]
    out = jnp.zeros_like(u)
    for j in range(cw):            # cw = 4: four shifted multiply-adds
        out = out + ext[:, j:j + S] * w[cw - 1 - j].astype(u.dtype)
    out = out + b.astype(u.dtype)
    return out, ext[:, -(cw - 1):] if cw > 1 else state


def _block_diag_gate(u: Array, w: Array, b: Array, n_heads: int) -> Array:
    """sigmoid(block-diagonal linear). u: (B, S, W); w: (H, bw, bw)."""
    B, S, W = u.shape
    bw = W // n_heads
    uh = u.reshape(B, S, n_heads, bw)
    y = jnp.einsum("bshi,hij->bshj", uh.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return jax.nn.sigmoid(y).reshape(B, S, W)


def rglru_scan(a: Array, bx: Array, h0: Optional[Array]) -> Array:
    """h_t = a_t * h_{t-1} + bx_t via associative scan. a/bx: (B, S, W) f32."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(bx.dtype))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(x: Array, p, bits: Optional[Dict], ctx: QuantContext,
                n_heads: int, state: Optional[Tuple[Array, Array]] = None):
    """Griffin recurrent block. x: (B, S, D).

    state = (conv_buf (B, cw-1, W), h (B, W)) or None. Returns (out, state).
    """
    def b(name):
        return None if bits is None else bits[name]

    u = qeinsum("bsd,dw->bsw", x, p["wx"], b("wx"), ctx)
    gate = jax.nn.gelu(qeinsum("bsd,dw->bsw", x, p["wgate"], b("wgate"), ctx))

    conv_state = None if state is None else state[0]
    u, conv_state = _causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)

    # RG-LRU
    r = _block_diag_gate(u, p["gate_a_w"], p["gate_a_b"], n_heads)
    i = _block_diag_gate(u, p["gate_x_w"], p["gate_x_b"], n_heads)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                       # (B,S,W) in (0,1)
    gated = i * u.astype(jnp.float32)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    h0 = None if state is None else state[1]
    if x.shape[1] == 1:                                      # decode fast path
        hprev = jnp.zeros_like(bx[:, 0]) if h0 is None else h0.astype(jnp.float32)
        h = (a[:, 0] * hprev + bx[:, 0])[:, None]
    else:
        h = rglru_scan(a, bx, h0)
    y = h.astype(x.dtype) * gate
    out = qeinsum("bsw,wd->bsd", y, p["wo"], b("wo"), ctx)
    return out, (conv_state, h[:, -1].astype(jnp.float32))
