"""Quantized einsum layers carrying the paper's per-bit indicator banks.

Every searchable projection is a param dict ``{"w", "s_w", "s_a"}`` where
``s_w``/``s_a`` are the (n_bits,) learnable scale banks — the layer's
importance indicators (paper §3.3/3.4). Bit selection is an *index into the
bank* so it can be static (ILP policy), uniform-traced (joint training pass
k), or random-traced (the communication pass), including under lax.scan.

Pinned 8-bit layers (embedding / lm head, paper §4.1) carry a single scale
and never enter the search.
"""
from __future__ import annotations

from dataclasses import dataclass
import jax
import jax.numpy as jnp

from repro.core.quantizer import (
    BitTables,
    bit_range,
    fake_quant,
    fake_quant_indexed,
    init_scale_from_stats,
    init_scale_same,
    lsq_grad_scale_factor,
)
from repro.models.common import dense_init

Array = jax.Array


@dataclass(frozen=True)
class QuantContext:
    """Static quantization-mode switches threaded through the model.

    ``kv_quant`` selects the decode-time KV-cache storage: "none" (fp),
    "int8" (codes + per-head write-time scales, ``runtime.kv_cache``), or
    "fake" (quantize-dequantize in an fp cache — the reference graph whose
    tokens the int8 path must reproduce exactly).
    """
    tables_w: BitTables
    tables_a: BitTables
    enabled: bool = True
    quantize_acts: bool = True
    compute_dtype: jnp.dtype = jnp.bfloat16
    kv_quant: str = "none"

    @staticmethod
    def make(bits, act_signed: bool, enabled: bool = True,
             compute_dtype=jnp.bfloat16, kv_quant: str = "none") -> "QuantContext":
        return QuantContext(
            tables_w=BitTables.make(bits, signed=True),
            tables_a=BitTables.make(bits, signed=act_signed),
            enabled=enabled,
            compute_dtype=compute_dtype,
            kv_quant=kv_quant,
        )

    @property
    def n_bits(self) -> int:
        return int(self.tables_w.bits.shape[0])


def fp_context(compute_dtype=jnp.bfloat16) -> QuantContext:
    """Quantization disabled (full-precision baseline)."""
    return QuantContext(
        tables_w=BitTables.make((8,), True),
        tables_a=BitTables.make((8,), True),
        enabled=False,
        compute_dtype=compute_dtype,
    )


# ---------------------------------------------------------------------------
# param construction
# ---------------------------------------------------------------------------
def qdense_init(rng, in_dim: int, out_dim: int, bits, *, stacked=()):
    """Searchable projection: weight + per-bit indicator banks.

    Weight scales use the paper's statistics init (2E|w|/sqrt(qmax_b));
    activation scales use the paper's same-value init 0.1/b (§3.3.2).
    Stacked layers (scan) get banks of shape (*stacked, n_bits).
    """
    w = dense_init(rng, in_dim, out_dim, stacked=stacked)
    s_w = jnp.stack(
        [init_scale_from_stats(w, bit_range(int(b), True)[1]) * jnp.ones(stacked)
         if stacked else init_scale_from_stats(w, bit_range(int(b), True)[1])
         for b in bits], axis=-1)
    s_a = jnp.stack(
        [init_scale_same(int(b)) * jnp.ones(stacked)
         if stacked else init_scale_same(int(b))
         for b in bits], axis=-1)
    return {"w": w, "s_w": jnp.asarray(s_w, jnp.float32),
            "s_a": jnp.asarray(s_a, jnp.float32)}


def pinned_init(rng, in_dim: int, out_dim: int, *, pinned_bits: int = 8,
                stacked=()):
    """8-bit pinned projection (embedding / lm head): single scale pair."""
    w = dense_init(rng, in_dim, out_dim, stacked=stacked)
    qmax = bit_range(pinned_bits, True)[1]
    s = init_scale_from_stats(w, qmax)
    if stacked:
        s = s * jnp.ones(stacked)
    return {"w": w, "s_w8": jnp.asarray(s, jnp.float32),
            "s_a8": jnp.full(stacked + (), 0.1 / pinned_bits, jnp.float32)}


# ---------------------------------------------------------------------------
# application
# ---------------------------------------------------------------------------
def _maybe_quant_w(p, w_idx, ctx: QuantContext) -> Array:
    w = p["w"]
    if ctx.enabled and w_idx is not None:
        w = fake_quant_indexed(w.astype(jnp.float32), p["s_w"], w_idx,
                               ctx.tables_w, numel=w.size)
    return w.astype(ctx.compute_dtype)


def _maybe_quant_a(x: Array, p, a_idx, ctx: QuantContext) -> Array:
    if ctx.enabled and ctx.quantize_acts and a_idx is not None:
        x = fake_quant_indexed(x, p["s_a"], a_idx, ctx.tables_a, numel=x.size)
    return x.astype(ctx.compute_dtype)


def qeinsum(eqn: str, x: Array, p, bits, ctx: QuantContext) -> Array:
    """Quantized einsum. `bits` is None (fp) or a dict {"w": idx, "a": idx}
    of scalar bank indices (python ints or traced).

    When `p` is a packed serving-time weight (``runtime.packing
    .PackedLinear``) instead of a fake-quant param dict, the matmul routes
    through the runtime kernel dispatch; the searched bit-widths are baked
    into the packed leaf, so `bits` is ignored."""
    if not isinstance(p, dict):
        from repro.runtime.dispatch import packed_qeinsum
        return packed_qeinsum(eqn, x, p, ctx)
    w_idx = None if bits is None else bits["w"]
    a_idx = None if bits is None else bits["a"]
    xq = _maybe_quant_a(x, p, a_idx, ctx)
    wq = _maybe_quant_w(p, w_idx, ctx)
    return jnp.einsum(eqn, xq, wq)


def qeinsum_pinned(eqn: str, x: Array, p, ctx: QuantContext,
                   pinned_bits: int = 8, quant_act: bool = True) -> Array:
    """8-bit pinned einsum for first/last layers (outside the search)."""
    w = p["w"]
    if ctx.enabled:
        qmin, qmax = bit_range(pinned_bits, True)
        g = lsq_grad_scale_factor(w.size, qmax)
        w = fake_quant(w.astype(jnp.float32), p["s_w8"], qmin, qmax,
                       grad_scale_factor=g)
        if quant_act:
            ga = lsq_grad_scale_factor(x.size, qmax)
            x = fake_quant(x, p["s_a8"].astype(x.dtype), qmin, qmax,
                           grad_scale_factor=ga)
    return jnp.einsum(eqn, x.astype(ctx.compute_dtype),
                      w.astype(ctx.compute_dtype))


def embed_lookup_pinned(tokens: Array, p, ctx: QuantContext) -> Array:
    """Embedding table lookup with the table fake-quantized at 8 bits."""
    w = p["w"]
    if ctx.enabled:
        qmin, qmax = bit_range(8, True)
        g = lsq_grad_scale_factor(w.size, qmax)
        w = fake_quant(w.astype(jnp.float32), p["s_w8"], qmin, qmax,
                       grad_scale_factor=g)
    return jnp.take(w.astype(ctx.compute_dtype), tokens, axis=0)
