"""Shared model primitives: norms, activations, RoPE, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def norm_init(d: int, norm_type: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(x: Array, p, norm_type: str, eps: float) -> Array:
    if norm_type == "ln":
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":   # RWKV channel-mix uses squared relu
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# RoPE (half-rotation convention)
# ---------------------------------------------------------------------------
def rope_table(head_dim: int, max_len: int, theta: float):
    """(max_len, head_dim//2) cos/sin tables in f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., S, H, D); cos/sin: (S, D//2) or broadcastable (..., S, 1, D//2)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = x32[..., :d2], x32[..., d2:]
    if cos.ndim == 2:  # (S, D//2) -> (S, 1, D//2) to broadcast over heads
        cos = cos[:, None, :]
        sin = sin[:, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def rope_at(cos_table: Array, sin_table: Array, positions: Array):
    """Gather per-position rows: positions (...,) -> (..., D//2)."""
    return jnp.take(cos_table, positions, axis=0), jnp.take(sin_table, positions, axis=0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def dense_init(rng, in_dim: int, out_dim: int, *, stacked=(), dtype=jnp.float32):
    shape = tuple(stacked) + (in_dim, out_dim)
    std = in_dim ** -0.5
    return jax.random.normal(rng, shape, dtype) * std


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    # d^-0.5 keeps tied-embedding logits O(|x|); the first norm layer
    # rescales activations regardless.
    return jax.random.normal(rng, (vocab, d), dtype) * d ** -0.5
